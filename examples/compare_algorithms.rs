//! Side-by-side comparison of FedAvg, D-SGD and MoDeST on one task —
//! the Fig. 1 story in a single runnable example.
//!
//! ```text
//! make artifacts && cargo run --release --example compare_algorithms
//! ```

use anyhow::Result;

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::sim::ChurnSchedule;

fn main() -> Result<()> {
    let runtime = XlaRuntime::load("artifacts")?;
    let mut rows = Vec::new();
    for algo in [Algo::Fedavg, Algo::Dsgd, Algo::Modest] {
        let spec = SessionSpec {
            dataset: "cifar10".into(),
            algo,
            nodes: 24,
            s: 8,
            a: 3,
            sf: 1.0,
            max_time_s: 300.0,
            eval_interval_s: 10.0,
            ..Default::default()
        };
        println!("running {algo:?}...");
        let (m, _) = match algo {
            Algo::Dsgd => spec.build_dsgd(Some(&runtime))?.run(),
            _ => spec.build_modest(Some(&runtime), ChurnSchedule::empty())?.run(),
        };
        rows.push((algo, m));
    }

    println!();
    println!(
        "{:<8} {:>7} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "algo", "rounds", "best-acc", "total", "min-node", "max-node", "overhead"
    );
    for (algo, m) in &rows {
        let t = &m.traffic;
        println!(
            "{:<8} {:>7} {:>10.4} {:>12} {:>12} {:>12} {:>9.1}%",
            format!("{algo:?}"),
            m.final_round,
            m.best_metric(true).unwrap_or(f64::NAN),
            fmt_bytes(t.total),
            fmt_bytes(t.min_node),
            fmt_bytes(t.max_node),
            100.0 * t.overhead_fraction
        );
    }
    println!();
    println!("expected shape (paper Fig. 1 + Table 4):");
    println!("  - FedAvg & MoDeST converge comparably fast; D-SGD lags (residual variance)");
    println!("  - D-SGD total traffic >> MoDeST > FedAvg");
    println!("  - FedAvg max-node (the server) >> its min-node; MoDeST is balanced");
    Ok(())
}
