//! Side-by-side comparison of every registered protocol on one task —
//! the Fig. 1 story in a single runnable example, driven entirely by the
//! scenario registry (FedAvg, D-SGD, MoDeST, and gossip-DL all come from
//! `ProtocolRegistry::builtins()` — nothing here names an algorithm
//! beyond its registry string).
//!
//! ```text
//! make artifacts && cargo run --release --example compare_algorithms
//! ```

use anyhow::Result;

use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::scenario::{ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;

fn main() -> Result<()> {
    let runtime = XlaRuntime::load("artifacts")?;
    let registry = ProtocolRegistry::builtins();
    let mut rows = Vec::new();
    for meta in registry.metas() {
        let mut spec = ScenarioSpec::new("cifar10", meta.name);
        spec.population.nodes = 24;
        spec.protocol.s = 8;
        spec.protocol.a = 3;
        spec.protocol.sf = 1.0;
        spec.run.max_time_s = 300.0;
        spec.run.eval_interval_s = 10.0;
        println!("running {}...", meta.label);
        let (m, _) = registry
            .build(&spec, Some(&runtime), ChurnSchedule::empty())?
            .run();
        rows.push((meta.label, m));
    }

    println!();
    println!(
        "{:<10} {:>7} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "protocol", "rounds", "best-acc", "total", "min-node", "max-node", "overhead"
    );
    for (label, m) in &rows {
        let t = &m.traffic;
        println!(
            "{:<10} {:>7} {:>10.4} {:>12} {:>12} {:>12} {:>9.1}%",
            label,
            m.final_round,
            m.best_metric(true).unwrap_or(f64::NAN),
            fmt_bytes(t.total),
            fmt_bytes(t.min_node),
            fmt_bytes(t.max_node),
            100.0 * t.overhead_fraction
        );
    }
    println!();
    println!("expected shape (paper Fig. 1 + Table 4):");
    println!("  - FedAvg & MoDeST converge comparably fast; D-SGD and gossip lag");
    println!("    (residual variance across node replicas)");
    println!("  - D-SGD total traffic >> MoDeST > FedAvg");
    println!("  - FedAvg max-node (the server) >> its min-node; MoDeST is balanced");
    Ok(())
}
