//! Churn resilience demo (paper §4.6–4.7 in miniature).
//!
//! ```text
//! make artifacts && cargo run --release --example churn_resilience
//! ```
//!
//! Starts a 40-node CIFAR10-sized session, lets 4 extra nodes join
//! mid-training, then crashes half the network, and shows that MoDeST
//! (a) integrates the joiners into everyone's views, (b) keeps making
//! rounds while unresponsive nodes inflate sample times, and (c) recovers
//! once the activity window flags the crashed nodes.

use anyhow::Result;

use modest_dl::runtime::XlaRuntime;
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, SimTime};

fn main() -> Result<()> {
    let initial = 40u32;
    let joiners = 4u32;
    let mut spec = ScenarioSpec::new("cifar10", "modest");
    spec.population.nodes = initial as usize;
    spec.protocol.s = 10;
    spec.protocol.a = 5;
    spec.protocol.sf = 0.8;
    spec.protocol.dt_s = 2.0;
    spec.protocol.dk = 10;
    spec.run.max_time_s = 900.0;
    spec.run.eval_interval_s = 10.0;

    // Joins at minute 1..4, mass crash from minute 6 until half are gone.
    let churn = ChurnSchedule::staggered_joins(
        initial,
        joiners,
        SimTime::from_secs_f64(60.0),
        SimTime::from_secs_f64(30.0),
    )
    .merged(ChurnSchedule::mass_crash(
        initial + joiners,
        (initial + joiners) / 2,
        3,
        SimTime::from_secs_f64(360.0),
        SimTime::from_secs_f64(30.0),
    ));

    let runtime = XlaRuntime::load(&spec.workload.artifacts_dir)?;
    println!(
        "running: {} initial nodes, {} joiners, then crash to {} survivors",
        initial,
        joiners,
        (initial + joiners) / 2
    );
    let (metrics, _) = run_scenario(&spec, Some(&runtime), churn)?;

    println!("\njoin propagation (paper Fig. 5 behaviour):");
    for j in &metrics.joins {
        match j.full_propagation_s() {
            Some(d) => println!(
                "  node {:>3} joined at {:>4.0}s -> known by all initial nodes after {:>5.1}s",
                j.joiner, j.joined_at_s, d
            ),
            None => println!(
                "  node {:>3} joined at {:>4.0}s -> propagation incomplete at session end",
                j.joiner, j.joined_at_s
            ),
        }
    }

    println!("\naccuracy through the crash window (paper Fig. 6 top):");
    for p in &metrics.curve {
        let phase = if p.time_s < 360.0 {
            "pre-crash "
        } else if p.time_s < 360.0 + 8.0 * 30.0 {
            "crashing  "
        } else {
            "post-crash"
        };
        println!(
            "  t={:>6.0}s [{phase}] round={:>4} acc={:.3}",
            p.time_s, p.round, p.metric
        );
    }

    println!("\nsample durations (paper Fig. 6 bottom — note the bump while");
    println!("crashed nodes still look like candidates, then recovery):");
    let mut window = vec![0.0f64; 0];
    let mut last_bucket = 0u64;
    for s in &metrics.samples {
        let bucket = (s.completed_at_s / 60.0) as u64;
        if bucket != last_bucket && !window.is_empty() {
            let mean: f64 = window.iter().sum::<f64>() / window.len() as f64;
            let max = window.iter().cloned().fold(0.0, f64::max);
            println!(
                "  minute {:>2}: {:>3} samples, mean {:.2}s, max {:.2}s",
                last_bucket,
                window.len(),
                mean,
                max
            );
            window.clear();
        }
        last_bucket = bucket;
        window.push(s.duration_s);
    }
    println!(
        "\nfinal round {} after {:.0}s virtual; session survived the crash wave",
        metrics.final_round, metrics.duration_s
    );
    Ok(())
}
