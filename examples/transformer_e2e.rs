//! End-to-end driver: decentralized training of a causal transformer LM.
//!
//! ```text
//! make artifacts && cargo run --release --example transformer_e2e [rounds]
//! ```
//!
//! This is the full-system proof that all layers compose:
//! * L1 — the Pallas dense (fwd+bwd), fused SGD, and masked-mean kernels,
//! * L2 — the JAX transformer train/eval graphs AOT'd to HLO text,
//! * L3 — the rust MoDeST coordinator sampling trainers/aggregators over a
//!   simulated WAN of 32 nodes,
//! with a 421k-parameter transformer (vocab 64, d=128, 2 layers, T=64)
//! learning a synthetic Markov corpus sharded across the nodes. The loss
//! curve and token accuracy are logged every few rounds; the run is
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! (The paper targets edge-scale CNNs; a 100M-parameter model at hundreds
//! of rounds is not feasible on this single-core CPU image — the model is
//! scaled to keep the full three-layer round path identical. See
//! EXPERIMENTS.md for the scaling note.)

use std::time::Instant;

use anyhow::Result;

use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;

fn main() -> Result<()> {
    let rounds: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("rounds must be an integer"))
        .unwrap_or(200);

    let mut spec = ScenarioSpec::new("transformer", "modest");
    spec.population.nodes = 32;
    spec.protocol.s = 8;
    spec.protocol.a = 2;
    spec.protocol.sf = 1.0;
    spec.run.max_rounds = rounds;
    spec.run.max_time_s = 86_400.0;
    spec.run.eval_interval_s = 30.0;

    println!("loading artifacts + compiling transformer executables...");
    let t0 = Instant::now();
    let runtime = XlaRuntime::load(&spec.workload.artifacts_dir)?;
    let vm = runtime.manifest().variant("transformer")?;
    println!(
        "  {} params ({}), vocab={}, layers={}, compiled in {:.1}s",
        vm.param_count,
        fmt_bytes(vm.model_bytes),
        vm.meta_usize("vocab").unwrap_or(0),
        vm.meta_usize("layers").unwrap_or(0),
        t0.elapsed().as_secs_f64()
    );

    println!(
        "training for {rounds} rounds across {} nodes (s={}, a={})...",
        spec.population.nodes, spec.protocol.s, spec.protocol.a
    );
    let wall = Instant::now();
    let (metrics, _) = run_scenario(&spec, Some(&runtime), ChurnSchedule::empty())?;
    let wall_s = wall.elapsed().as_secs_f64();

    println!("\nloss curve (token-level NLL on held-out sequences):");
    for p in &metrics.curve {
        println!(
            "  t={:>7.0}s round={:>5} token-acc={:.4} loss={:.4}",
            p.time_s, p.round, p.metric, p.loss
        );
    }

    let first = metrics.curve.first().expect("curve");
    let last = metrics.curve.last().expect("curve");
    println!("\nsummary:");
    println!(
        "  loss {:.4} -> {:.4} over {} rounds ({:.0}s virtual, {:.1}s wallclock)",
        first.loss, last.loss, metrics.final_round, metrics.duration_s, wall_s
    );
    println!(
        "  token accuracy {:.4} -> {:.4} (chance = {:.4})",
        first.metric,
        last.metric,
        1.0 / vm.meta_usize("vocab").unwrap_or(64) as f64
    );
    let t = &metrics.traffic;
    println!(
        "  traffic total={} max-node={} overhead={:.1}%",
        fmt_bytes(t.total),
        fmt_bytes(t.max_node),
        100.0 * t.overhead_fraction
    );
    anyhow::ensure!(
        last.loss < first.loss * 0.8,
        "end-to-end training failed to reduce loss meaningfully"
    );
    println!("\nEND-TO-END OK: all three layers compose.");
    Ok(())
}
