//! Quickstart: train a model with MoDeST on a small simulated WAN.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Builds a 20-node network over the synthetic latency matrix, runs the
//! MoDeST protocol (s=10 trainers, a=3 aggregators per round) on the
//! CelebA-sized classifier, and prints the convergence curve plus the
//! per-node traffic summary.

use anyhow::Result;

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::sim::ChurnSchedule;

fn main() -> Result<()> {
    let spec = SessionSpec {
        dataset: "celeba".into(),
        algo: Algo::Modest,
        nodes: 20,
        s: 10,
        a: 3,
        sf: 1.0,
        max_rounds: 30,
        max_time_s: 600.0,
        eval_interval_s: 5.0,
        ..Default::default()
    };

    println!("loading AOT artifacts (run `make artifacts` first)...");
    let runtime = XlaRuntime::load(&spec.artifacts_dir)?;
    let session = spec.build_modest(Some(&runtime), ChurnSchedule::empty())?;

    println!(
        "running MoDeST: n={} s={} a={} sf={}",
        spec.resolved_nodes()?,
        spec.s,
        spec.a,
        spec.sf
    );
    let (metrics, traffic) = session.run();

    println!("\nconvergence curve (virtual time):");
    for p in &metrics.curve {
        let bar_len = (p.metric * 40.0) as usize;
        println!(
            "  t={:>6.0}s round={:>4} acc={:.3} loss={:.3} {}",
            p.time_s,
            p.round,
            p.metric,
            p.loss,
            "#".repeat(bar_len)
        );
    }

    let t = &metrics.traffic;
    println!("\nnetwork usage:");
    println!("  total     {}", fmt_bytes(t.total));
    println!("  min node  {}", fmt_bytes(t.min_node));
    println!("  max node  {}", fmt_bytes(t.max_node));
    println!(
        "  overhead  {} ({:.1}% of total)",
        fmt_bytes(t.overhead),
        100.0 * t.overhead_fraction
    );
    println!("  conserved {}", traffic.is_conserved());
    println!(
        "\nreached round {} in {:.0}s virtual / {} DES events",
        metrics.final_round, metrics.duration_s, metrics.events
    );
    Ok(())
}
