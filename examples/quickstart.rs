//! Quickstart: train a model with MoDeST on a small simulated WAN.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Describes a 20-node network as a [`ScenarioSpec`], runs the MoDeST
//! protocol (s=10 trainers, a=3 aggregators per round) on the CelebA-sized
//! classifier through the scenario registry, and prints the convergence
//! curve plus the per-node traffic summary.

use anyhow::Result;

use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;

fn main() -> Result<()> {
    let mut spec = ScenarioSpec::new("celeba", "modest");
    spec.population.nodes = 20;
    spec.protocol.s = 10;
    spec.protocol.a = 3;
    spec.protocol.sf = 1.0;
    spec.run.max_rounds = 30;
    spec.run.max_time_s = 600.0;
    spec.run.eval_interval_s = 5.0;

    println!("loading AOT artifacts (run `make artifacts` first)...");
    let runtime = XlaRuntime::load(&spec.workload.artifacts_dir)?;

    println!(
        "running MoDeST: n={} s={} a={} sf={}",
        spec.resolved_nodes()?,
        spec.protocol.s,
        spec.protocol.a,
        spec.protocol.sf
    );
    let (metrics, traffic) = run_scenario(&spec, Some(&runtime), ChurnSchedule::empty())?;

    println!("\nconvergence curve (virtual time):");
    for p in &metrics.curve {
        let bar_len = (p.metric * 40.0) as usize;
        println!(
            "  t={:>6.0}s round={:>4} acc={:.3} loss={:.3} {}",
            p.time_s,
            p.round,
            p.metric,
            p.loss,
            "#".repeat(bar_len)
        );
    }

    let t = &metrics.traffic;
    println!("\nnetwork usage:");
    println!("  total     {}", fmt_bytes(t.total));
    println!("  min node  {}", fmt_bytes(t.min_node));
    println!("  max node  {}", fmt_bytes(t.max_node));
    println!(
        "  overhead  {} ({:.1}% of total)",
        fmt_bytes(t.overhead),
        100.0 * t.overhead_fraction
    );
    println!("  conserved {}", traffic.is_conserved());
    println!(
        "\nreached round {} in {:.0}s virtual / {} DES events",
        metrics.final_round, metrics.duration_s, metrics.events
    );
    Ok(())
}
