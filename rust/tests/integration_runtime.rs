//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need the `xla` cargo feature plus `make artifacts` to have run
//! (they are skipped with a clear message otherwise, so `cargo test` works
//! on a fresh checkout too).
#![cfg(feature = "xla")]

use modest_dl::learning::{Task, TaskData, XlaTask};
use modest_dl::runtime::{Batch, XlaRuntime};
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, SimRng};

fn runtime() -> Option<XlaRuntime> {
    match XlaRuntime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime integration test (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_five_variants() {
    let Some(rt) = runtime() else { return };
    let names: Vec<&String> = rt.manifest().variants.keys().collect();
    for expect in ["cifar10", "celeba", "femnist", "movielens", "transformer"] {
        assert!(names.iter().any(|n| n.as_str() == expect), "{names:?}");
    }
}

#[test]
fn train_step_executes_and_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let v = rt.variant("celeba").expect("compile celeba");
    let m = &v.manifest;
    let mut rng = SimRng::new(7);
    let b = m.train_batch;
    let dim = m.train_x.shape[1];
    let x: Vec<f32> = (0..b * dim).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.gen_range(2) as i32).collect();
    let batch = Batch::F32I32 { x, y };

    let mut params = v.init_params();
    let mut vel = vec![0f32; params.len()];
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..6 {
        let out = v.train_step(&params, &vel, &batch, m.lr, m.momentum).unwrap();
        params = out.params;
        vel = out.velocity;
        last = out.loss;
        first.get_or_insert(out.loss);
    }
    let first = first.unwrap();
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(params.iter().all(|p| p.is_finite()));
}

#[test]
fn eval_metric_sums_are_bounded() {
    let Some(rt) = runtime() else { return };
    let v = rt.variant("celeba").expect("compile");
    let m = &v.manifest;
    let mut rng = SimRng::new(8);
    let b = m.eval_batch;
    let dim = m.eval_x.shape[1];
    let x: Vec<f32> = (0..b * dim).map(|_| rng.next_gaussian() as f32).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.gen_range(2) as i32).collect();
    let out = v.eval_batch(&v.init_params(), &Batch::F32I32 { x, y }).unwrap();
    assert!(out.metric_sum >= 0.0 && out.metric_sum <= b as f32);
    assert!(out.loss_sum.is_finite());
}

#[test]
fn xla_aggregate_matches_native_mean() {
    let Some(rt) = runtime() else { return };
    let v = rt.variant("celeba").expect("compile");
    let mut rng = SimRng::new(9);
    let p = v.param_count();
    let models: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..p).map(|_| rng.next_gaussian() as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let got = v.aggregate(&refs).unwrap();
    let model_refs: Vec<&Vec<f32>> = models.iter().collect();
    let want = modest_dl::learning::aggregate_native(&model_refs);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-5, "idx {i}: {g} vs {w}");
    }
}

#[test]
fn init_params_match_manifest_hash_length() {
    let Some(rt) = runtime() else { return };
    for name in ["cifar10", "celeba"] {
        let v = rt.variant(name).unwrap();
        assert_eq!(v.init_params().len(), v.manifest.param_count);
    }
}

#[test]
fn xla_task_local_update_runs_one_epoch() {
    let Some(rt) = runtime() else { return };
    let mut spec = ScenarioSpec::new("celeba", "modest");
    spec.population.nodes = 10;
    let mut task = spec.build_task(Some(&rt)).unwrap();
    let model = task.init_model();
    let (updated, loss, batches) = task.local_update(&model, 3, 42).unwrap();
    assert_eq!(updated.len(), model.len());
    assert!(loss.is_finite());
    // 60 samples per node, batch 20 -> 3 batches.
    assert_eq!(batches, 3);
    assert_ne!(updated, model);

    // Deterministic per (node, seed).
    let (again, _, _) = task.local_update(&model, 3, 42).unwrap();
    assert_eq!(updated, again);
    let (other, _, _) = task.local_update(&model, 3, 43).unwrap();
    assert_ne!(updated, other);
}

#[test]
fn xla_task_evaluate_improves_with_training() {
    let Some(rt) = runtime() else { return };
    let mut spec = ScenarioSpec::new("celeba", "modest");
    spec.population.nodes = 10;
    let mut task = spec.build_task(Some(&rt)).unwrap();
    let mut model = task.init_model();
    let before = task.evaluate(&model).unwrap();
    for round in 0..6 {
        // mini-FedAvg over 4 nodes
        let mut locals = Vec::new();
        for node in 0..4u32 {
            locals.push(task.local_update(&model, node, round * 10 + node as u64).unwrap().0);
        }
        let refs: Vec<&Vec<f32>> = locals.iter().collect();
        model = task.aggregate(&refs).unwrap();
    }
    let after = task.evaluate(&model).unwrap();
    assert!(
        after.metric > before.metric,
        "accuracy {} -> {} did not improve",
        before.metric,
        after.metric
    );
    assert!(after.loss < before.loss);
}

#[test]
fn full_modest_session_on_real_celeba_artifacts() {
    let Some(rt) = runtime() else { return };
    let mut spec = ScenarioSpec::new("celeba", "modest");
    spec.population.nodes = 12;
    spec.protocol.s = 4;
    spec.protocol.a = 2;
    spec.protocol.sf = 1.0;
    spec.run.max_time_s = 400.0;
    spec.run.max_rounds = 12;
    spec.run.eval_interval_s = 10.0;
    let (m, traffic) = run_scenario(&spec, Some(&rt), ChurnSchedule::empty()).unwrap();
    assert!(m.final_round >= 8, "only reached round {}", m.final_round);
    assert!(traffic.is_conserved());
    let first = m.curve.first().unwrap().metric;
    let best = m.best_metric(true).unwrap();
    assert!(best > first, "no learning progress: {first} -> {best}");
}

#[test]
fn xla_task_kind_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let mut rng = SimRng::new(1);
    let data = modest_dl::data::TokensData::generate(
        &modest_dl::data::tokens::TokensParams {
            nodes: 2,
            seqs_per_node: 2,
            test_seqs: 2,
            ..Default::default()
        },
        &mut rng,
    );
    assert!(XlaTask::new(&rt, "celeba", TaskData::Tokens(data)).is_err());
}
