//! Protocol-behaviour integration tests: the paper's qualitative claims,
//! checked end-to-end on the mock task (fast, artifact-free) through the
//! scenario registry.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;

fn spec(protocol: &str, s: usize, a: usize, sf: f64) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new("mock", protocol);
    spec.population.nodes = 20;
    spec.protocol.s = s;
    spec.protocol.a = a;
    spec.protocol.sf = sf;
    spec.run.max_time_s = 600.0;
    spec.run.max_rounds = 50;
    spec.run.eval_interval_s = 5.0;
    spec
}

fn run(spec: &ScenarioSpec) -> (SessionMetrics, TrafficLedger) {
    run_scenario(spec, None, ChurnSchedule::empty()).unwrap()
}

#[test]
fn modest_converges_like_fedavg_better_than_dsgd() {
    // The headline Fig. 3 ordering on the mock task.
    let (m_md, _) = run(&spec("modest", 6, 3, 1.0));
    let (m_fl, _) = run(&spec("fedavg", 6, 1, 1.0));
    let (m_dl, _) = run(&spec("dsgd", 0, 0, 1.0));
    let best = |m: &SessionMetrics| m.best_metric(true).unwrap_or(0.0);
    assert!(
        best(&m_md) > 0.85 * best(&m_fl),
        "MoDeST {} far below FedAvg {}",
        best(&m_md),
        best(&m_fl)
    );
    assert!(
        best(&m_md) > best(&m_dl),
        "MoDeST {} !> D-SGD {}",
        best(&m_md),
        best(&m_dl)
    );
}

#[test]
fn gossip_learns_but_lags_modest() {
    // The new registry-added protocol: epidemic averaging makes progress,
    // but without aggregators it keeps residual replica variance, so it
    // must not beat MoDeST's aggregated model.
    let (m_md, _) = run(&spec("modest", 6, 3, 1.0));
    let (m_gp, _) = run(&spec("gossip", 0, 0, 1.0));
    let best = |m: &SessionMetrics| m.best_metric(true).unwrap_or(0.0);
    assert!(best(&m_gp) > 0.4, "gossip never learned: {}", best(&m_gp));
    assert!(
        best(&m_md) >= 0.95 * best(&m_gp),
        "MoDeST {} unexpectedly far below gossip {}",
        best(&m_md),
        best(&m_gp)
    );
}

#[test]
fn more_aggregators_do_not_change_rounds_needed() {
    // §4.5: rounds-to-accuracy is indifferent to `a` when sf = 1 (same
    // aggregated model from every aggregator).
    let (m_a1, _) = run(&spec("modest", 6, 1, 1.0));
    let (m_a4, _) = run(&spec("modest", 6, 4, 1.0));
    let target = 0.85;
    let r1 = m_a1.time_to_target(target, true).map(|(_, r)| r);
    let r4 = m_a4.time_to_target(target, true).map(|(_, r)| r);
    if let (Some(r1), Some(r4)) = (r1, r4) {
        let lo = r1.min(r4) as f64;
        let hi = r1.max(r4) as f64;
        assert!(hi / lo < 1.8, "rounds diverge too much: {r1} vs {r4}");
    }
}

#[test]
fn larger_sample_lowers_rounds_to_target() {
    // Fig. 4 right panel: rounds-to-target decreases with s.
    let (m_s2, _) = run(&spec("modest", 2, 2, 1.0));
    let (m_s10, _) = run(&spec("modest", 10, 2, 1.0));
    let target = 0.8;
    let r2 = m_s2.time_to_target(target, true).map(|(_, r)| r).unwrap_or(u64::MAX);
    let r10 = m_s10.time_to_target(target, true).map(|(_, r)| r).unwrap_or(u64::MAX);
    assert!(r10 <= r2, "s=10 needed {r10} rounds, s=2 needed {r2}");
}

#[test]
fn sf_below_one_tolerates_failures() {
    // With sf < 1 and extra aggregators, a crash wave must not stall the
    // session (paper §3.2 fault-tolerance design).
    let churn = modest_dl::sim::ChurnSchedule::mass_crash(
        20,
        14,
        2,
        modest_dl::sim::SimTime::from_secs_f64(50.0),
        modest_dl::sim::SimTime::from_secs_f64(25.0),
    );
    let mut sp = spec("modest", 6, 3, 0.67);
    sp.run.max_rounds = 0;
    sp.run.max_time_s = 500.0;
    let (m, _) = run_scenario(&sp, None, churn).unwrap();
    let last_round_start = m.round_starts.last().map(|(_, t)| t).unwrap_or(0.0);
    assert!(
        last_round_start > 200.0,
        "stalled at t={last_round_start} (final round {})",
        m.final_round
    );
}

#[test]
fn view_overhead_is_counted_but_small() {
    let (m, _) = run(&spec("modest", 6, 3, 1.0));
    let t = &m.traffic;
    assert!(t.overhead > 0, "views/pings must produce overhead");
    // Mock model is tiny (32 f32), so overhead fraction is large here; the
    // invariant is just that accounting splits the classes.
    assert!(t.overhead < t.total);
}

#[test]
fn round_times_are_plausible() {
    let (m, _) = run(&spec("modest", 6, 3, 1.0));
    let mean = m.mean_round_time_s().expect("round times");
    // A round = ping wave + model push + training (0.05s/batch x 5) +
    // aggregation: it cannot be faster than training alone, nor slower
    // than a few timeouts.
    assert!(mean > 0.3, "mean round {mean}s too fast");
    assert!(mean < 20.0, "mean round {mean}s too slow");
}

#[test]
fn fedavg_single_aggregator_is_the_latency_hub() {
    let (_, t) = run(&spec("fedavg", 6, 1, 1.0));
    // The best-connected node carries ~50% of total traffic (Table 4's
    // "Max. vs Total" observation).
    let (_, max) = t.min_max_usage(20);
    let frac = max as f64 / t.total().max(1) as f64;
    assert!(frac > 0.35, "server carries only {frac:.2} of traffic");
}
