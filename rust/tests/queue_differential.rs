//! Differential tests pinning the calendar event queue to the BinaryHeap
//! shim: identical pop order — `(time, insertion seq)` — under randomized
//! schedules, including past-clamping, tie bursts, window-crossing jumps,
//! and interleaved push/pop (the live-session access pattern). Also
//! session-level fingerprint equivalence: the Arc-payload refactor and the
//! queue swap must leave same-seed `SessionMetrics` bit-identical.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{run_scenario, ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::{CalendarEventQueue, ChurnSchedule, HeapEventQueue, SimRng, SimTime};

/// Drive both backends through one interleaved push/pop script and assert
/// every observable matches step-by-step.
fn differential(seed: u64, ops: usize, spread_us: u64, tie_every: u64) {
    let mut rng = SimRng::new(seed);
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    let mut label = 0u64;
    for step in 0..ops {
        let roll = rng.gen_range(100);
        if roll < 60 {
            // Push: mostly near-future, sometimes far jumps, sometimes the
            // past (exercises the clamp), sometimes exact-tie bursts.
            let at = if tie_every > 0 && (step as u64) % tie_every == 0 {
                cal.now() + SimTime::from_micros(17)
            } else if roll < 5 {
                // "In the past": clamped to now by both backends.
                SimTime::from_micros(cal.now().0 / 2)
            } else if roll < 10 {
                // Far beyond any near window.
                cal.now() + SimTime::from_micros(spread_us * 4096)
            } else {
                cal.now() + SimTime::from_micros(rng.gen_range(spread_us.max(1)))
            };
            cal.schedule_at(at, label);
            heap.schedule_at(at, label);
            label += 1;
        } else {
            let a = cal.pop();
            let b = heap.pop();
            match (a, b) {
                (None, None) => {}
                (Some((ta, va)), Some((tb, vb))) => {
                    assert_eq!(ta, tb, "time diverged at step {step} (seed {seed})");
                    assert_eq!(va, vb, "order diverged at step {step} (seed {seed})");
                }
                (a, b) => panic!("emptiness diverged at step {step}: {a:?} vs {b:?}"),
            }
        }
        assert_eq!(cal.len(), heap.len(), "len diverged at step {step}");
        assert_eq!(cal.now(), heap.now(), "clock diverged at step {step}");
    }
    // Drain both completely: the tails must agree too.
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (Some((ta, va)), Some((tb, vb))) => {
                assert_eq!((ta, va), (tb, vb), "tail diverged (seed {seed})");
            }
            (a, b) => panic!("tail emptiness diverged: {a:?} vs {b:?}"),
        }
    }
    assert_eq!(cal.events_processed(), heap.events_processed());
}

#[test]
fn calendar_matches_heap_on_dense_microsecond_schedules() {
    differential(1, 20_000, 50, 0);
}

#[test]
fn calendar_matches_heap_on_sparse_wan_scale_schedules() {
    // Millisecond-to-second gaps: crosses many bucket windows.
    differential(2, 20_000, 2_000_000, 0);
}

#[test]
fn calendar_matches_heap_under_tie_bursts_and_past_clamping() {
    differential(3, 20_000, 10_000, 3);
}

#[test]
fn calendar_matches_heap_across_many_seeds() {
    for seed in 10..30 {
        differential(seed, 3_000, 1 + seed * 997, if seed % 3 == 0 { 5 } else { 0 });
    }
}

#[test]
fn calendar_matches_heap_on_dense_traffic_after_an_idle_stretch() {
    // Probe-only 10s gaps inflate the internal gap estimate; dense µs-scale
    // traffic then returns (a churn recovery). The calendar queue must both
    // stay order-identical to the heap AND re-derive a fine bucket width
    // (the rebalance path) instead of degrading to one giant bucket.
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    for i in 0..20u64 {
        let t = SimTime::from_micros((i + 1) * 10_000_000);
        cal.schedule_at(t, i);
        heap.schedule_at(t, i);
    }
    for _ in 0..19 {
        assert_eq!(cal.pop(), heap.pop());
    }
    let mut rng = SimRng::new(3);
    for i in 0..5_000u64 {
        let at = cal.now() + SimTime::from_micros(rng.gen_range(2_000));
        cal.schedule_at(at, 1_000 + i);
        heap.schedule_at(at, 1_000 + i);
    }
    for i in 0..100_000u64 {
        let a = cal.pop().expect("cal under-filled");
        let b = heap.pop().expect("heap under-filled");
        assert_eq!(a, b, "diverged at hold iteration {i}");
        let at = a.0 + SimTime::from_micros(1 + rng.gen_range(2_000));
        cal.schedule_at(at, 10_000 + i);
        heap.schedule_at(at, 10_000 + i);
    }
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (a, b) => assert_eq!(a, b),
        }
    }
}

#[test]
fn calendar_matches_heap_when_a_rebalance_grows_the_window_over_far_events() {
    // A far-heap event sits just past the initial window; a burst then
    // forces a rebalance whose new width can ENLARGE the window past that
    // event. The rebalance must pull it into the buckets, or a later near
    // push would pop ahead of it.
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    let mut label = 0u64;
    cal.schedule_at(SimTime::from_micros(600_000), label);
    heap.schedule_at(SimTime::from_micros(600_000), label);
    label += 1;
    let mut rng = SimRng::new(11);
    for _ in 0..1_500 {
        let at = SimTime::from_micros(rng.gen_range(520_000));
        cal.schedule_at(at, label);
        heap.schedule_at(at, label);
        label += 1;
    }
    for _ in 0..10 {
        assert_eq!(cal.pop(), heap.pop());
    }
    for _ in 0..600 {
        let at = cal.now() + SimTime::from_micros(5);
        cal.schedule_at(at, label);
        heap.schedule_at(at, label);
        label += 1;
    }
    cal.schedule_at(SimTime::from_micros(700_000), label);
    heap.schedule_at(SimTime::from_micros(700_000), label);
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (a, b) => assert_eq!(a, b),
        }
    }
}

#[test]
fn batch_push_then_drain_is_fully_sorted() {
    // The harness bootstrap pattern: churn script + every probe tick pushed
    // up front, then the session drains. The calendar queue must hand back
    // a perfect (time, seq) sort through all its window re-anchors.
    let mut rng = SimRng::new(77);
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    for i in 0..50_000u64 {
        let at = SimTime::from_micros(rng.gen_range(3_600_000_000));
        cal.schedule_at(at, i);
        heap.schedule_at(at, i);
    }
    let a: Vec<(SimTime, u64)> = std::iter::from_fn(|| cal.pop()).collect();
    let b: Vec<(SimTime, u64)> = std::iter::from_fn(|| heap.pop()).collect();
    assert_eq!(a.len(), 50_000);
    assert_eq!(a, b);
}

#[test]
fn slab_reuse_storms_keep_backends_in_lockstep() {
    // The arena-allocator stress: waves of pushes alternating with full
    // and half drains, so event slots are freed and recycled thousands of
    // times. Recycled slots must never leak a stale (time, seq) — pop
    // order stays bit-identical to the heap shim through every wave.
    let mut rng = SimRng::new(0x51ab);
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    let mut label = 0u64;
    for wave in 0..40u64 {
        let width = 100 + (wave * 137) % 1_900;
        for _ in 0..width {
            let at = cal.now() + SimTime::from_micros(1 + rng.gen_range(50_000));
            cal.schedule_at(at, label);
            heap.schedule_at(at, label);
            label += 1;
        }
        // Odd waves drain fully (arena empties, free list holds every
        // slot); even waves drain half (live and recycled slots mix).
        let drain = if wave % 2 == 1 { cal.len() } else { cal.len() / 2 };
        for i in 0..drain {
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b, "wave {wave} pop {i} diverged");
            assert!(a.is_some(), "wave {wave} under-filled at pop {i}");
        }
        assert_eq!(cal.len(), heap.len(), "wave {wave} len diverged");
    }
    loop {
        match (cal.pop(), heap.pop()) {
            (None, None) => break,
            (a, b) => assert_eq!(a, b, "tail diverged"),
        }
    }
    assert_eq!(cal.events_processed(), heap.events_processed());
}

#[test]
fn slab_reuse_bounds_arena_growth_to_peak_live() {
    // Forty full fill/drain cycles push 40x more events than are ever
    // live at once. The free list must recycle slots: the arena ends no
    // larger than the peak resident set, on both backends.
    let mut rng = SimRng::new(0xa3e4);
    let mut cal = CalendarEventQueue::new();
    let mut heap = HeapEventQueue::new();
    let mut label = 0u64;
    let mut peak = 0usize;
    for _ in 0..40u64 {
        for _ in 0..2_000 {
            let at = cal.now() + SimTime::from_micros(1 + rng.gen_range(10_000));
            cal.schedule_at(at, label);
            heap.schedule_at(at, label);
            label += 1;
        }
        peak = peak.max(cal.len());
        while let Some(a) = cal.pop() {
            assert_eq!(Some(a), heap.pop());
        }
        assert_eq!(heap.pop(), None);
    }
    assert!(
        cal.arena_capacity() <= peak,
        "calendar arena grew past peak live: {} > {peak}",
        cal.arena_capacity()
    );
    assert!(
        heap.arena_capacity() <= peak,
        "heap arena grew past peak live: {} > {peak}",
        heap.arena_capacity()
    );
}

// ------------------------------------------------------------ fingerprints

fn fingerprint(m: &SessionMetrics, t: &TrafficLedger) -> (u64, u64, Vec<(u64, u64)>, u64) {
    (
        m.final_round,
        m.events,
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect(),
        t.total(),
    )
}

fn smoke_spec(protocol: &str) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new("mock", protocol);
    spec.population.nodes = 14;
    spec.protocol.s = 4;
    spec.protocol.a = 2;
    spec.run.max_time_s = 150.0;
    spec.run.max_rounds = 18;
    spec.run.eval_interval_s = 10.0;
    spec.run.seed = 4242;
    spec
}

/// Same-seed fingerprint equivalence across the zero-copy refactor: every
/// protocol's smoke scenario must replay bit-identically run-over-run (the
/// Arc payload sharing and the calendar queue may not perturb a single
/// event, metric bit, or ledger byte). Run with
/// `--features queue-heap` to cross-check the same fingerprints on the
/// heap backend — CI exercises both.
#[test]
fn every_protocol_smoke_fingerprint_is_reproducible() {
    for name in ProtocolRegistry::builtins().names() {
        let spec = smoke_spec(name);
        let (m1, t1) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        let (m2, t2) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        assert!(m1.events > 0 && t1.total() > 0, "{name} did nothing");
        assert_eq!(
            fingerprint(&m1, &t1),
            fingerprint(&m2, &t2),
            "{name} same-seed fingerprint diverged"
        );
    }
}
