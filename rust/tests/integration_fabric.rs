//! Integration tests for the network fabric + harness refactor: uniform
//! capacity reproduces the seed behaviour, heterogeneous/thin uplinks
//! measurably stretch rounds, and full sessions stay deterministic and
//! byte-conserving on the shared `SimHarness`.

use modest_dl::baselines::{DsgdConfig, DsgdSession};
use modest_dl::learning::{ComputeModel, MockTask};
use modest_dl::modest::{ModestConfig, ModestSession};
use modest_dl::net::{BandwidthConfig, LatencyMatrix, LatencyParams, NetworkFabric};
use modest_dl::sim::{ChurnSchedule, SimRng, SimTime};

const SEED: u64 = 42;

fn fabric_with(n: usize, bw: &BandwidthConfig) -> NetworkFabric {
    let mut rng = SimRng::new(SEED);
    let latency = LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
    NetworkFabric::new(latency, bw, n, &mut rng.fork("bw"))
}

fn modest_session(n: usize, bw: &BandwidthConfig) -> ModestSession {
    let cfg = ModestConfig {
        s: 4,
        a: 2,
        sf: 1.0,
        max_time: SimTime::from_secs_f64(400.0),
        max_rounds: 40,
        eval_interval: SimTime::from_secs_f64(5.0),
        seed: SEED,
        ..Default::default()
    };
    let task = MockTask::new(n, 16, 0.5, SEED);
    let compute = ComputeModel::uniform(n, 0.05);
    ModestSession::new(cfg, n, Box::new(task), compute, fabric_with(n, bw), ChurnSchedule::empty())
}

fn dsgd_session(n: usize, bw: &BandwidthConfig) -> DsgdSession {
    let cfg = DsgdConfig {
        max_time: SimTime::from_secs_f64(400.0),
        max_rounds: 30,
        eval_interval: SimTime::from_secs_f64(5.0),
        seed: SEED,
        ..Default::default()
    };
    let task = MockTask::new(n, 16, 0.5, SEED);
    let compute = ComputeModel::uniform(n, 0.05);
    DsgdSession::new(cfg, n, Box::new(task), compute, fabric_with(n, bw), ChurnSchedule::empty())
}

/// Acceptance: a fast uniform fabric vs one with 10x-thinner uplinks —
/// the thin uplinks must measurably lengthen round duration, because the
/// fabric serializes each aggregator's `s` model pushes on its uplink.
#[test]
fn thin_uplinks_lengthen_rounds() {
    // Capacities low enough that model transfers are on the round's
    // critical path for the mock task (~900-byte train/aggregate messages).
    let fast = BandwidthConfig::Uniform { bps: 400_000.0 };
    // Same downlinks, uplinks 10x thinner.
    let thin = BandwidthConfig::PerNode {
        up_bps: vec![40_000.0; 16],
        down_bps: vec![400_000.0; 16],
    };
    let (m_fast, _) = modest_session(16, &fast).run();
    let (m_thin, _) = modest_session(16, &thin).run();
    let rt_fast = m_fast.mean_round_time_s().expect("fast rounds");
    let rt_thin = m_thin.mean_round_time_s().expect("thin rounds");
    assert!(
        rt_thin > 1.15 * rt_fast,
        "10x-thinner uplinks did not stretch rounds: fast {rt_fast:.3}s vs thin {rt_thin:.3}s"
    );
}

/// Acceptance: the uniform default capacity reproduces the seed session's
/// qualitative metrics (rounds made, convergence, byte conservation).
#[test]
fn uniform_fabric_reproduces_seed_equivalent_metrics() {
    let bw = BandwidthConfig::uniform_mbps(50.0);
    let (m, traffic) = modest_session(16, &bw).run();
    assert!(m.final_round >= 20, "only reached round {}", m.final_round);
    assert!(m.best_metric(true).unwrap() > 0.8, "best {:?}", m.best_metric(true));
    assert!(traffic.is_conserved());
    assert!(traffic.total() > 0);
    // At 50 Mbit/s the mock task's transfers are microseconds: contention
    // must not distort sampling (seed invariant: one ping wave << Δt).
    for s in &m.samples {
        assert!(s.duration_s < 2.0, "sample took {}s", s.duration_s);
    }

    let (m_dl, t_dl) = dsgd_session(8, &bw).run();
    assert!(m_dl.final_round >= 25, "dsgd round {}", m_dl.final_round);
    assert!(t_dl.is_conserved());
}

/// Two `SimHarness` runs with the same seed produce identical
/// `SessionMetrics` — for both MoDeST and D-SGD, on a heterogeneous fabric.
#[test]
fn harness_runs_are_deterministic_for_both_protocols() {
    let bw = BandwidthConfig::LogNormal { median_bps: 5e6, sigma: 0.5 };

    let fingerprint_md = || {
        let (m, t) = modest_session(12, &bw).run();
        (
            m.final_round,
            m.events,
            m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect::<Vec<_>>(),
            m.round_starts.clone(),
            m.samples.len(),
            t.total(),
            t.messages(),
        )
    };
    assert_eq!(fingerprint_md(), fingerprint_md(), "MoDeST not deterministic");

    let fingerprint_dl = || {
        let (m, t) = dsgd_session(8, &bw).run();
        (
            m.final_round,
            m.events,
            m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect::<Vec<_>>(),
            m.round_starts.clone(),
            t.total(),
            t.messages(),
        )
    };
    assert_eq!(fingerprint_dl(), fingerprint_dl(), "D-SGD not deterministic");
}

/// The FedAvg emulation's server override survives the fabric refactor:
/// traffic still concentrates on the server, and thin *client* uplinks do
/// not deadlock the star topology.
#[test]
fn fedavg_server_override_on_thin_fabric() {
    let n = 12;
    let cfg = ModestConfig {
        s: 4,
        a: 1,
        sf: 1.0,
        fedavg_server: Some(0),
        max_time: SimTime::from_secs_f64(400.0),
        max_rounds: 20,
        seed: SEED,
        ..Default::default()
    };
    let task = MockTask::new(n, 16, 0.5, SEED);
    let compute = ComputeModel::uniform(n, 0.05);
    let bw = BandwidthConfig::Uniform { bps: 200_000.0 };
    let session =
        ModestSession::new(cfg, n, Box::new(task), compute, fabric_with(n, &bw), ChurnSchedule::empty());
    let (m, traffic) = session.run();
    assert!(m.final_round >= 8, "round {}", m.final_round);
    let server = traffic.node_usage(0);
    let max_other = (1..n as u32).map(|i| traffic.node_usage(i)).max().unwrap();
    assert!(server > 2 * max_other, "server {server} vs {max_other}");
}
