//! Fault-injection termination suite: every registered protocol must run
//! to completion — no deadlock, no livelock, bounded event count — under
//! fully lossy links, a timed total blackout, and 20% burst loss combined
//! with diurnal churn. Also pinned here: a `network.loss` section with all
//! drop probabilities at zero compiles away entirely, so same-seed
//! fingerprints replay bit-identically against the absent-section run.
//! Runs under both queue backends via the CI feature matrix
//! (`--features queue-heap` swaps the backend under the same test body).

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{run_scenario, ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;

fn fingerprint(m: &SessionMetrics, t: &TrafficLedger) -> (u64, u64, Vec<(u64, u64)>, u64) {
    (
        m.final_round,
        m.events,
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect(),
        t.total(),
    )
}

/// Any session that terminates must do so within the spec's clock budget
/// and without an exploding event count — the livelock guard (a retransmit
/// storm that never converges would blow through this long before the
/// wall-clock test timeout). The harness stops on the first event *past*
/// `max_time`, so the clock check allows one event gap (the longest timer
/// in play is a backstop of a few tens of seconds).
fn assert_bounded(name: &str, m: &SessionMetrics, max_time_s: f64) {
    assert!(
        m.duration_s <= max_time_s + 60.0,
        "{name}: session overran the clock budget ({} > {max_time_s}s)",
        m.duration_s
    );
    assert!(m.events < 5_000_000, "{name}: event explosion ({} events)", m.events);
}

/// The smoke population with a parameterized `network` section (pass an
/// empty string for none) and optional `availability` churn.
fn spec(protocol: &str, network: &str, availability: &str, max_time_s: f64) -> ScenarioSpec {
    let network = if network.is_empty() {
        String::new()
    } else {
        format!(r#""network": {network},"#)
    };
    ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 16{availability}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            {network}
            "run": {{"max_time_s": {max_time_s}, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap()
}

/// A lossless `loss` section must compile to *nothing*: no loss layer, no
/// reliability outboxes, no extra RNG stream — so the fingerprint matches
/// the absent-section run bit-for-bit. This is the guarantee that lets the
/// section ship without perturbing any recorded baseline.
#[test]
fn zero_loss_section_replays_absent_section_fingerprints() {
    for name in ProtocolRegistry::builtins().names() {
        let absent = spec(name, "", "", 150.0);
        let (m0, t0) = run_scenario(&absent, None, ChurnSchedule::empty()).unwrap();
        assert!(m0.events > 0 && t0.total() > 0, "{name} did nothing");
        let want = fingerprint(&m0, &t0);
        for (tag, section) in [
            ("uniform p=0", r#"{"loss": {"model": "uniform", "p": 0.0}}"#),
            (
                "burst p=0",
                r#"{"loss": {"model": "burst", "p_good": 0.0, "p_bad": 0.0,
                             "good_s": 10.0, "bad_s": 1.0}}"#,
            ),
        ] {
            let lossless = spec(name, section, "", 150.0);
            let (m1, t1) = run_scenario(&lossless, None, ChurnSchedule::empty()).unwrap();
            assert_eq!(
                fingerprint(&m1, &t1),
                want,
                "{name}: lossless section ({tag}) perturbed the fingerprint"
            );
            assert_eq!(t1.dropped_bytes(), 0);
            assert_eq!(t1.retransmitted_bytes(), 0);
        }
    }
}

/// 20% average burst loss (Gilbert–Elliott: ~5% in the good state, 50% in
/// the bad) on top of diurnal churn — the hostile-edge scenario the paper
/// premises. Every protocol's degradation path must keep the session
/// moving: retransmits happen, some expire, and the run still terminates
/// with work done. Same seed, same fault schedule: bit-identical replay.
#[test]
fn burst_loss_with_diurnal_churn_completes_for_every_protocol() {
    let section = r#"{"loss": {
        "model": "burst", "p_good": 0.05, "p_bad": 0.5,
        "good_s": 15.0, "bad_s": 7.5,
        "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0, "retries": 2}}"#;
    let avail = r#", "availability": {
        "model": "diurnal", "amplitude": 0.3, "period_s": 50.0, "seed": 5}"#;
    for name in ProtocolRegistry::builtins().names() {
        let s = spec(name, section, avail, 600.0);
        let (m1, t1) = run_scenario(&s, None, ChurnSchedule::empty()).unwrap();
        assert_bounded(name, &m1, 600.0);
        assert!(m1.final_round >= 1, "{name}: no round survived 20% burst loss + churn");
        assert!(t1.dropped_bytes() > 0, "{name}: burst loss dropped nothing");
        assert!(t1.retransmitted_bytes() > 0, "{name}: loss triggered no retransmits");
        assert!(t1.goodput() < t1.total(), "{name}: goodput must exclude loss overhead");
        assert!(t1.is_conserved(), "{name}: attempt accounting leaked bytes");
        let (m2, t2) = run_scenario(&s, None, ChurnSchedule::empty()).unwrap();
        assert_eq!(
            fingerprint(&m1, &t1),
            fingerprint(&m2, &t2),
            "{name}: lossy same-seed fingerprint diverged"
        );
        assert_eq!(t1.dropped_bytes(), t2.dropped_bytes(), "{name}: drop schedule diverged");
        assert_eq!(t1.retransmitted_bytes(), t2.retransmitted_bytes());
    }
}

/// Total blackout: every link drops every message. No protocol may spin —
/// retry caps expire, degradation paths run out of peers, and the session
/// ends by the clock (or earlier) with every sent byte accounted as
/// dropped, never received.
#[test]
fn total_blackout_terminates_by_the_clock() {
    let section = r#"{"loss": {
        "model": "uniform", "p": 1.0,
        "timeout_s": 1.0, "backoff": 2.0, "max_timeout_s": 4.0, "retries": 2}}"#;
    for name in ProtocolRegistry::builtins().names() {
        let s = spec(name, section, "", 120.0);
        let (m, t) = run_scenario(&s, None, ChurnSchedule::empty()).unwrap();
        assert_bounded(name, &m, 120.0);
        assert!(t.dropped_bytes() > 0, "{name}: blackout dropped nothing");
        assert_eq!(t.retransmitted_bytes(), 0, "{name}: a blackout delivers no retransmit");
        assert_eq!(t.goodput(), 0, "{name}: goodput under total blackout must be zero");
        assert!(t.is_conserved(), "{name}: dropped bytes must stay accounted");
    }
}

/// Fully lossy links: the `classes` model blackholes every link touching a
/// dead-tier node (loss = 1.0 on those links, 0 elsewhere). Protocols with
/// unconditional progress guarantees — gossip's locally-driven rounds,
/// D-SGD's barrier waiver — must keep advancing past the silent peers;
/// MoDeST/FedAvg may stall if a round's entire aggregator draw lands in
/// the dead tier, but must still terminate bounded by the clock.
#[test]
fn fully_lossy_links_do_not_deadlock_any_protocol() {
    let section = r#"{
        "classes": [
            {"name": "ok",   "weight": 0.75, "up_mbps": 50.0},
            {"name": "dead", "weight": 0.25, "up_mbps": 50.0}
        ],
        "loss": {"model": "classes", "tiers": [0.0, 1.0],
                 "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                 "retries": 2}}"#;
    for name in ProtocolRegistry::builtins().names() {
        let s = spec(name, section, "", 900.0);
        let (m, t) = run_scenario(&s, None, ChurnSchedule::empty()).unwrap();
        assert_bounded(name, &m, 900.0);
        // The tier draw is seed-fixed (run.seed 4242 forks "bw"), so a
        // 16-node population deterministically contains dead-tier nodes
        // and some traffic must die on their links.
        assert!(t.dropped_bytes() > 0, "{name}: no link was actually blackholed");
        assert!(t.is_conserved(), "{name}: attempt accounting leaked bytes");
        if matches!(name, "gossip" | "dsgd") {
            assert!(
                m.final_round >= 3,
                "{name}: stalled at round {} behind blackholed peers",
                m.final_round
            );
        }
    }
}

/// The wire/goodput split holds under every loss model: total is the true
/// wire cost, goodput excludes in-flight losses and delivered duplicates,
/// and the three columns always reconcile.
#[test]
fn ledger_columns_reconcile_under_every_loss_model() {
    let sections = [
        r#"{"loss": {"model": "uniform", "p": 0.3,
                     "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                     "retries": 3}}"#,
        r#"{"loss": {"model": "burst", "p_good": 0.02, "p_bad": 0.6,
                     "good_s": 12.0, "bad_s": 4.0,
                     "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                     "retries": 3}}"#,
        r#"{
            "classes": [
                {"name": "clean", "weight": 0.5, "up_mbps": 50.0},
                {"name": "noisy", "weight": 0.5, "up_mbps": 50.0}
            ],
            "loss": {"model": "classes", "tiers": [0.0, 0.4],
                     "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                     "retries": 3}}"#,
    ];
    for section in sections {
        let s = spec("gossip", section, "", 400.0);
        let (m, t) = run_scenario(&s, None, ChurnSchedule::empty()).unwrap();
        assert!(m.events > 0);
        assert!(t.dropped_bytes() > 0, "model dropped nothing: {section}");
        assert!(t.is_conserved());
        assert_eq!(
            t.goodput() + t.dropped_bytes() + t.retransmitted_bytes(),
            t.total(),
            "wire/goodput split does not reconcile"
        );
    }
}
