//! Integration tests over the simulation substrates (no artifacts needed):
//! DES + network + traffic + churn wired together through full sessions on
//! the mock task.

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::sim::{ChurnSchedule, SimTime};

fn mock_spec(algo: Algo) -> SessionSpec {
    SessionSpec {
        dataset: "mock".into(),
        algo,
        nodes: 16,
        s: 4,
        a: 2,
        sf: 1.0,
        max_time_s: 400.0,
        max_rounds: 40,
        eval_interval_s: 5.0,
        hetero_sigma: 0.35,
        ..Default::default()
    }
}

#[test]
fn modest_session_is_deterministic_given_seed() {
    let run = || {
        let spec = mock_spec(Algo::Modest);
        let (m, t) = spec.build_modest(None, ChurnSchedule::empty()).unwrap().run();
        (
            m.final_round,
            m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect::<Vec<_>>(),
            t.total(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must replay identically");
}

#[test]
fn different_seeds_give_different_traffic_patterns() {
    let mut spec = mock_spec(Algo::Modest);
    let (_, t1) = spec.build_modest(None, ChurnSchedule::empty()).unwrap().run();
    spec.seed = 1234;
    let (_, t2) = spec.build_modest(None, ChurnSchedule::empty()).unwrap().run();
    assert_ne!(t1.total(), t2.total());
}

#[test]
fn traffic_conservation_across_all_algorithms() {
    for algo in [Algo::Modest, Algo::Fedavg, Algo::Dsgd] {
        let spec = mock_spec(algo);
        let (_, t) = match algo {
            Algo::Dsgd => spec.build_dsgd(None).unwrap().run(),
            _ => spec.build_modest(None, ChurnSchedule::empty()).unwrap().run(),
        };
        assert!(t.is_conserved(), "{algo:?} lost bytes");
        assert!(t.total() > 0, "{algo:?} sent nothing");
    }
}

#[test]
fn fedavg_server_dominates_traffic_modest_balances() {
    let (_, t_fl) = mock_spec(Algo::Fedavg)
        .build_modest(None, ChurnSchedule::empty())
        .unwrap()
        .run();
    let (_, t_md) = mock_spec(Algo::Modest)
        .build_modest(None, ChurnSchedule::empty())
        .unwrap()
        .run();
    let (min_fl, max_fl) = t_fl.min_max_usage(16);
    let (min_md, max_md) = t_md.min_max_usage(16);
    let spread_fl = max_fl as f64 / min_fl.max(1) as f64;
    let spread_md = max_md as f64 / min_md.max(1) as f64;
    // The paper's §4.4 claim: MoDeST load-balances far better than FL.
    assert!(
        spread_md < spread_fl,
        "MoDeST spread {spread_md:.1} !< FedAvg spread {spread_fl:.1}"
    );
}

#[test]
fn dsgd_total_traffic_exceeds_modest() {
    // D-SGD involves every node every round: at equal round counts its
    // total traffic must exceed MoDeST's sampled rounds (Table 4 shape).
    let mut spec_md = mock_spec(Algo::Modest);
    spec_md.max_rounds = 20;
    spec_md.max_time_s = 2000.0;
    let (m_md, t_md) = spec_md.build_modest(None, ChurnSchedule::empty()).unwrap().run();
    let mut spec_dl = mock_spec(Algo::Dsgd);
    spec_dl.max_rounds = 20;
    spec_dl.max_time_s = 2000.0;
    let (m_dl, t_dl) = spec_dl.build_dsgd(None).unwrap().run();
    assert!(m_md.final_round >= 18 && m_dl.final_round >= 18);
    assert!(
        t_dl.kind_total(modest_dl::net::MsgKind::ModelPayload)
            > t_md.kind_total(modest_dl::net::MsgKind::ModelPayload),
        "DL model traffic {} !> MoDeST {}",
        t_dl.kind_total(modest_dl::net::MsgKind::ModelPayload),
        t_md.kind_total(modest_dl::net::MsgKind::ModelPayload)
    );
}

#[test]
fn mass_crash_session_keeps_making_progress() {
    let churn = ChurnSchedule::mass_crash(
        16,
        6,
        2,
        SimTime::from_secs_f64(60.0),
        SimTime::from_secs_f64(20.0),
    );
    let mut spec = mock_spec(Algo::Modest);
    spec.a = 3;
    spec.sf = 0.5;
    spec.max_rounds = 0;
    spec.max_time_s = 600.0;
    let (m, _) = spec.build_modest(None, churn).unwrap().run();
    let after_crashes = m.round_starts.iter().filter(|&&(_, t)| t > 200.0).count();
    assert!(after_crashes > 3, "no rounds after the crash wave");
}

#[test]
fn staggered_joins_propagate_to_all_initial_nodes() {
    let churn = ChurnSchedule::staggered_joins(
        12,
        3,
        SimTime::from_secs_f64(30.0),
        SimTime::from_secs_f64(30.0),
    );
    let mut spec = mock_spec(Algo::Modest);
    spec.nodes = 12;
    spec.max_rounds = 0;
    spec.max_time_s = 500.0;
    let (m, _) = spec.build_modest(None, churn).unwrap().run();
    assert_eq!(m.joins.len(), 3);
    for j in &m.joins {
        let prop = j.full_propagation_s();
        assert!(prop.is_some(), "join of node {} never propagated", j.joiner);
        assert!(prop.unwrap() > 0.0);
    }
}

#[test]
fn curve_csv_roundtrip() {
    let spec = mock_spec(Algo::Modest);
    let (m, _) = spec.build_modest(None, ChurnSchedule::empty()).unwrap().run();
    let dir = std::env::temp_dir().join(format!("modest_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("curve.csv");
    m.write_curve_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "time_s,round,metric,loss,metric_std");
    assert_eq!(lines.len() - 1, m.curve.len());
    std::fs::remove_dir_all(&dir).ok();
}
