//! Integration tests over the simulation substrates (no artifacts needed):
//! DES + network + traffic + churn wired together through full sessions on
//! the mock task, launched through the scenario registry.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnEvent, ChurnKind, ChurnSchedule, SimTime};

fn mock_spec(protocol: &str) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new("mock", protocol);
    spec.population.nodes = 16;
    spec.protocol.s = 4;
    spec.protocol.a = 2;
    spec.protocol.sf = 1.0;
    spec.run.max_time_s = 400.0;
    spec.run.max_rounds = 40;
    spec.run.eval_interval_s = 5.0;
    spec.population.hetero_sigma = 0.35;
    spec
}

fn run(spec: &ScenarioSpec) -> (SessionMetrics, TrafficLedger) {
    run_scenario(spec, None, ChurnSchedule::empty()).unwrap()
}

#[test]
fn modest_session_is_deterministic_given_seed() {
    let go = || {
        let (m, t) = run(&mock_spec("modest"));
        (
            m.final_round,
            m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect::<Vec<_>>(),
            t.total(),
        )
    };
    let a = go();
    let b = go();
    assert_eq!(a, b, "same seed must replay identically");
}

#[test]
fn different_seeds_give_different_traffic_patterns() {
    let mut spec = mock_spec("modest");
    let (_, t1) = run(&spec);
    spec.run.seed = 1234;
    let (_, t2) = run(&spec);
    assert_ne!(t1.total(), t2.total());
}

#[test]
fn traffic_conservation_across_all_registered_protocols() {
    // Registry-driven: every protocol in the builtin registry must conserve
    // bytes, with zero per-protocol launch code here.
    for protocol in modest_dl::scenario::ProtocolRegistry::builtins().names() {
        let (_, t) = run(&mock_spec(protocol));
        assert!(t.is_conserved(), "{protocol} lost bytes");
        assert!(t.total() > 0, "{protocol} sent nothing");
    }
}

#[test]
fn fedavg_server_dominates_traffic_modest_balances() {
    let (_, t_fl) = run(&mock_spec("fedavg"));
    let (_, t_md) = run(&mock_spec("modest"));
    let (min_fl, max_fl) = t_fl.min_max_usage(16);
    let (min_md, max_md) = t_md.min_max_usage(16);
    let spread_fl = max_fl as f64 / min_fl.max(1) as f64;
    let spread_md = max_md as f64 / min_md.max(1) as f64;
    // The paper's §4.4 claim: MoDeST load-balances far better than FL.
    assert!(
        spread_md < spread_fl,
        "MoDeST spread {spread_md:.1} !< FedAvg spread {spread_fl:.1}"
    );
}

#[test]
fn dsgd_total_traffic_exceeds_modest() {
    // D-SGD involves every node every round: at equal round counts its
    // total traffic must exceed MoDeST's sampled rounds (Table 4 shape).
    let mut spec_md = mock_spec("modest");
    spec_md.run.max_rounds = 20;
    spec_md.run.max_time_s = 2000.0;
    let (m_md, t_md) = run(&spec_md);
    let mut spec_dl = mock_spec("dsgd");
    spec_dl.run.max_rounds = 20;
    spec_dl.run.max_time_s = 2000.0;
    let (m_dl, t_dl) = run(&spec_dl);
    assert!(m_md.final_round >= 18 && m_dl.final_round >= 18);
    assert!(
        t_dl.kind_total(modest_dl::net::MsgKind::ModelPayload)
            > t_md.kind_total(modest_dl::net::MsgKind::ModelPayload),
        "DL model traffic {} !> MoDeST {}",
        t_dl.kind_total(modest_dl::net::MsgKind::ModelPayload),
        t_md.kind_total(modest_dl::net::MsgKind::ModelPayload)
    );
}

#[test]
fn mass_crash_session_keeps_making_progress() {
    let churn = ChurnSchedule::mass_crash(
        16,
        6,
        2,
        SimTime::from_secs_f64(60.0),
        SimTime::from_secs_f64(20.0),
    );
    let mut spec = mock_spec("modest");
    spec.protocol.a = 3;
    spec.protocol.sf = 0.5;
    spec.run.max_rounds = 0;
    spec.run.max_time_s = 600.0;
    let (m, _) = run_scenario(&spec, None, churn).unwrap();
    let after_crashes = m.round_starts.iter().filter(|&(_, t)| t > 200.0).count();
    assert!(after_crashes > 3, "no rounds after the crash wave");
}

#[test]
fn staggered_joins_propagate_to_all_initial_nodes() {
    let churn = ChurnSchedule::staggered_joins(
        12,
        3,
        SimTime::from_secs_f64(30.0),
        SimTime::from_secs_f64(30.0),
    );
    let mut spec = mock_spec("modest");
    spec.population.nodes = 12;
    spec.run.max_rounds = 0;
    spec.run.max_time_s = 500.0;
    let (m, _) = run_scenario(&spec, None, churn).unwrap();
    assert_eq!(m.joins.len(), 3);
    for j in &m.joins {
        let prop = j.full_propagation_s();
        assert!(prop.is_some(), "join of node {} never propagated", j.joiner);
        assert!(prop.unwrap() > 0.0);
    }
}

#[test]
fn invalid_churn_scripts_are_rejected_at_build() {
    // Stale-proofed from the PR 2 era (when D-SGD/gossip rejected every
    // churn script — both tolerate crash/leave since PR 3): what must be
    // rejected TODAY is (a) crash/leave of a node id that never joins —
    // now a spec-level build error for every protocol — and (b) fresh-id
    // joins into D-SGD's fixed one-peer topology.
    let orphan = ChurnSchedule::new(vec![ChurnEvent {
        at: SimTime::from_secs_f64(5.0),
        node: 99,
        kind: ChurnKind::Crash,
    }]);
    for protocol in ["modest", "fedavg", "dsgd", "gossip"] {
        let spec = mock_spec(protocol);
        let err = run_scenario(&spec, None, orphan.clone()).unwrap_err();
        assert!(
            err.to_string().contains("never joins"),
            "{protocol}: wrong orphan-crash error: {err:#}"
        );
    }
    let join = ChurnSchedule::staggered_joins(
        16,
        2,
        SimTime::from_secs_f64(5.0),
        SimTime::from_secs_f64(5.0),
    );
    assert!(
        run_scenario(&mock_spec("dsgd"), None, join).is_err(),
        "d-sgd accepted fresh joiners into its fixed topology"
    );
}

#[test]
fn curve_csv_roundtrip() {
    let (m, _) = run(&mock_spec("modest"));
    let dir = std::env::temp_dir().join(format!("modest_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("curve.csv");
    m.write_curve_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines[0], "time_s,round,metric,loss,metric_std");
    assert_eq!(lines.len() - 1, m.curve.len());
    std::fs::remove_dir_all(&dir).ok();
}
