//! Differential tests pinning the V2 partial-shuffle sampling stream to
//! the frozen V1 full-shuffle stream: identical *set distribution* over
//! randomized (n, k) schedules, O(k) draw complexity at n = 100k (V2 must
//! never do O(n) work — the tentpole property behind the 100k-node fast
//! path), and scenario-level determinism under `sampling: v2` with the
//! default `v1` untouched.

use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, Population, SamplingVersion, SimRng};

/// Both versions must return k distinct in-range indices for arbitrary
/// (n, k) schedules, including the k = n and k = 0 edges.
#[test]
fn randomized_schedules_yield_distinct_in_range_samples() {
    let mut sched = SimRng::new(0xC0FFEE);
    let mut v1 = SimRng::new(1);
    let mut v2 = SimRng::new(2);
    for step in 0..500 {
        let n = 1 + sched.gen_range(400) as usize;
        let k = sched.gen_range((n + 1) as u64) as usize;
        for (label, s) in [
            ("v1", v1.sample_indices_versioned(SamplingVersion::V1Shuffle, n, k)),
            ("v2", v2.sample_indices_versioned(SamplingVersion::V2Partial, n, k)),
        ] {
            assert_eq!(s.len(), k, "{label} len at step {step} (n={n}, k={k})");
            assert!(
                s.iter().all(|&i| i < n),
                "{label} out of range at step {step}: {s:?} (n={n})"
            );
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "{label} duplicates at step {step}: {s:?}");
        }
    }
}

/// V1 and V2 draw the same distribution over unordered k-subsets: count
/// every C(8,3) = 56 subset over a fixed-seed schedule and require each
/// bin within 15% of uniform for BOTH streams (deterministic given the
/// seeds; the worst observed deviation is ~9% at these sample sizes).
#[test]
fn v1_and_v2_agree_on_subset_distribution() {
    let trials = 56_000usize;
    let expected = trials as f64 / 56.0;
    for (label, seed, version) in [
        ("v1", 101u64, SamplingVersion::V1Shuffle),
        ("v2", 202u64, SamplingVersion::V2Partial),
    ] {
        let mut rng = SimRng::new(seed);
        let mut bins = std::collections::HashMap::new();
        for _ in 0..trials {
            let mut s = rng.sample_indices_versioned(version, 8, 3);
            s.sort_unstable();
            *bins.entry((s[0], s[1], s[2])).or_insert(0u64) += 1;
        }
        assert_eq!(bins.len(), 56, "{label} missed subsets");
        for (subset, count) in &bins {
            let dev = (*count as f64 - expected).abs() / expected;
            assert!(
                dev < 0.15,
                "{label} subset {subset:?} count {count} deviates {dev:.3} from {expected}"
            );
        }
    }
}

/// Per-index inclusion frequency at (n=50, k=10): every index near k/n for
/// both versions (marginals agree, not just the aggregate).
#[test]
fn v1_and_v2_agree_on_inclusion_frequency() {
    for (label, seed, version) in [
        ("v1", 303u64, SamplingVersion::V1Shuffle),
        ("v2", 404u64, SamplingVersion::V2Partial),
    ] {
        let mut rng = SimRng::new(seed);
        let trials = 20_000usize;
        let mut inc = [0u64; 50];
        for _ in 0..trials {
            for i in rng.sample_indices_versioned(version, 50, 10) {
                inc[i] += 1;
            }
        }
        for (i, &c) in inc.iter().enumerate() {
            let f = c as f64 / trials as f64;
            assert!(
                (0.18..=0.22).contains(&f),
                "{label} index {i} inclusion {f:.4} far from 0.2"
            );
        }
    }
}

/// The tentpole complexity bound: at n = 100k, k = 10, V2 consumes O(k)
/// raw RNG draws (exactly k absent astronomically-rare Lemire rejections)
/// while V1's frozen stream burns n - 1. The draw counter is the
/// allocation proxy — V2's only storage is its k-entry displacement map,
/// so a stream that stayed at ~k draws cannot have touched an O(n) array.
#[test]
fn v2_draw_complexity_is_o_k_at_n_100k() {
    let mut rng = SimRng::new(9);
    let before = rng.draw_count();
    let s = rng.sample_indices_v2(100_000, 10);
    let v2_draws = rng.draw_count() - before;
    assert_eq!(s.len(), 10);
    assert!(
        v2_draws <= 40,
        "v2 consumed {v2_draws} draws for k=10 — not O(k)"
    );

    let mut rng = SimRng::new(9);
    let before = rng.draw_count();
    rng.sample_indices(100_000, 10);
    let v1_draws = rng.draw_count() - before;
    assert!(
        v1_draws >= 99_999,
        "v1's frozen stream changed: {v1_draws} draws"
    );
}

/// The churned-path fingerprint guarantee: `Population`'s Fenwick
/// rank/`select` sampling must be draw-for-draw AND peer-for-peer
/// identical to the historical materialize-the-alive-list-then-index slow
/// path, for both stream versions, across randomized churn states
/// (including dead `excluded` nodes, out-of-range `excluded`, k > alive,
/// and all-alive tables that take the no-materialization fast path). This
/// is what lets every recorded same-seed churn fingerprint (gossip, D-SGD,
/// MoDeST) replay bit-identically across the Population refactor.
#[test]
fn churned_sampling_matches_the_materialized_list_oracle() {
    let mut sched = SimRng::new(0xFE0C);
    for case in 0..300u64 {
        let n = 2 + sched.gen_range(180) as usize;
        let mut pop = Population::new(n, n);
        let flips = sched.gen_range(2 * n as u64 + 1) as usize;
        for _ in 0..flips {
            let i = sched.gen_range(n as u64) as usize;
            if sched.gen_range(2) == 0 {
                pop.mark_dead(i);
            } else {
                pop.mark_alive(i);
            }
        }
        let of = sched.gen_range(n as u64 + 2) as usize; // sometimes out of range
        let k = 1 + sched.gen_range(12) as usize;
        for version in [SamplingVersion::V1Shuffle, SamplingVersion::V2Partial] {
            let seed = 0x5eed ^ (case << 8);
            let mut fenwick_rng = SimRng::new(seed);
            let mut oracle_rng = SimRng::new(seed);
            let got = pop.sample_alive_excluding(&mut fenwick_rng, version, of, k);
            // The pre-Population slow path, verbatim: materialize the
            // alive list minus `of`, sample positions, index into it.
            let peers: Vec<u32> = (0..n as u32)
                .filter(|&j| j as usize != of && pop.is_alive(j as usize))
                .collect();
            let expect: Vec<u32> = if peers.is_empty() {
                Vec::new()
            } else {
                let kk = k.min(peers.len());
                oracle_rng
                    .sample_indices_versioned(version, peers.len(), kk)
                    .into_iter()
                    .map(|p| peers[p])
                    .collect()
            };
            assert_eq!(got, expect, "case {case} {version:?} (n={n}, of={of}, k={k})");
            assert_eq!(
                fenwick_rng.draw_count(),
                oracle_rng.draw_count(),
                "case {case} {version:?}: draw streams diverged"
            );
        }
    }
}

/// The tentpole churned complexity bound: at n = 100k with 30% of the
/// population dead, a V2 fan-out draw consumes O(k) raw RNG draws — the
/// Fenwick `select` mapping spends no entropy and materializes no
/// alive-peer list, so the whole churned draw is O(k log n) work. V1's
/// frozen stream still burns alive-1 draws by contract (which is exactly
/// why the churned 100k CI smoke runs under `--sampling v2`).
#[test]
fn churned_v2_draw_complexity_is_o_k_at_n_100k() {
    let n = 100_000;
    let mut pop = Population::new(n, n);
    let mut killer = SimRng::new(0xDEAD);
    for i in killer.sample_indices_v2(n, 30_000) {
        pop.mark_dead(i);
    }
    assert_eq!(pop.alive_count(), 70_000);
    let of = pop.select(0); // a known-alive node
    let mut rng = SimRng::new(9);
    let before = rng.draw_count();
    let s = pop.sample_alive_excluding(&mut rng, SamplingVersion::V2Partial, of, 10);
    let v2_draws = rng.draw_count() - before;
    assert_eq!(s.len(), 10);
    for &x in &s {
        assert!(pop.is_alive(x as usize), "dead peer {x} sampled");
        assert_ne!(x as usize, of);
    }
    assert!(
        v2_draws <= 40,
        "churned v2 consumed {v2_draws} draws for k=10 — not O(k)"
    );

    let mut rng = SimRng::new(9);
    let before = rng.draw_count();
    pop.sample_alive_excluding(&mut rng, SamplingVersion::V1Shuffle, of, 10);
    let v1_draws = rng.draw_count() - before;
    assert!(
        v1_draws >= 69_000,
        "v1's frozen churned stream changed: {v1_draws} draws"
    );
}

/// Scenario plumbing end to end, on a protocol that samples peers every
/// round (gossip): the same scenario runs deterministically under
/// `sampling: v2`, AND flipping the version changes the session outcome —
/// different peers receive the pushes, so the merged models and the
/// convergence curve diverge. If a builder ever stops copying
/// `spec.run.sampling` into its config, v1 and v2 collapse to the same
/// stream and this test fails, instead of the 100k CI smoke timing out
/// minutes later with no pointer to the cause.
#[test]
fn scenario_sampling_version_reaches_the_sampler() {
    let mk = |sampling: &str| {
        let spec = ScenarioSpec::from_json(&format!(
            r#"{{
                "workload": {{"dataset": "mock"}},
                "population": {{"nodes": 16}},
                "protocol": {{"name": "gossip", "params": {{"fanout": 2}}}},
                "run": {{"max_time_s": 300.0, "max_rounds": 12,
                         "eval_interval_s": 10.0, "seed": 11,
                         "sampling": "{sampling}"}}
            }}"#
        ))
        .unwrap();
        assert_eq!(
            spec.run.sampling,
            SamplingVersion::parse(sampling).unwrap()
        );
        run_scenario(&spec, None, ChurnSchedule::empty()).unwrap()
    };
    let fingerprint = |m: &modest_dl::metrics::SessionMetrics| -> Vec<u64> {
        let mut f: Vec<u64> = m.curve.iter().map(|p| p.metric.to_bits()).collect();
        f.push(m.duration_s.to_bits());
        f
    };
    let (a, ta) = mk("v2");
    let (b, tb) = mk("v2");
    assert_eq!(a.events, b.events);
    assert_eq!(a.final_round, b.final_round);
    assert_eq!(ta.total(), tb.total());
    assert_eq!(fingerprint(&a), fingerprint(&b), "v2 not deterministic");
    assert!(a.final_round >= 10, "v2 session stalled at {}", a.final_round);
    let (c, tc) = mk("v1");
    assert!(c.final_round >= 10, "v1 session stalled at {}", c.final_round);
    assert!(tc.is_conserved() && ta.is_conserved());
    // The discriminating assertion: 16 nodes x 12 rounds x fanout 2 means
    // ~hundreds of versioned draws; if the version knob reached the
    // sampler, at least one push went to a different peer and the merged
    // models (hence the curve bits) diverge.
    assert_ne!(
        fingerprint(&a),
        fingerprint(&c),
        "v1 and v2 produced identical sessions — run.sampling is not \
         reaching the sampler"
    );
}
