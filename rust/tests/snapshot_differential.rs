//! Differential tests for the checkpoint/restore subsystem: for every
//! registered protocol, a run that checkpoints at time T and resumes from
//! the file must produce a final fingerprint bit-identical to the same
//! scenario run uninterrupted — metrics curve bits, event counts, and
//! traffic ledger bytes all included. Runs under both queue backends via
//! the CI feature matrix (`--features queue-heap` swaps the backend under
//! the same test body). Also pinned here: the write→read→write byte
//! round trip, loud failures on corrupted snapshots, and what-if branching
//! (fork label / availability overlay) diverging only after the branch
//! point.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{
    resume_session, run_scenario, ProgressSpec, ProtocolRegistry, ScenarioSpec,
};
use modest_dl::sim::ChurnSchedule;

fn fingerprint(m: &SessionMetrics, t: &TrafficLedger) -> (u64, u64, Vec<(u64, u64)>, u64) {
    (
        m.final_round,
        m.events,
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect(),
        t.total(),
    )
}

/// A churned mock scenario small enough to run 4x per protocol: the step
/// availability model takes a slice of the population down and up again,
/// so snapshots cover dead nodes, queued churn events, and mid-flight
/// revival state — not just the happy path.
fn churned_spec(protocol: &str) -> ScenarioSpec {
    ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 14, "availability": {{
                "model": "step", "amplitude": 0.3, "period_s": 50.0, "seed": 5}}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            "run": {{"max_time_s": 150.0, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap()
}

fn snap_path(tag: &str) -> std::path::PathBuf {
    let backend = if cfg!(feature = "queue-heap") { "heap" } else { "cal" };
    std::env::temp_dir().join(format!("snapshot_diff_{tag}_{backend}.snap"))
}

/// Run `spec` to completion with a checkpoint at `at_s`, returning the
/// snapshot bytes (the interrupted run's own metrics are discarded — the
/// oracle is the resumed continuation).
fn checkpoint_run(spec: &ScenarioSpec, at_s: f64, tag: &str) -> Vec<u8> {
    let path = snap_path(tag);
    let mut ck = spec.clone();
    ck.run.checkpoint_at_s = Some(at_s);
    ck.run.checkpoint_out = Some(path.to_string_lossy().into_owned());
    let _ = run_scenario(&ck, None, ChurnSchedule::empty()).unwrap();
    let bytes = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("checkpoint at t={at_s}s was never written ({tag}): {e}")
    });
    let _ = std::fs::remove_file(&path);
    bytes
}

#[test]
fn resume_matches_uninterrupted_for_every_protocol() {
    for name in ProtocolRegistry::builtins().names() {
        let spec = churned_spec(name);
        let (m0, t0) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        assert!(m0.events > 0 && t0.total() > 0, "{name} did nothing");
        let want = fingerprint(&m0, &t0);
        // Checkpoint instants as fixed fractions of the session's actual
        // span: early (mid-bootstrap traffic), middle (first churn step
        // has landed), late (most rounds done). Each must land before the
        // run's end for the trigger to fire.
        for (i, frac) in [0.2, 0.45, 0.8].into_iter().enumerate() {
            let at_s = m0.duration_s * frac;
            let bytes = checkpoint_run(&spec, at_s, &format!("{name}_{i}"));
            let (_, session) = resume_session(&bytes, None, None, None).unwrap();
            let (m1, t1) = session.run();
            assert_eq!(
                fingerprint(&m1, &t1),
                want,
                "{name}: resume from t={at_s:.1}s diverged from the uninterrupted run"
            );
        }
    }
}

/// The lossy variant of [`churned_spec`]: ~20% average Gilbert–Elliott
/// burst loss rides the same step churn, so snapshots taken mid-run carry
/// in-flight retransmit state (armed outbox timers, pending attempts) and
/// the loss RNG's channel states. Short retransmit timeouts keep outboxes
/// busy at every checkpoint instant.
fn lossy_churned_spec(protocol: &str) -> ScenarioSpec {
    ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 14, "availability": {{
                "model": "step", "amplitude": 0.3, "period_s": 50.0, "seed": 5}}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            "network": {{"loss": {{
                "model": "burst", "p_good": 0.05, "p_bad": 0.5,
                "good_s": 15.0, "bad_s": 7.5,
                "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                "retries": 2}}}},
            "run": {{"max_time_s": 400.0, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap()
}

/// Under burst loss + churn, a checkpoint/resume must still be
/// bit-identical to the uninterrupted run: the loss layer's per-receiver
/// channel states, the forked loss RNG, and every protocol's in-flight
/// retransmit state (seq counters, attempt counts, armed timers) all ride
/// the snapshot. A single dropped or double-fired retransmit after resume
/// would shift the event count and the ledger's drop column.
#[test]
fn lossy_resume_matches_uninterrupted_for_every_protocol() {
    for name in ProtocolRegistry::builtins().names() {
        let spec = lossy_churned_spec(name);
        let (m0, t0) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        assert!(m0.events > 0 && t0.total() > 0, "{name} did nothing");
        assert!(t0.dropped_bytes() > 0, "{name}: burst loss dropped nothing");
        let want = fingerprint(&m0, &t0);
        for (i, frac) in [0.3, 0.7].into_iter().enumerate() {
            let at_s = m0.duration_s * frac;
            let bytes = checkpoint_run(&spec, at_s, &format!("{name}_lossy_{i}"));
            let (_, session) = resume_session(&bytes, None, None, None).unwrap();
            let (m1, t1) = session.run();
            assert_eq!(
                fingerprint(&m1, &t1),
                want,
                "{name}: lossy resume from t={at_s:.1}s diverged from the uninterrupted run"
            );
            assert_eq!(
                (t1.dropped_bytes(), t1.retransmitted_bytes()),
                (t0.dropped_bytes(), t0.retransmitted_bytes()),
                "{name}: loss columns diverged after resume"
            );
        }
    }
}

/// The progress JSONL stream rides checkpoints: a run that checkpoints at
/// T (suppressing its terminal line) and then resumes must *append* to the
/// same file and end up with exactly the lines an uninterrupted run
/// streams — compared after stripping the non-deterministic wall-clock
/// tail of each line with a textual cut at `,"wall_s":`.
#[test]
fn progress_stream_rides_checkpoint_resume() {
    let backend = if cfg!(feature = "queue-heap") { "heap" } else { "cal" };
    let full_path =
        std::env::temp_dir().join(format!("progress_diff_full_{backend}.jsonl"));
    let stitched_path =
        std::env::temp_dir().join(format!("progress_diff_stitched_{backend}.jsonl"));

    let mut spec = churned_spec("modest");
    spec.run.progress = Some(ProgressSpec {
        every_s: 10.0,
        out: Some(full_path.to_string_lossy().into_owned()),
    });
    let (m0, _) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    let full = std::fs::read_to_string(&full_path).unwrap();
    let _ = std::fs::remove_file(&full_path);

    // Same session split across a process-equivalent boundary, streaming
    // into one stitched file: part 1 truncates on its first emit, the
    // resumed part appends (the spec — progress config included — rides
    // the snapshot).
    let mut ck = spec.clone();
    ck.run.progress.as_mut().unwrap().out =
        Some(stitched_path.to_string_lossy().into_owned());
    let bytes = checkpoint_run(&ck, m0.duration_s * 0.5, "modest_progress");
    let (_, session) = resume_session(&bytes, None, None, None).unwrap();
    let _ = session.run();
    let stitched = std::fs::read_to_string(&stitched_path).unwrap();
    let _ = std::fs::remove_file(&stitched_path);

    let strip = |text: &str| -> Vec<String> {
        text.lines()
            .map(|l| {
                let cut = l.find(",\"wall_s\":").expect("wall tail missing");
                l[..cut].to_string()
            })
            .collect()
    };
    let (a, b) = (strip(&full), strip(&stitched));
    assert!(a.len() >= 4, "uninterrupted run streamed only {} lines", a.len());
    assert_eq!(a, b, "checkpoint+resume progress stream diverged from uninterrupted");
}

#[test]
fn snapshot_write_read_write_is_byte_identical() {
    for name in ProtocolRegistry::builtins().names() {
        let spec = churned_spec(name);
        let (m0, _) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        let bytes = checkpoint_run(&spec, m0.duration_s * 0.5, &format!("{name}_rt"));
        let (_, session) = resume_session(&bytes, None, None, None).unwrap();
        let rewritten = session.snapshot_bytes().unwrap();
        assert_eq!(
            rewritten, bytes,
            "{name}: restored session re-serialized differently ({} vs {} bytes)",
            rewritten.len(),
            bytes.len()
        );
    }
}

#[test]
fn corrupted_snapshots_fail_loudly() {
    let spec = churned_spec("gossip");
    let (m0, _) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    let bytes = checkpoint_run(&spec, m0.duration_s * 0.5, "gossip_corrupt");

    // Truncation at any coarse cut must error, never mis-restore.
    for cut in [7, bytes.len() / 3, bytes.len() - 1] {
        let err = resume_session(&bytes[..cut], None, None, None).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated") || msg.contains("section"),
            "cut at {cut}: unhelpful error {msg:?}"
        );
    }
    // Wrong magic.
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    let msg = format!("{:#}", resume_session(&bad, None, None, None).unwrap_err());
    assert!(msg.contains("magic"), "{msg:?}");
    // Unsupported future format version.
    let mut bad = bytes.clone();
    bad[8..12].copy_from_slice(&99u32.to_le_bytes());
    let msg = format!("{:#}", resume_session(&bad, None, None, None).unwrap_err());
    assert!(msg.contains("version"), "{msg:?}");
}

#[test]
fn fork_branch_shares_history_and_diverges_after_the_checkpoint() {
    let spec = churned_spec("gossip");
    let (m0, t0) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    let at_s = m0.duration_s * 0.4;
    let bytes = checkpoint_run(&spec, at_s, "gossip_fork");

    let (_, session_a) = resume_session(&bytes, None, None, None).unwrap();
    let (ma, ta) = session_a.run();
    let (_, session_b) = resume_session(&bytes, None, Some("branch-b".into()), None).unwrap();
    let (mb, _) = session_b.run();

    // Branch A replays the original future exactly.
    assert_eq!(fingerprint(&ma, &ta), fingerprint(&m0, &t0));
    // Branch B shares every eval point before the checkpoint bit-for-bit
    // (restored state, not re-computed)...
    let prefix = |m: &SessionMetrics| -> Vec<(u64, u64)> {
        m.curve
            .iter()
            .filter(|p| p.time_s < at_s)
            .map(|p| (p.round, p.metric.to_bits()))
            .collect()
    };
    assert_eq!(prefix(&ma), prefix(&mb), "history diverged before the branch point");
    assert!(!prefix(&ma).is_empty(), "checkpoint landed before the first eval");
    // ...and diverges afterwards: the fork relabels the only runtime RNG
    // stream, so peer draws — and through them the mixing trajectory —
    // must differ somewhere after the branch.
    let curve_bits = |m: &SessionMetrics| -> Vec<(u64, u64)> {
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect()
    };
    assert_ne!(curve_bits(&ma), curve_bits(&mb), "fork label did not branch the future");
}

#[test]
fn availability_overlay_rewrites_the_future_churn() {
    let spec = churned_spec("modest");
    let (m0, t0) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    let at_s = m0.duration_s * 0.4;
    let bytes = checkpoint_run(&spec, at_s, "modest_whatif");

    // What-if: from the checkpoint on, nobody churns (availability removed).
    let overlay = r#"{"population": {"availability": null}}"#;
    let (spec2, session) = resume_session(&bytes, Some(overlay), None, None).unwrap();
    assert!(spec2.population.availability.is_none(), "overlay did not apply");
    let (mw, tw) = session.run();
    assert!(mw.final_round >= m0.final_round.min(1), "what-if branch made no progress");
    // Pre-branch history is shared verbatim.
    let prefix = |m: &SessionMetrics| -> Vec<(u64, u64)> {
        m.curve
            .iter()
            .filter(|p| p.time_s < at_s)
            .map(|p| (p.round, p.metric.to_bits()))
            .collect()
    };
    assert_eq!(prefix(&m0), prefix(&mw), "history diverged before the branch point");
    // A churn-free future is a different world: the full fingerprints must
    // not collide with the churned original.
    assert_ne!(
        fingerprint(&mw, &tw),
        fingerprint(&m0, &t0),
        "removing all future churn changed nothing — overlay ineffective?"
    );
}
