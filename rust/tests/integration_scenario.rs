//! Scenario-API integration tests: JSON round-trip, flat-key back-compat,
//! registry completeness, and same-seed equivalence between the legacy
//! flat config form and its nested translation.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{run_scenario, AvailabilityModel, ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::{ChurnEvent, ChurnKind, ChurnSchedule, SimTime};

fn fingerprint(m: &SessionMetrics, t: &TrafficLedger) -> (u64, u64, Vec<(u64, u64)>, u64) {
    (
        m.final_round,
        m.events,
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect(),
        t.total(),
    )
}

/// A short deterministic mock scenario for `protocol`.
fn short_mock(protocol: &str) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new("mock", protocol);
    spec.population.nodes = 12;
    spec.protocol.s = 4;
    spec.protocol.a = 2;
    spec.run.max_time_s = 120.0;
    spec.run.max_rounds = 15;
    spec.run.eval_interval_s = 10.0;
    spec
}

#[test]
fn every_registered_protocol_runs_a_deterministic_mock_session() {
    // Registry completeness: each protocol builds from a plain spec and
    // replays identically under the same seed.
    let registry = ProtocolRegistry::builtins();
    for name in registry.names() {
        let spec = short_mock(name);
        let go = || {
            let (m, t) = registry
                .build(&spec, None, ChurnSchedule::empty())
                .unwrap_or_else(|e| panic!("{name} failed to build: {e:#}"))
                .run();
            fingerprint(&m, &t)
        };
        let a = go();
        let b = go();
        assert!(a.1 > 0, "{name} processed no events");
        assert!(a.3 > 0, "{name} sent no traffic");
        assert_eq!(a, b, "{name} is not deterministic under one seed");
    }
}

#[test]
fn nested_json_roundtrip_preserves_every_field() {
    let mut spec = ScenarioSpec::new("femnist", "gossip");
    spec.workload.artifacts_dir = "my-artifacts".into();
    spec.population.nodes = 48;
    spec.population.scale = 0.5;
    spec.population.base_batch_s = 0.08;
    spec.population.hetero_sigma = 0.2;
    spec.network.bandwidth_mbps = 12.5;
    spec.network.bandwidth_sigma = 0.9;
    spec.protocol.s = 6;
    spec.protocol.a = 2;
    spec.protocol.sf = 0.8;
    spec.protocol.dt_s = 1.5;
    spec.protocol.dk = 15;
    spec.protocol.params = vec![("fanout".into(), 4.0)];
    spec.run.max_time_s = 321.0;
    spec.run.max_rounds = 77;
    spec.run.eval_interval_s = 7.0;
    spec.run.target_metric = Some(0.9);
    spec.run.seed = 1234;
    spec.run.sampling = modest_dl::sim::SamplingVersion::V2Partial;
    spec.population.availability = Some(modest_dl::scenario::AvailabilitySpec {
        model: AvailabilityModel::Step,
        amplitude: 0.4,
        period_s: 120.0,
        seed: Some(5),
        trace_file: None,
    });
    let text = spec.to_json().to_string();
    let back = ScenarioSpec::from_json(&text).unwrap();
    assert_eq!(spec, back);
}

#[test]
fn availability_section_drives_real_churn_deterministically() {
    // The same gossip scenario with and without a diurnal availability
    // section: with it, ~amplitude of the population crashes/recovers over
    // the run, so the session fingerprint must diverge from the all-alive
    // run — proving the compiled schedule actually reaches the harness —
    // while two same-seed availability runs replay bit-identically.
    let mk = |availability: bool| {
        let av = if availability {
            r#", "availability": {"model": "diurnal", "amplitude": 0.4,
                                  "period_s": 10.0, "seed": 3}"#
        } else {
            ""
        };
        let spec = ScenarioSpec::from_json(&format!(
            r#"{{
                "workload": {{"dataset": "mock"}},
                "population": {{"nodes": 24{av}}},
                "protocol": {{"name": "gossip", "params": {{"fanout": 2}}}},
                "run": {{"max_time_s": 150.0, "max_rounds": 12,
                         "eval_interval_s": 10.0, "seed": 11}}
            }}"#
        ))
        .unwrap();
        assert_eq!(spec.population.availability.is_some(), availability);
        let (m, t) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        assert!(t.is_conserved());
        fingerprint(&m, &t)
    };
    let a = mk(true);
    let b = mk(true);
    assert_eq!(a, b, "availability churn is not deterministic");
    let plain = mk(false);
    assert_ne!(
        a, plain,
        "availability section did not change the session — the compiled \
         schedule is not reaching the harness"
    );
}

#[test]
fn availability_runs_on_every_registered_protocol() {
    // The registry compiles availability churn once for all protocols —
    // including D-SGD, whose builder historically rejected every non-crash
    // script (it now accepts recover) and FedAvg's fixed-server emulation.
    let registry = ProtocolRegistry::builtins();
    for name in registry.names() {
        let mut spec = short_mock(name);
        // A short period so crash AND recover windows land inside the few
        // virtual seconds a budgeted mock session actually runs (D-SGD's
        // recovery rejoin gets exercised end-to-end here).
        spec.population.availability = Some(modest_dl::scenario::AvailabilitySpec {
            model: AvailabilityModel::Diurnal,
            amplitude: 0.25,
            period_s: 4.0,
            seed: Some(7),
            trace_file: None,
        });
        let (m, t) = registry
            .build(&spec, None, ChurnSchedule::empty())
            .unwrap_or_else(|e| panic!("{name} rejected availability churn: {e:#}"))
            .run();
        assert!(m.events > 0, "{name} processed no events under availability churn");
        assert!(t.is_conserved(), "{name} leaked traffic under availability churn");
    }
}

#[test]
fn trace_availability_plays_back_offline_intervals() {
    let dir = std::env::temp_dir().join("modest_dl_avail_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("offline.csv");
    // Intervals inside the first virtual seconds, so the budgeted mock
    // session actually lives through them.
    std::fs::write(
        &path,
        "# node,offline_from_s,offline_until_s\n3,2.0,6.0\n5,3.0,8.0\n",
    )
    .unwrap();
    let spec = ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 12,
                "availability": {{"model": "trace", "trace_file": {:?}}}}},
            "protocol": {{"name": "gossip"}},
            "run": {{"max_time_s": 120.0, "max_rounds": 10,
                     "eval_interval_s": 10.0, "seed": 4}}
        }}"#,
        path.to_string_lossy()
    ))
    .unwrap();
    let churn = spec.availability_churn().unwrap();
    assert_eq!(churn.events().len(), 4, "two crash/recover pairs");
    let (m, t) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    assert!(m.final_round >= 8, "stalled at {}", m.final_round);
    assert!(t.is_conserved());
}

#[test]
fn never_joining_churn_targets_fail_at_build_not_runtime() {
    // The parse-time churn-validation satellite: a script that crashes a
    // node id outside the population (with no Join for it) must be
    // rejected by the registry with a pointed error for EVERY protocol —
    // MoDeST historically let this straight through to the session.
    let registry = ProtocolRegistry::builtins();
    for name in registry.names() {
        let spec = short_mock(name);
        let churn = ChurnSchedule::new(vec![ChurnEvent {
            at: SimTime::from_secs_f64(5.0),
            node: 9_999,
            kind: ChurnKind::Crash,
        }]);
        // (`.err()` rather than `unwrap_err`: the Ok side is a type-erased
        // session with no Debug impl.)
        let err = registry
            .build(&spec, None, churn)
            .err()
            .unwrap_or_else(|| panic!("{name} accepted an orphan crash"));
        assert!(
            err.to_string().contains("never joins"),
            "{name}: wrong error: {err:#}"
        );
    }
    // The same id WITH a Join event is legitimate (for protocols that
    // admit joiners) and passes spec-level validation.
    let spec = short_mock("gossip");
    let churn = ChurnSchedule::new(vec![
        ChurnEvent { at: SimTime::from_secs_f64(2.0), node: 30, kind: ChurnKind::Join },
        ChurnEvent { at: SimTime::from_secs_f64(5.0), node: 30, kind: ChurnKind::Crash },
    ]);
    assert!(registry.build(&spec, None, churn).is_ok());
}

#[test]
fn legacy_flat_fixture_parses_into_sections() {
    // A verbatim pre-scenario config file (the old SessionSpec vocabulary).
    let flat = r#"{
        "dataset": "mock",
        "algo": "fedavg",
        "nodes": 14,
        "scale": 0.3,
        "s": 5,
        "a": 2,
        "sf": 0.9,
        "dt_s": 1.0,
        "dk": 10,
        "max_time_s": 200.0,
        "max_rounds": 20,
        "eval_interval_s": 5.0,
        "target_metric": null,
        "seed": 99,
        "bandwidth_mbps": 20.0,
        "bandwidth_sigma": 0.5,
        "base_batch_s": 0.04,
        "hetero_sigma": 0.1,
        "artifacts_dir": "artifacts"
    }"#;
    let spec = ScenarioSpec::from_json(flat).unwrap();
    assert_eq!(spec.workload.dataset, "mock");
    assert_eq!(spec.protocol.name, "fedavg");
    assert_eq!(spec.population.nodes, 14);
    assert_eq!(spec.protocol.s, 5);
    assert_eq!(spec.protocol.sf, 0.9);
    assert_eq!(spec.run.max_rounds, 20);
    assert_eq!(spec.run.seed, 99);
    assert_eq!(spec.run.target_metric, None);
    assert_eq!(spec.network.bandwidth_mbps, 20.0);
    assert_eq!(spec.network.bandwidth_sigma, 0.5);
    assert_eq!(spec.population.base_batch_s, 0.04);
    assert_eq!(spec.population.hetero_sigma, 0.1);
}

#[test]
fn flat_and_nested_translations_run_identically_same_seed() {
    // The compatibility shim must not just parse — it must reproduce the
    // exact same session: same events, same curve bits, same bytes.
    let flat = r#"{
        "dataset": "mock", "algo": "modest", "nodes": 14, "s": 4, "a": 2,
        "sf": 1.0, "max_time_s": 150.0, "max_rounds": 12,
        "eval_interval_s": 5.0, "seed": 7,
        "bandwidth_mbps": 25.0, "bandwidth_sigma": 0.4
    }"#;
    let nested = r#"{
        "workload": {"dataset": "mock"},
        "population": {"nodes": 14},
        "protocol": {"name": "modest", "s": 4, "a": 2, "sf": 1.0},
        "run": {"max_time_s": 150.0, "max_rounds": 12,
                "eval_interval_s": 5.0, "seed": 7},
        "network": {"bandwidth_mbps": 25.0, "bandwidth_sigma": 0.4}
    }"#;
    let spec_flat = ScenarioSpec::from_json(flat).unwrap();
    let spec_nested = ScenarioSpec::from_json(nested).unwrap();
    assert_eq!(spec_flat, spec_nested, "translations parse differently");
    let (mf, tf) = run_scenario(&spec_flat, None, ChurnSchedule::empty()).unwrap();
    let (mn, tn) = run_scenario(&spec_nested, None, ChurnSchedule::empty()).unwrap();
    assert_eq!(fingerprint(&mf, &tf), fingerprint(&mn, &tn));
}

#[test]
fn nested_network_classes_drive_asymmetric_fabric() {
    // The ROADMAP item: asymmetric class tiers expressible in config, end
    // to end through the fabric.
    let spec = ScenarioSpec::from_json(
        r#"{
            "workload": {"dataset": "mock"},
            "population": {"nodes": 32},
            "network": {"classes": [
                {"name": "fiber", "weight": 1.0, "up_mbps": 100.0, "down_mbps": 300.0},
                {"name": "dsl",   "weight": 1.0, "up_mbps": 1.5,   "down_mbps": 12.0}
            ]}
        }"#,
    )
    .unwrap();
    let fabric = spec.build_fabric(32).unwrap();
    let mut asym = 0;
    let mut tiers = std::collections::BTreeSet::new();
    for n in 0..32u32 {
        if fabric.down_bps(n) > fabric.up_bps(n) {
            asym += 1;
        }
        tiers.insert(fabric.up_bps(n) as u64);
    }
    assert_eq!(asym, 32, "every node must have down > up in these tiers");
    assert_eq!(tiers.len(), 2, "both tiers must be sampled: {tiers:?}");
}

#[test]
fn scenario_with_classes_runs_end_to_end() {
    let mut spec = short_mock("modest");
    spec.network.classes = vec![
        modest_dl::scenario::TierSpec {
            name: "cable".into(),
            weight: 1.0,
            up_mbps: 10.0,
            down_mbps: 100.0,
        },
        modest_dl::scenario::TierSpec {
            name: "dsl".into(),
            weight: 1.0,
            up_mbps: 1.5,
            down_mbps: 12.0,
        },
    ];
    let (m, t) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
    assert!(m.final_round >= 5, "round {}", m.final_round);
    assert!(t.is_conserved());
}

#[test]
fn per_node_trace_file_round_trips_through_the_fabric() {
    let dir = std::env::temp_dir().join(format!("scenario_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    std::fs::write(&path, "up_mbps,down_mbps\n10,100\n2,16\n50,50\n").unwrap();
    let mut spec = short_mock("modest");
    spec.network.trace_file = Some(path.to_string_lossy().into_owned());
    let fabric = spec.build_fabric(4).unwrap();
    assert_eq!(fabric.up_bps(0), 10e6);
    assert_eq!(fabric.down_bps(1), 16e6);
    // Nodes beyond the trace reuse the last entry.
    assert_eq!(fabric.up_bps(3), 50e6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn typoed_protocol_params_fail_loudly() {
    // `params` typos must not silently fall back to defaults: a gossip run
    // asking for "fanuot": 8 would otherwise run with fanout 2.
    let mut spec = short_mock("gossip");
    spec.protocol.params = vec![("fanuot".into(), 8.0)];
    let err = run_scenario(&spec, None, ChurnSchedule::empty())
        .unwrap_err()
        .to_string();
    assert!(err.contains("fanuot"), "{err}");
    assert!(err.contains("fanout"), "should list known params: {err}");
    // The correctly-spelled param is accepted.
    spec.protocol.params = vec![("fanout".into(), 3.0)];
    assert!(run_scenario(&spec, None, ChurnSchedule::empty()).is_ok());
    // Protocols that declare no params reject any param.
    let mut spec = short_mock("modest");
    spec.protocol.params = vec![("fanout".into(), 3.0)];
    assert!(run_scenario(&spec, None, ChurnSchedule::empty()).is_err());
}

#[test]
fn invalid_param_values_fail_loudly() {
    // A fanout of 0 (or a fractional one) must error, not silently clamp.
    for bad in [0.0, -1.0, 2.5] {
        let mut spec = short_mock("gossip");
        spec.protocol.params = vec![("fanout".into(), bad)];
        assert!(
            run_scenario(&spec, None, ChurnSchedule::empty()).is_err(),
            "fanout {bad} was accepted"
        );
    }
}

#[test]
fn registry_rejects_unknown_protocols_with_catalog() {
    let spec = ScenarioSpec::new("mock", "no-such-protocol");
    let err = run_scenario(&spec, None, ChurnSchedule::empty())
        .unwrap_err()
        .to_string();
    assert!(err.contains("no-such-protocol"), "{err}");
    assert!(err.contains("modest"), "error should list the catalog: {err}");
}
