//! Property-based tests on coordinator invariants.
//!
//! The image is offline (no proptest crate), so properties are checked over
//! hundreds of seeded random cases drawn from the project's own `SimRng` —
//! same spirit: random structure generation + invariant assertion, fully
//! deterministic per seed.

use modest_dl::modest::registry::MembershipEvent;
use modest_dl::modest::sampler::{candidate_order, sample_hash};
use modest_dl::modest::{ActivityClock, Registry, View};
use modest_dl::net::{BandwidthConfig, LatencyMatrix, MsgKind, NetworkFabric};
use modest_dl::sim::{EventQueue, Population, SimRng, SimTime};
use modest_dl::NodeId;

const CASES: u64 = 300;

fn random_registry(rng: &mut SimRng, nodes: u64, ops: usize) -> Registry {
    let mut r = Registry::new();
    for _ in 0..ops {
        let node = rng.gen_range(nodes) as NodeId;
        let counter = rng.gen_range(10) + 1;
        // Protocol invariant (Alg. 2): the counter is incremented only by
        // the node itself, so a given (node, counter) pair corresponds to
        // exactly ONE event network-wide. Derive it deterministically —
        // generating conflicting events for equal counters would test a
        // state no execution can produce.
        let ev = if sample_hash(node, counter) & 1 == 0 {
            MembershipEvent::Joined
        } else {
            MembershipEvent::Left
        };
        r.update(node, counter, ev);
    }
    r
}

fn random_activity(rng: &mut SimRng, nodes: u64, ops: usize) -> ActivityClock {
    let mut a = ActivityClock::new();
    for _ in 0..ops {
        a.update(rng.gen_range(nodes) as NodeId, rng.gen_range(50));
    }
    a
}

// ---------------------------------------------------------------- registry

#[test]
fn prop_registry_merge_commutative() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed);
        let a = random_registry(&mut rng, 20, 15);
        let b = random_registry(&mut rng, 20, 15);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba, "seed {seed}");
    }
}

#[test]
fn prop_registry_merge_associative() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xa550);
        let a = random_registry(&mut rng, 16, 12);
        let b = random_registry(&mut rng, 16, 12);
        let c = random_registry(&mut rng, 16, 12);
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "seed {seed}");
    }
}

#[test]
fn prop_registry_merge_idempotent() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0x1de5);
        let a = random_registry(&mut rng, 16, 20);
        let b = random_registry(&mut rng, 16, 20);
        let mut once = a.clone();
        once.merge(&b);
        let mut twice = once.clone();
        twice.merge(&b);
        assert_eq!(once, twice, "seed {seed}");
    }
}

#[test]
fn prop_registry_counter_monotone() {
    // After any update sequence, the stored counter per node is the max
    // counter ever accepted.
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xc0de);
        let mut r = Registry::new();
        let mut max_seen: std::collections::BTreeMap<NodeId, u64> = Default::default();
        for _ in 0..30 {
            let node = rng.gen_range(8) as NodeId;
            let counter = rng.gen_range(20) + 1;
            r.update(node, counter, MembershipEvent::Joined);
            let e = max_seen.entry(node).or_insert(0);
            *e = (*e).max(counter);
        }
        for (&node, &cmax) in &max_seen {
            assert_eq!(r.get(node).unwrap().0, cmax, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------- activity

#[test]
fn prop_activity_merge_is_pointwise_max() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xac71);
        let a = random_activity(&mut rng, 16, 25);
        let b = random_activity(&mut rng, 16, 25);
        let mut m = a.clone();
        m.merge(&b);
        for node in 0..16u32 {
            let expect = match (a.get(node), b.get(node)) {
                (Some(x), Some(y)) => Some(x.max(y)),
                (Some(x), None) => Some(x),
                (None, Some(y)) => Some(y),
                (None, None) => None,
            };
            assert_eq!(m.get(node), expect, "seed {seed} node {node}");
        }
    }
}

#[test]
fn prop_activity_estimate_never_exceeds_true_round() {
    // Simulate a network where the true round advances and estimates are
    // gossiped: no node's estimate may exceed the true round (logical-clock
    // property from §3.5).
    for seed in 0..50 {
        let mut rng = SimRng::new(seed ^ 0xe571);
        let n = 10usize;
        let mut clocks: Vec<ActivityClock> = (0..n).map(|_| ActivityClock::new()).collect();
        let mut true_round = 0u64;
        for _ in 0..200 {
            match rng.gen_range(3) {
                0 => {
                    // a node participates in a new round
                    true_round += 1;
                    let i = rng.gen_range(n as u64) as usize;
                    clocks[i].update(i as NodeId, true_round);
                }
                1 => {
                    // gossip merge between two nodes
                    let i = rng.gen_range(n as u64) as usize;
                    let j = rng.gen_range(n as u64) as usize;
                    let cj = clocks[j].clone();
                    clocks[i].merge(&cj);
                }
                _ => {
                    // a node records an estimate for another node
                    let i = rng.gen_range(n as u64) as usize;
                    let j = rng.gen_range(n as u64) as NodeId;
                    let est = clocks[i].estimate();
                    clocks[i].update(j, est);
                }
            }
            for c in &clocks {
                assert!(c.estimate() <= true_round, "seed {seed}");
            }
        }
    }
}

// ----------------------------------------------------------------- sampler

#[test]
fn prop_sampler_deterministic_and_permutation() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0x5a3b);
        let n = 1 + rng.gen_range(60) as usize;
        let round = rng.gen_range(1000);
        let cands: Vec<NodeId> = (0..n as NodeId).collect();
        let o1 = candidate_order(round, &cands);
        let o2 = candidate_order(round, &cands);
        assert_eq!(o1, o2);
        let mut sorted = o1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, cands, "seed {seed}");
    }
}

#[test]
fn prop_sampler_mostly_consistent() {
    // Views differing in z nodes yield samples overlapping in >= s - z.
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0x3c3c);
        let n = 30 + rng.gen_range(70) as usize;
        let s = 5 + rng.gen_range(10) as usize;
        let z = 1 + rng.gen_range(3) as usize;
        let round = rng.gen_range(500);
        let full: Vec<NodeId> = (0..n as NodeId).collect();
        let mut missing = full.clone();
        for _ in 0..z {
            let idx = rng.gen_range(missing.len() as u64) as usize;
            missing.remove(idx);
        }
        let sa: Vec<NodeId> = candidate_order(round, &full).into_iter().take(s).collect();
        let sb: Vec<NodeId> = candidate_order(round, &missing).into_iter().take(s).collect();
        let overlap = sa.iter().filter(|x| sb.contains(x)).count();
        assert!(overlap + z >= s, "seed {seed}: overlap {overlap}, z {z}, s {s}");
    }
}

#[test]
fn prop_sample_hash_no_trivial_collisions() {
    // Across a realistic population x round grid, collisions should be
    // essentially absent (64-bit hash).
    let mut seen = std::collections::HashSet::new();
    for node in 0..500u32 {
        for round in 0..50u64 {
            seen.insert(sample_hash(node, round));
        }
    }
    assert_eq!(seen.len(), 500 * 50);
}

// --------------------------------------------------------------------- DES

#[test]
fn prop_event_queue_total_order() {
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xde5);
        let mut q = EventQueue::new();
        for i in 0..100u64 {
            q.schedule_at(SimTime::from_micros(rng.gen_range(1000)), i);
        }
        let mut last = SimTime::ZERO;
        let mut count = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last, "seed {seed}");
            last = t;
            count += 1;
        }
        assert_eq!(count, 100);
    }
}

// ------------------------------------------------------------------ fabric

fn random_fabric(rng: &mut SimRng, nodes: usize) -> NetworkFabric {
    let bw = match rng.gen_range(3) {
        0 => BandwidthConfig::Uniform { bps: 1e4 + rng.next_f64() * 1e6 },
        1 => BandwidthConfig::LogNormal {
            median_bps: 1e5 + rng.next_f64() * 1e6,
            sigma: 0.2 + rng.next_f64(),
        },
        _ => BandwidthConfig::PerNode {
            up_bps: (0..nodes).map(|_| 1e4 + rng.next_f64() * 1e6).collect(),
            down_bps: (0..nodes).map(|_| 1e4 + rng.next_f64() * 1e6).collect(),
        },
    };
    let latency = LatencyMatrix::uniform(nodes, SimTime::from_millis(rng.gen_range(50) + 1));
    NetworkFabric::new(latency, &bw, nodes, rng)
}

#[test]
fn prop_fabric_uplink_fifo_never_overlaps() {
    // Random transfer schedules: the uplink occupancy windows of any one
    // sender must be non-overlapping and in schedule order, and delivery
    // on any one downlink must be serialized too.
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xfab1);
        let nodes = 3 + rng.gen_range(8) as usize;
        let mut fabric = random_fabric(&mut rng, nodes);
        let mut now = SimTime::ZERO;
        // Every occupancy window per link, for the overlap checks.
        let mut windows: Vec<Vec<(SimTime, SimTime)>> = vec![Vec::new(); nodes];
        let mut deliver_windows: Vec<Vec<(SimTime, SimTime)>> = vec![Vec::new(); nodes];
        for _ in 0..60 {
            now += SimTime::from_micros(rng.gen_range(200_000));
            let from = rng.gen_range(nodes as u64) as NodeId;
            let mut to = rng.gen_range(nodes as u64) as NodeId;
            if to == from {
                to = (to + 1) % nodes as NodeId;
            }
            let bytes = 100 + rng.gen_range(1_000_000);
            let plan = fabric.plan(now, from, to, bytes);
            assert!(plan.up_start >= now, "seed {seed}");
            assert!(plan.up_end >= plan.up_start, "seed {seed}");
            assert!(plan.down_end >= plan.down_start, "seed {seed}");
            assert!(plan.delivered >= plan.down_end, "seed {seed}");
            assert!(plan.delivered >= plan.up_end, "seed {seed}");
            // Uplink FIFO: the new window starts at/after every prior end.
            for &(_, prev_end) in &windows[from as usize] {
                assert!(
                    plan.up_start >= prev_end,
                    "seed {seed}: uplink windows overlap ({prev_end:?} vs {:?})",
                    plan.up_start
                );
            }
            windows[from as usize].push((plan.up_start, plan.up_end));
            // Downlink FIFO: occupancy windows [down_start, down_end] on
            // one downlink never overlap.
            for &(_, prev_end) in &deliver_windows[to as usize] {
                assert!(
                    plan.down_start >= prev_end,
                    "seed {seed}: downlink windows overlap"
                );
            }
            deliver_windows[to as usize].push((plan.down_start, plan.down_end));
        }
    }
}

#[test]
fn prop_fabric_charged_bytes_equal_ledger_bytes() {
    // Every byte scheduled through link capacity must appear in the ledger
    // exactly once (and be conserved between senders and receivers).
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0xfab2);
        let nodes = 2 + rng.gen_range(6) as usize;
        let mut fabric = random_fabric(&mut rng, nodes);
        let mut expected = 0u64;
        let mut now = SimTime::ZERO;
        for _ in 0..40 {
            now += SimTime::from_micros(rng.gen_range(100_000));
            let from = rng.gen_range(nodes as u64) as NodeId;
            let mut to = rng.gen_range(nodes as u64) as NodeId;
            if to == from {
                to = (to + 1) % nodes as NodeId;
            }
            let model = rng.gen_range(100_000) + 1;
            let control = rng.gen_range(500);
            let parts: Vec<(MsgKind, u64)> = if control == 0 {
                vec![(MsgKind::ModelPayload, model)]
            } else {
                vec![(MsgKind::ModelPayload, model), (MsgKind::Control, control)]
            };
            expected += model + control;
            fabric.transfer(now, from, to, &parts);
        }
        assert_eq!(fabric.charged_bytes(), expected, "seed {seed}");
        let ledger = fabric.ledger();
        assert_eq!(ledger.total(), expected, "seed {seed}");
        assert!(ledger.is_conserved(), "seed {seed}");
    }
}

// -------------------------------------------------------------------- view

#[test]
fn prop_view_candidates_sound_and_complete() {
    // Every candidate is registered and recently active; every registered
    // + recently-active node is a candidate.
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0x71e3);
        let mut v = View::default();
        let n = 24u32;
        for node in 0..n {
            if rng.next_f64() < 0.8 {
                v.registry.update(node, 1, MembershipEvent::Joined);
            } else {
                v.registry.update(node, 1, MembershipEvent::Left);
            }
            if rng.next_f64() < 0.9 {
                v.activity.update(node, rng.gen_range(40));
            }
        }
        let k = 30u64;
        let dk = 20u64;
        let cands = v.candidates(k, dk);
        for node in 0..n {
            let expect = v.registry.is_registered(node)
                && v.activity.get(node).map(|r| r + dk > k).unwrap_or(false);
            assert_eq!(cands.contains(&node), expect, "seed {seed} node {node}");
        }
    }
}

#[test]
fn prop_view_merge_preserves_knowledge() {
    // Merging views never loses a known node.
    for seed in 0..CASES {
        let mut rng = SimRng::new(seed ^ 0x9e99);
        let mut a = View::default();
        let mut b = View::default();
        for node in 0..16u32 {
            if rng.next_f64() < 0.5 {
                a.registry.update(node, 1, MembershipEvent::Joined);
            }
            if rng.next_f64() < 0.5 {
                b.registry.update(node, 1, MembershipEvent::Joined);
            }
        }
        let known_before: Vec<NodeId> =
            (0..16u32).filter(|&n| a.registry.knows(n) || b.registry.knows(n)).collect();
        a.merge(&b);
        for n in known_before {
            assert!(a.registry.knows(n), "seed {seed} lost node {n}");
        }
    }
}

// -------------------------------------------------------------- population

#[test]
fn prop_population_fenwick_matches_bitset_oracle() {
    // The Fenwick alive index against a naive bitset through randomized
    // join/leave/crash/recover sequences: alive_count, is_alive, rank,
    // select, alive_ids, and alive_peers must all agree at every step —
    // the structural invariant behind O(k log n) churned peer sampling
    // (the sampling stream itself is pinned separately in
    // tests/sampling_differential.rs).
    for seed in 0..120u64 {
        let mut rng = SimRng::new(seed ^ 0xF3A1);
        let total = 2 + rng.gen_range(64) as usize;
        let initial = rng.gen_range(total as u64 + 1) as usize;
        let mut pop = Population::new(total, initial);
        let mut oracle: Vec<bool> = (0..total).map(|i| i < initial).collect();
        for step in 0..60 {
            let i = rng.gen_range(total as u64) as usize;
            match rng.gen_range(4) {
                // Crash and Leave both land on mark_dead; Join and
                // Recover both land on mark_alive — exactly the harness's
                // churn application.
                0 | 1 => {
                    pop.mark_dead(i);
                    oracle[i] = false;
                }
                _ => {
                    pop.mark_alive(i);
                    oracle[i] = true;
                }
            }
            let alive: Vec<usize> = (0..total).filter(|&j| oracle[j]).collect();
            assert_eq!(pop.alive_count(), alive.len(), "seed {seed} step {step}");
            for j in 0..total {
                assert_eq!(pop.is_alive(j), oracle[j], "seed {seed} step {step} node {j}");
            }
            for probe in [0, i, total / 2, total] {
                let expect = alive.iter().filter(|&&x| x < probe).count();
                assert_eq!(pop.rank(probe), expect, "seed {seed} step {step} rank({probe})");
            }
            for (r, &id) in alive.iter().enumerate() {
                assert_eq!(pop.select(r), id, "seed {seed} step {step} select({r})");
            }
            assert_eq!(pop.alive_ids(), alive, "seed {seed} step {step}");
            let of = rng.gen_range(total as u64) as u32;
            let expect_peers: Vec<u32> =
                alive.iter().map(|&x| x as u32).filter(|&x| x != of).collect();
            assert_eq!(pop.alive_peers(of), expect_peers, "seed {seed} step {step} of={of}");
        }
    }
}
