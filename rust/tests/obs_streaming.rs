//! End-to-end tests of the live progress stream through the scenario
//! layer: every registered protocol armed with `run.progress` writes a
//! JSONL stream whose every line reconciles and whose final line agrees
//! with the session's own terminal metrics — and arming the stream must
//! not perturb the session at all (same RNG draws, same fingerprints, on
//! both queue backends via the CI feature matrix).

use modest_dl::scenario::{run_scenario, ProgressSpec, ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::Json;

/// A small churned mock scenario (the snapshot-differential shape): step
/// availability takes a slice of the population down and up again, so the
/// stream covers an `alive` dip, retries, and mid-run round stalls.
fn churned_spec(protocol: &str) -> ScenarioSpec {
    ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 14, "availability": {{
                "model": "step", "amplitude": 0.3, "period_s": 50.0, "seed": 5}}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            "run": {{"max_time_s": 150.0, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap()
}

fn stream_path(tag: &str) -> std::path::PathBuf {
    let backend = if cfg!(feature = "queue-heap") { "heap" } else { "cal" };
    std::env::temp_dir().join(format!("obs_streaming_{tag}_{backend}.jsonl"))
}

#[test]
fn every_protocol_streams_a_reconciling_jsonl() {
    for name in ProtocolRegistry::builtins().names() {
        let path = stream_path(name);
        let mut spec = churned_spec(name);
        spec.run.progress = Some(ProgressSpec {
            every_s: 10.0,
            out: Some(path.to_string_lossy().into_owned()),
        });
        let (m, ledger) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{name}: progress stream never written: {e}"));
        let _ = std::fs::remove_file(&path);

        let lines: Vec<&str> = text.lines().collect();
        // 150 sim-seconds at 10s cadence (rounds may end the run early,
        // but never before a few ticks) plus the terminal line.
        assert!(lines.len() >= 4, "{name}: only {} progress lines", lines.len());
        let mut prev_t = f64::NEG_INFINITY;
        for l in &lines {
            let j = Json::parse(l).unwrap_or_else(|e| panic!("{name}: bad line {l}: {e}"));
            let t_s = j.field("t_s").unwrap().as_f64().unwrap();
            assert!(t_s >= prev_t, "{name}: sim-time went backwards in {l}");
            prev_t = t_s;
            let total = j.field("bytes_total").unwrap().as_u64().unwrap();
            let good = j.field("bytes_goodput").unwrap().as_u64().unwrap();
            let dropped = j.field("bytes_dropped").unwrap().as_u64().unwrap();
            let retrans = j.field("bytes_retrans").unwrap().as_u64().unwrap();
            assert_eq!(total, good + dropped + retrans, "{name}: no reconcile: {l}");
        }
        // The terminal line agrees with the final metrics/ledger exactly —
        // the stream is the same bookkeeping, not a parallel estimate.
        let last = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(last.field("bytes_total").unwrap().as_u64().unwrap(), ledger.total());
        assert_eq!(
            last.field("rounds").unwrap().as_u64().unwrap(),
            m.final_round,
            "{name}: final line disagrees on rounds"
        );
        assert_eq!(last.field("events").unwrap().as_u64().unwrap(), m.events);
        assert_eq!(
            last.field("peers_est").unwrap().as_u64().unwrap(),
            m.traffic.distinct_peers,
            "{name}: final line disagrees with TrafficSummary.distinct_peers"
        );
        let trainers = last.field("trainers_est").unwrap().as_u64().unwrap();
        assert!(
            (1..=14 + 2).contains(&trainers),
            "{name}: implausible distinct-trainers estimate {trainers} for 14 nodes"
        );
    }
}

#[test]
fn arming_progress_does_not_perturb_the_session() {
    // The acceptance bar for zero observer effect at the scenario layer:
    // with and without `run.progress`, the convergence curve (metric
    // bits), event count, and traffic totals are bit-identical.
    let spec_plain = churned_spec("modest");
    let (m0, t0) = run_scenario(&spec_plain, None, ChurnSchedule::empty()).unwrap();
    let path = stream_path("perturb");
    let mut spec_obs = churned_spec("modest");
    spec_obs.run.progress = Some(ProgressSpec {
        every_s: 7.0,
        out: Some(path.to_string_lossy().into_owned()),
    });
    let (m1, t1) = run_scenario(&spec_obs, None, ChurnSchedule::empty()).unwrap();
    let _ = std::fs::remove_file(&path);
    let bits = |m: &modest_dl::metrics::SessionMetrics| -> Vec<(u64, u64)> {
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect()
    };
    assert_eq!(m0.final_round, m1.final_round);
    assert_eq!(m0.events, m1.events, "progress ticks leaked into the event count");
    assert_eq!(bits(&m0), bits(&m1), "progress stream perturbed the RNG");
    assert_eq!(t0.total(), t1.total());
}

#[test]
fn invalid_progress_specs_fail_loudly_at_build_time() {
    // The scenario boundary rejects a bad progress config before any
    // session state is built, for every protocol's builder path.
    for name in ProtocolRegistry::builtins().names() {
        let mut spec = churned_spec(name);
        spec.run.progress = Some(ProgressSpec { every_s: 0.0, out: None });
        let err = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap_err();
        assert!(
            format!("{err:#}").contains("every_s"),
            "{name}: unhelpful error {err:#}"
        );
        let mut spec = churned_spec(name);
        spec.run.progress = Some(ProgressSpec {
            every_s: 5.0,
            out: Some("/nonexistent_dir_modest_obs/stream.jsonl".into()),
        });
        let err = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap_err();
        assert!(
            format!("{err:#}").contains("not writable"),
            "{name}: unhelpful error {err:#}"
        );
    }
}
