//! Differential tests for the sharded conservative-window scheduler: for
//! every registered protocol, a same-seed session run under `run.threads`
//! of 2 or 4 must produce a fingerprint bit-identical to the
//! single-threaded run — metrics curve bits, event counts, and traffic
//! ledger bytes included — under churn and under burst loss. Runs under
//! both queue backends via the CI feature matrix (`--features queue-heap`
//! swaps the per-shard partitions under the same test body). Also pinned
//! here: snapshots are thread-count-agnostic (a T=4 checkpoint resumes
//! under T=1 and vice versa), and T=1/T=4 progress streams differ in
//! nothing but the non-deterministic `wall_s`/`rss_kb` tail.

use modest_dl::metrics::SessionMetrics;
use modest_dl::net::TrafficLedger;
use modest_dl::scenario::{
    resume_session, run_scenario, ProgressSpec, ProtocolRegistry, ScenarioSpec,
};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::Json;

fn fingerprint(m: &SessionMetrics, t: &TrafficLedger) -> (u64, u64, Vec<(u64, u64)>, u64) {
    (
        m.final_round,
        m.events,
        m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect(),
        t.total(),
    )
}

/// The snapshot-differential churn scenario, reused verbatim so the
/// thread-count axis covers the same dead-node/mid-revival state space.
fn churned_spec(protocol: &str, threads: usize) -> ScenarioSpec {
    let mut spec = ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 14, "availability": {{
                "model": "step", "amplitude": 0.3, "period_s": 50.0, "seed": 5}}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            "run": {{"max_time_s": 150.0, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap();
    spec.run.threads = threads;
    spec
}

/// Churn plus ~20% Gilbert–Elliott burst loss: retransmit timers fire well
/// inside the lookahead window and reliability state spans shards, so a
/// single mis-merged or re-ordered event shifts the drop column.
fn lossy_churned_spec(protocol: &str, threads: usize) -> ScenarioSpec {
    let mut spec = ScenarioSpec::from_json(&format!(
        r#"{{
            "workload": {{"dataset": "mock"}},
            "population": {{"nodes": 14, "availability": {{
                "model": "step", "amplitude": 0.3, "period_s": 50.0, "seed": 5}}}},
            "protocol": {{"name": "{protocol}", "s": 4, "a": 2}},
            "network": {{"loss": {{
                "model": "burst", "p_good": 0.05, "p_bad": 0.5,
                "good_s": 15.0, "bad_s": 7.5,
                "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 8.0,
                "retries": 2}}}},
            "run": {{"max_time_s": 400.0, "max_rounds": 18,
                     "eval_interval_s": 10.0, "seed": 4242}}
        }}"#
    ))
    .unwrap();
    spec.run.threads = threads;
    spec
}

#[test]
fn fingerprints_are_thread_count_invariant_for_every_protocol() {
    for name in ProtocolRegistry::builtins().names() {
        let (m0, t0) =
            run_scenario(&churned_spec(name, 1), None, ChurnSchedule::empty()).unwrap();
        assert!(m0.events > 0 && t0.total() > 0, "{name} did nothing");
        let want = fingerprint(&m0, &t0);
        for threads in [2, 4] {
            let (m, t) =
                run_scenario(&churned_spec(name, threads), None, ChurnSchedule::empty())
                    .unwrap();
            assert_eq!(
                fingerprint(&m, &t),
                want,
                "{name}: T={threads} diverged from the single-threaded run"
            );
        }
    }
}

#[test]
fn lossy_fingerprints_are_thread_count_invariant_for_every_protocol() {
    for name in ProtocolRegistry::builtins().names() {
        let (m0, t0) =
            run_scenario(&lossy_churned_spec(name, 1), None, ChurnSchedule::empty()).unwrap();
        assert!(t0.dropped_bytes() > 0, "{name}: burst loss dropped nothing");
        let want = fingerprint(&m0, &t0);
        for threads in [2, 4] {
            let (m, t) =
                run_scenario(&lossy_churned_spec(name, threads), None, ChurnSchedule::empty())
                    .unwrap();
            assert_eq!(
                fingerprint(&m, &t),
                want,
                "{name}: lossy T={threads} diverged from the single-threaded run"
            );
            assert_eq!(
                (t.dropped_bytes(), t.retransmitted_bytes()),
                (t0.dropped_bytes(), t0.retransmitted_bytes()),
                "{name}: loss columns diverged at T={threads}"
            );
        }
    }
}

fn snap_path(tag: &str) -> std::path::PathBuf {
    let backend = if cfg!(feature = "queue-heap") { "heap" } else { "cal" };
    std::env::temp_dir().join(format!("parallel_diff_{tag}_{backend}.snap"))
}

/// Run `spec` with a checkpoint at `at_s`, returning the snapshot bytes.
fn checkpoint_run(spec: &ScenarioSpec, at_s: f64, tag: &str) -> Vec<u8> {
    let path = snap_path(tag);
    let mut ck = spec.clone();
    ck.run.checkpoint_at_s = Some(at_s);
    ck.run.checkpoint_out = Some(path.to_string_lossy().into_owned());
    let _ = run_scenario(&ck, None, ChurnSchedule::empty()).unwrap();
    let bytes = std::fs::read(&path)
        .unwrap_or_else(|e| panic!("checkpoint at t={at_s}s was never written ({tag}): {e}"));
    let _ = std::fs::remove_file(&path);
    bytes
}

/// Snapshots are thread-count-agnostic: a session checkpointed under T=4
/// must resume under T=1 (and vice versa) and still land on the
/// single-threaded fingerprint. The resumed run's thread count comes from
/// a `{"run": {"threads": N}}` overlay merged over the embedded spec.
#[test]
fn checkpoints_cross_restore_between_thread_counts() {
    for name in ProtocolRegistry::builtins().names() {
        let spec = lossy_churned_spec(name, 1);
        let (m0, t0) = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        let want = fingerprint(&m0, &t0);
        let at_s = m0.duration_s * 0.5;
        for (ck_threads, resume_threads) in [(4, 1), (1, 4)] {
            let bytes = checkpoint_run(
                &lossy_churned_spec(name, ck_threads),
                at_s,
                &format!("{name}_t{ck_threads}"),
            );
            let overlay = format!(r#"{{"run": {{"threads": {resume_threads}}}}}"#);
            let (spec2, session) =
                resume_session(&bytes, Some(&overlay), None, None).unwrap();
            assert_eq!(spec2.run.threads, resume_threads, "{name}: overlay did not apply");
            let (m1, t1) = session.run();
            assert_eq!(
                fingerprint(&m1, &t1),
                want,
                "{name}: T={ck_threads} checkpoint resumed under T={resume_threads} \
                 diverged from the uninterrupted single-threaded run"
            );
        }
    }
}

/// The live progress stream is part of the determinism contract: between a
/// T=1 and a T=4 run, the ONLY fields allowed to differ are the
/// non-deterministic wall-clock tail (`wall_s`, `rss_kb`) — event
/// counters, byte columns, and estimator sketches are merged globally,
/// never per-shard.
#[test]
fn progress_streams_differ_only_in_wall_clock_fields() {
    let backend = if cfg!(feature = "queue-heap") { "heap" } else { "cal" };
    let mut streams = Vec::new();
    for threads in [1usize, 4] {
        let path = std::env::temp_dir()
            .join(format!("parallel_diff_progress_t{threads}_{backend}.jsonl"));
        let mut spec = churned_spec("modest", threads);
        spec.run.progress = Some(ProgressSpec {
            every_s: 10.0,
            out: Some(path.to_string_lossy().into_owned()),
        });
        let _ = run_scenario(&spec, None, ChurnSchedule::empty()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        streams.push(text);
    }
    let (a, b) = (&streams[0], &streams[1]);
    assert!(a.lines().count() >= 4, "only {} progress lines", a.lines().count());
    assert_eq!(a.lines().count(), b.lines().count(), "line counts diverged");
    for (i, (la, lb)) in a.lines().zip(b.lines()).enumerate() {
        let ja = Json::parse(la).unwrap();
        let jb = Json::parse(lb).unwrap();
        let fa = ja.as_obj().unwrap();
        let fb = jb.as_obj().unwrap();
        let keys = |f: &[(String, Json)]| -> Vec<String> {
            f.iter().map(|(k, _)| k.clone()).collect()
        };
        assert_eq!(keys(fa), keys(fb), "line {i}: field sets diverged");
        for ((k, va), (_, vb)) in fa.iter().zip(fb.iter()) {
            if k == "wall_s" || k == "rss_kb" {
                continue;
            }
            assert_eq!(va, vb, "line {i}: field {k:?} diverged between T=1 and T=4");
        }
    }
}
