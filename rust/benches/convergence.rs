//! End-to-end bench regenerating the paper's Fig. 3 / Fig. 1 rows (scaled).
//!
//! Runs FedAvg, D-SGD and MoDeST on the CIFAR10-sized task (real artifacts
//! when available, mock otherwise) and prints the time-to-target /
//! best-metric rows the figure is built from, plus the wallclock cost of
//! each simulated session.
//!
//! Run: `cargo bench --bench convergence`
//! (larger replication: `repro exp fig3 --scale 1.0`)

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::bench::Bencher;

fn main() {
    let have_artifacts = modest_dl::runtime::XlaRuntime::load("artifacts").is_ok();
    let dataset = if have_artifacts { "cifar10" } else { "mock" };
    let runtime = if have_artifacts {
        Some(modest_dl::runtime::XlaRuntime::load("artifacts").unwrap())
    } else {
        None
    };
    println!("== Fig. 3 bench (dataset: {dataset}) ==");
    let mut b = Bencher::new("convergence");
    let mut rows = Vec::new();
    for algo in [Algo::Fedavg, Algo::Dsgd, Algo::Modest] {
        let spec = SessionSpec {
            dataset: dataset.into(),
            algo,
            nodes: 24,
            s: 8,
            a: 3,
            sf: 1.0,
            max_rounds: if algo == Algo::Dsgd { 60 } else { 120 },
            max_time_s: 7200.0,
            eval_interval_s: 10.0,
            ..Default::default()
        };
        let mut result = None;
        b.bench_once(&format!("session/{algo:?}"), || {
            let out = match algo {
                Algo::Dsgd => spec.build_dsgd(runtime.as_ref()).unwrap().run(),
                _ => spec
                    .build_modest(runtime.as_ref(), ChurnSchedule::empty())
                    .unwrap()
                    .run(),
            };
            result = Some(out);
        });
        let (m, _) = result.unwrap();
        rows.push((algo, m));
    }
    println!();
    println!(
        "{:<8} {:>7} {:>10} {:>14} {:>12}",
        "algo", "rounds", "best", "t-to-0.75", "virtual-dur"
    );
    for (algo, m) in &rows {
        println!(
            "{:<8} {:>7} {:>10.4} {:>14} {:>11.0}s",
            format!("{algo:?}"),
            m.final_round,
            m.best_metric(true).unwrap_or(f64::NAN),
            m.time_to_target(0.75, true)
                .map(|(t, _)| format!("{t:.0}s"))
                .unwrap_or_else(|| "-".into()),
            m.duration_s
        );
    }
    println!();
    println!("expected shape: MoDeST ~ FedAvg time-to-target; D-SGD behind.");
    b.finish();
}
