//! End-to-end bench regenerating the paper's Fig. 3 / Fig. 1 rows (scaled).
//!
//! Runs FedAvg, D-SGD and MoDeST on the CIFAR10-sized task (real artifacts
//! when available, mock otherwise) through the scenario registry and
//! prints the time-to-target / best-metric rows the figure is built from,
//! plus the wallclock cost of each simulated session.
//!
//! Run: `cargo bench --bench convergence`
//! (larger replication: `repro exp fig3 --scale 1.0`)

use modest_dl::scenario::{ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::bench::Bencher;

fn main() {
    let have_artifacts = modest_dl::runtime::XlaRuntime::load("artifacts").is_ok();
    let dataset = if have_artifacts { "cifar10" } else { "mock" };
    let runtime = if have_artifacts {
        Some(modest_dl::runtime::XlaRuntime::load("artifacts").unwrap())
    } else {
        None
    };
    let registry = ProtocolRegistry::builtins();
    println!("== Fig. 3 bench (dataset: {dataset}) ==");
    let mut b = Bencher::new("convergence");
    let mut rows = Vec::new();
    // (protocol, bench round budget): every-node-per-round protocols get
    // half the rounds to keep the bench quick.
    for (protocol, rounds) in [("fedavg", 120), ("dsgd", 60), ("modest", 120)] {
        let label = registry.label(protocol).unwrap();
        let mut spec = ScenarioSpec::new(dataset, protocol);
        spec.population.nodes = 24;
        spec.protocol.s = 8;
        spec.protocol.a = 3;
        spec.protocol.sf = 1.0;
        spec.run.max_rounds = rounds;
        spec.run.max_time_s = 7200.0;
        spec.run.eval_interval_s = 10.0;
        let mut result = None;
        b.bench_once(&format!("session/{label}"), || {
            let out = registry
                .build(&spec, runtime.as_ref(), ChurnSchedule::empty())
                .unwrap()
                .run();
            result = Some(out);
        });
        let (m, _) = result.unwrap();
        rows.push((label, m));
    }
    println!();
    println!(
        "{:<8} {:>7} {:>10} {:>14} {:>12}",
        "protocol", "rounds", "best", "t-to-0.75", "virtual-dur"
    );
    for (label, m) in &rows {
        println!(
            "{:<8} {:>7} {:>10.4} {:>14} {:>11.0}s",
            label,
            m.final_round,
            m.best_metric(true).unwrap_or(f64::NAN),
            m.time_to_target(0.75, true)
                .map(|(t, _)| format!("{t:.0}s"))
                .unwrap_or_else(|| "-".into()),
            m.duration_s
        );
    }
    println!();
    println!("expected shape: MoDeST ~ FedAvg time-to-target; D-SGD behind.");
    b.finish();
}
