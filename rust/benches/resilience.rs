//! End-to-end bench regenerating the paper's Fig. 6 (scaled): progress and
//! sample times while 80% of the network crashes.
//!
//! Run: `cargo bench --bench resilience`
//! (paper-scale replication: `repro exp fig6 --nodes 100`)

use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, SimTime};
use modest_dl::util::bench::Bencher;

fn main() {
    println!("== Fig. 6 bench: mass-crash resilience (mock task, 40 nodes) ==");
    let mut b = Bencher::new("resilience");
    let nodes = 40u32;
    let survivors = 8u32;
    let crash_start = 120.0;
    let churn = ChurnSchedule::mass_crash(
        nodes,
        survivors,
        4,
        SimTime::from_secs_f64(crash_start),
        SimTime::from_secs_f64(15.0),
    );
    let mut spec = ScenarioSpec::new("mock", "modest");
    spec.population.nodes = nodes as usize;
    spec.protocol.s = 8;
    spec.protocol.a = 5;
    spec.protocol.sf = 0.75;
    spec.protocol.dt_s = 2.0;
    spec.protocol.dk = 10;
    spec.run.max_time_s = 600.0;
    spec.run.eval_interval_s = 5.0;
    let mut out = None;
    b.bench_once("session/crash-80pct", || {
        out = Some(run_scenario(&spec, None, churn.clone()).unwrap());
    });
    let (m, _) = out.unwrap();

    // Bucket sample durations by phase.
    let crash_end = crash_start + 15.0 * ((nodes - survivors) as f64 / 4.0);
    let mut phases = [(0usize, 0f64, 0f64); 3]; // count, sum, max
    for s in &m.samples {
        let idx = if s.completed_at_s < crash_start {
            0
        } else if s.completed_at_s < crash_end + 60.0 {
            1
        } else {
            2
        };
        phases[idx].0 += 1;
        phases[idx].1 += s.duration_s;
        phases[idx].2 = phases[idx].2.max(s.duration_s);
    }
    println!();
    println!("{:<22} {:>8} {:>12} {:>10}", "phase", "samples", "mean-dur", "max-dur");
    for (label, (n, sum, max)) in
        ["pre-crash", "crashing(+60s)", "recovered"].iter().zip(phases)
    {
        println!(
            "{:<22} {:>8} {:>11.2}s {:>9.2}s",
            label,
            n,
            if n > 0 { sum / n as f64 } else { f64::NAN },
            max
        );
    }
    let last_round = m.round_starts.last().unwrap_or((0, 0.0));
    println!();
    println!(
        "progress: round {} at t={:.0}s (crashes ended ~{crash_end:.0}s); best metric {:.3}",
        last_round.0,
        last_round.1,
        m.best_metric(true).unwrap_or(f64::NAN)
    );
    println!("expected shape: sample durations bump during the crash window, then");
    println!("recover once the Δk activity window flags dead nodes (paper Fig. 6).");
    b.finish();
}
