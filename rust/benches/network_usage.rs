//! End-to-end bench regenerating the paper's Table 4 / Table 1 rows
//! (scaled): total / min / max per-node traffic for the three algorithms
//! plus the MoDeST overhead fraction.
//!
//! Run: `cargo bench --bench network_usage`
//! (full grid: `repro exp table4 --scale 1.0`)

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::net::traffic::fmt_bytes;
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::bench::Bencher;

fn main() {
    let runtime = modest_dl::runtime::XlaRuntime::load("artifacts").ok();
    let dataset = if runtime.is_some() { "celeba" } else { "mock" };
    println!("== Table 4 bench (dataset: {dataset}, 40 nodes, 80 rounds) ==");
    let mut b = Bencher::new("network_usage");
    let mut rows = Vec::new();
    for algo in [Algo::Dsgd, Algo::Fedavg, Algo::Modest] {
        let spec = SessionSpec {
            dataset: dataset.into(),
            algo,
            nodes: 40,
            // Keep s(a+1) well under n: MoDeST's advantage over D-SGD is
            // the n-vs-s(a+1) per-round transfer count (EXPERIMENTS.md
            // scale note) — s=6, a=2 gives 18 transfers/round vs 40.
            s: 6,
            a: 2,
            sf: 1.0,
            max_rounds: 80,
            max_time_s: 7200.0,
            ..Default::default()
        };
        let mut out = None;
        b.bench_once(&format!("session/{algo:?}"), || {
            out = Some(match algo {
                Algo::Dsgd => spec.build_dsgd(runtime.as_ref()).unwrap().run(),
                _ => spec
                    .build_modest(runtime.as_ref(), ChurnSchedule::empty())
                    .unwrap()
                    .run(),
            });
        });
        rows.push((algo, out.unwrap().0));
    }
    println!();
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10}",
        "method", "total", "min", "max", "overhead"
    );
    for (algo, m) in &rows {
        let t = &m.traffic;
        println!(
            "{:<8} {:>12} {:>12} {:>12} {:>9.1}%",
            format!("{algo:?}"),
            fmt_bytes(t.total),
            fmt_bytes(t.min_node),
            fmt_bytes(t.max_node),
            100.0 * t.overhead_fraction
        );
    }
    let total = |a: Algo| {
        rows.iter()
            .find(|(x, _)| *x == a)
            .map(|(_, m)| m.traffic.total.max(1))
            .unwrap()
    };
    println!();
    println!(
        "ratios: D-SGD/FedAvg = {:.1}x, D-SGD/MoDeST = {:.1}x (paper: 13-71x, 3-14x)",
        total(Algo::Dsgd) as f64 / total(Algo::Fedavg) as f64,
        total(Algo::Dsgd) as f64 / total(Algo::Modest) as f64,
    );

    // ---- heterogeneous capacity: thin uplinks must stretch rounds (the
    // fabric serializes each node's concurrent sends on its uplink).
    println!();
    println!("== fabric: uniform vs heterogeneous per-node capacity (MoDeST) ==");
    let mut round_times = Vec::new();
    for (label, mbps, sigma) in [("uniform-1mbps", 1.0, 0.0), ("lognormal-sigma1", 1.0, 1.0)] {
        let spec = SessionSpec {
            dataset: "mock".into(),
            algo: Algo::Modest,
            nodes: 40,
            s: 6,
            a: 2,
            sf: 1.0,
            max_rounds: 80,
            max_time_s: 7200.0,
            bandwidth_mbps: mbps,
            bandwidth_sigma: sigma,
            ..Default::default()
        };
        let mut out = None;
        b.bench_once(&format!("fabric/{label}"), || {
            out = Some(spec.build_modest(None, ChurnSchedule::empty()).unwrap().run());
        });
        let (m, _) = out.unwrap();
        let rt = m.mean_round_time_s().unwrap_or(f64::NAN);
        println!("{label:<18} rounds={:<4} mean-round={rt:.3}s", m.final_round);
        round_times.push(rt);
    }
    if round_times.len() == 2 {
        println!(
            "slowdown from capacity heterogeneity: {:.2}x (thin-uplink nodes gate their rounds)",
            round_times[1] / round_times[0]
        );
    }
    b.finish();
}
