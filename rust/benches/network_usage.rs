//! End-to-end bench regenerating the paper's Table 4 / Table 1 rows
//! (scaled): total / min / max per-node traffic for the three algorithms
//! plus the MoDeST overhead fraction.
//!
//! Run: `cargo bench --bench network_usage`
//! (full grid: `repro exp table4 --scale 1.0`)

use modest_dl::net::traffic::fmt_bytes;
use modest_dl::scenario::{ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::bench::Bencher;

fn main() {
    let runtime = modest_dl::runtime::XlaRuntime::load("artifacts").ok();
    let dataset = if runtime.is_some() { "celeba" } else { "mock" };
    let registry = ProtocolRegistry::builtins();
    println!("== Table 4 bench (dataset: {dataset}, 40 nodes, 80 rounds) ==");
    let mut b = Bencher::new("network_usage");
    let mut rows = Vec::new();
    for protocol in ["dsgd", "fedavg", "modest"] {
        let label = registry.label(protocol).unwrap();
        let mut spec = ScenarioSpec::new(dataset, protocol);
        spec.population.nodes = 40;
        // Keep s(a+1) well under n: MoDeST's advantage over D-SGD is
        // the n-vs-s(a+1) per-round transfer count (EXPERIMENTS.md
        // scale note) — s=6, a=2 gives 18 transfers/round vs 40.
        spec.protocol.s = 6;
        spec.protocol.a = 2;
        spec.protocol.sf = 1.0;
        spec.run.max_rounds = 80;
        spec.run.max_time_s = 7200.0;
        let mut out = None;
        b.bench_once(&format!("session/{label}"), || {
            out = Some(
                registry
                    .build(&spec, runtime.as_ref(), ChurnSchedule::empty())
                    .unwrap()
                    .run(),
            );
        });
        rows.push((label, out.unwrap().0));
    }
    println!();
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>10}",
        "method", "total", "min", "max", "overhead"
    );
    for (label, m) in &rows {
        let t = &m.traffic;
        println!(
            "{:<8} {:>12} {:>12} {:>12} {:>9.1}%",
            label,
            fmt_bytes(t.total),
            fmt_bytes(t.min_node),
            fmt_bytes(t.max_node),
            100.0 * t.overhead_fraction
        );
    }
    let total = |label: &str| {
        rows.iter()
            .find(|(x, _)| *x == label)
            .map(|(_, m)| m.traffic.total.max(1))
            .unwrap()
    };
    println!();
    println!(
        "ratios: D-SGD/FedAvg = {:.1}x, D-SGD/MoDeST = {:.1}x (paper: 13-71x, 3-14x)",
        total("D-SGD") as f64 / total("FedAvg") as f64,
        total("D-SGD") as f64 / total("MoDeST") as f64,
    );

    // ---- heterogeneous capacity: thin uplinks must stretch rounds (the
    // fabric serializes each node's concurrent sends on its uplink).
    println!();
    println!("== fabric: uniform vs heterogeneous per-node capacity (MoDeST) ==");
    let mut round_times = Vec::new();
    for (label, mbps, sigma) in [("uniform-1mbps", 1.0, 0.0), ("lognormal-sigma1", 1.0, 1.0)] {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.population.nodes = 40;
        spec.protocol.s = 6;
        spec.protocol.a = 2;
        spec.protocol.sf = 1.0;
        spec.run.max_rounds = 80;
        spec.run.max_time_s = 7200.0;
        spec.network.bandwidth_mbps = mbps;
        spec.network.bandwidth_sigma = sigma;
        let mut out = None;
        b.bench_once(&format!("fabric/{label}"), || {
            out = Some(
                registry
                    .build(&spec, None, ChurnSchedule::empty())
                    .unwrap()
                    .run(),
            );
        });
        let (m, _) = out.unwrap();
        let rt = m.mean_round_time_s().unwrap_or(f64::NAN);
        println!("{label:<18} rounds={:<4} mean-round={rt:.3}s", m.final_round);
        round_times.push(rt);
    }
    if round_times.len() == 2 {
        println!(
            "slowdown from capacity heterogeneity: {:.2}x (thin-uplink nodes gate their rounds)",
            round_times[1] / round_times[0]
        );
    }
    b.finish();
}
