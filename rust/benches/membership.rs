//! End-to-end bench regenerating the paper's Fig. 5 (scaled): how fast a
//! newly joined node's membership propagates to every existing view.
//!
//! Run: `cargo bench --bench membership`
//! (paper-scale replication: `repro exp fig5 --initial 90 --joiners 10`)

use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, SimTime};
use modest_dl::util::bench::Bencher;

fn main() {
    println!("== Fig. 5 bench: join propagation (mock task, 30+4 nodes) ==");
    let mut b = Bencher::new("membership");
    let initial = 30u32;
    let churn = ChurnSchedule::staggered_joins(
        initial,
        4,
        SimTime::from_secs_f64(30.0),
        SimTime::from_secs_f64(30.0),
    );
    let mut spec = ScenarioSpec::new("mock", "modest");
    spec.population.nodes = initial as usize;
    spec.protocol.s = 10;
    spec.protocol.a = 5;
    spec.protocol.sf = 0.9;
    spec.run.max_time_s = 600.0;
    spec.run.eval_interval_s = 2.0;
    let mut out = None;
    b.bench_once("session/30-initial-4-joiners", || {
        out = Some(run_scenario(&spec, None, churn.clone()).unwrap());
    });
    let (m, _) = out.unwrap();
    println!();
    println!("{:>6} {:>10} {:>18} {:>14}", "joiner", "join@", "full-propagation", "~rounds");
    let round_time = m.mean_round_time_s().unwrap_or(1.0);
    for t in &m.joins {
        match t.full_propagation_s() {
            Some(d) => println!(
                "{:>6} {:>9.0}s {:>17.1}s {:>14.0}",
                t.joiner,
                t.joined_at_s,
                d,
                d / round_time
            ),
            None => println!("{:>6} {:>9.0}s {:>18}", t.joiner, t.joined_at_s, "(incomplete)"),
        }
    }
    println!();
    println!(
        "paper: ~n/s rounds per refresh, full propagation ~56 rounds at n=100,s=10;"
    );
    println!(
        "here n={} s=10 -> expect the same n/s scaling (mean round {round_time:.2}s).",
        initial + 4
    );
    b.finish();
}
