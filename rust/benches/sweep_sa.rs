//! End-to-end bench regenerating the paper's Fig. 4 (scaled): time and
//! rounds until target accuracy over the (s, a) grid. Uses the mock task
//! so the sweep finishes in seconds; the real-model sweep is
//! `repro exp fig4`.
//!
//! Run: `cargo bench --bench sweep_sa`

use modest_dl::scenario::{run_scenario, ScenarioSpec};
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::bench::Bencher;

fn main() {
    println!("== Fig. 4 bench: (s, a) sweep on the mock task, 24 nodes ==");
    let mut b = Bencher::new("sweep_sa");
    let target = 0.9;
    println!(
        "{:>3} {:>3} {:>14} {:>16} {:>10}",
        "s", "a", "time-to-target", "rounds-to-target", "best"
    );
    for s in [1usize, 2, 4, 7] {
        for a in [1usize, 3, 5] {
            let mut spec = ScenarioSpec::new("mock", "modest");
            spec.population.nodes = 24;
            spec.protocol.s = s;
            spec.protocol.a = a;
            spec.protocol.sf = 1.0;
            spec.run.max_rounds = 150;
            spec.run.max_time_s = 7200.0;
            spec.run.eval_interval_s = 5.0;
            spec.run.target_metric = Some(target);
            let mut out = None;
            b.bench_once(&format!("session/s={s}/a={a}"), || {
                out = Some(run_scenario(&spec, None, ChurnSchedule::empty()).unwrap());
            });
            let (m, _) = out.unwrap();
            let tt = m.time_to_target(target, true);
            println!(
                "{:>3} {:>3} {:>14} {:>16} {:>10.4}",
                s,
                a,
                tt.map(|(t, _)| format!("{t:.0}s")).unwrap_or_else(|| "-".into()),
                tt.map(|(_, r)| r.to_string()).unwrap_or_else(|| "-".into()),
                m.best_metric(true).unwrap_or(f64::NAN)
            );
        }
    }
    println!();
    println!("expected shape: rounds-to-target falls with s (diminishing past s~4);");
    println!("time-to-target rises with s (stragglers) and falls with a (fast path).");
    b.finish();
}
