//! Microbenchmarks of the L3 hot paths (§Perf):
//!
//! * model aggregation — native mean vs naive indexed loop vs the
//!   XLA/Pallas masked-mean executable (if artifacts are present),
//! * the sampler's per-round hash+sort candidate ordering,
//! * DES event-queue throughput,
//! * registry/view merge, and view wire-size computation.
//!
//! Run: `cargo bench --bench hotpaths` (BENCH_FAST=1 for a smoke pass).

use modest_dl::learning::{aggregate_native, Model};
use modest_dl::modest::registry::MembershipEvent;
use modest_dl::modest::sampler::candidate_order;
use modest_dl::modest::View;
use modest_dl::net::SizeModel;
#[cfg(feature = "xla")]
use modest_dl::runtime::XlaRuntime;
use modest_dl::sim::{EventQueue, SimRng, SimTime};
use modest_dl::util::bench::{black_box, Bencher};
use modest_dl::NodeId;

/// Naive baseline: per-element indexed accumulation (what the optimized
/// `aggregate_native` is measured against).
fn aggregate_naive(models: &[&Model]) -> Model {
    let n = models[0].len();
    let mut out = vec![0f32; n];
    for i in 0..n {
        let mut acc = 0f32;
        for m in models {
            acc += m[i];
        }
        out[i] = acc / models.len() as f32;
    }
    out
}

fn main() {
    let mut b = Bencher::new("hotpaths");
    let mut rng = SimRng::new(42);

    // ---- aggregation: s models x P params (FEMNIST-sized and CIFAR-sized)
    for (label, s, p) in [
        ("aggregate/native/8x1.75M(femnist)", 8usize, 1_754_430usize),
        ("aggregate/native/10x86k(cifar10)", 10, 86_314),
    ] {
        let models: Vec<Model> = (0..s)
            .map(|_| (0..p).map(|_| rng.next_f32()).collect())
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        b.bench(label, || {
            black_box(aggregate_native(black_box(&refs)));
        });
    }
    {
        let s = 8;
        let p = 1_754_430;
        let models: Vec<Model> = (0..s)
            .map(|_| (0..p).map(|_| rng.next_f32()).collect())
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        b.bench("aggregate/naive/8x1.75M(femnist)", || {
            black_box(aggregate_naive(black_box(&refs)));
        });
        // XLA/Pallas path (needs the `xla` feature + artifacts; includes
        // stack copy + PJRT).
        #[cfg(feature = "xla")]
        {
            if let Ok(rt) = XlaRuntime::load("artifacts") {
                if let Ok(v) = rt.variant("femnist") {
                    let slices: Vec<&[f32]> = refs.iter().map(|m| m.as_slice()).collect();
                    b.bench("aggregate/xla-pallas/8x1.75M(femnist)", || {
                        black_box(v.aggregate(black_box(&slices)).unwrap());
                    });
                }
            }
        }
    }

    // ---- sampler ordering at population scales
    for n in [100usize, 1_000, 10_000] {
        let cands: Vec<NodeId> = (0..n as NodeId).collect();
        let mut round = 0u64;
        b.bench(&format!("sampler/candidate_order/n={n}"), || {
            round += 1;
            black_box(candidate_order(round, black_box(&cands)));
        });
    }

    // ---- DES queue throughput: push+pop 10k events
    b.bench("des/queue/10k-events", || {
        let mut q = EventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at(SimTime::from_micros((i * 7919) % 100_000), i);
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        black_box(n);
    });

    // ---- view merge + wire size at population 500 (celeba scale)
    {
        let mut a = View::default();
        let mut c = View::default();
        for node in 0..500u32 {
            a.registry.update(node, 1, MembershipEvent::Joined);
            a.activity.update(node, (node % 60) as u64);
            c.registry.update(node, 2, MembershipEvent::Joined);
            c.activity.update(node, (node % 90) as u64);
        }
        b.bench("view/merge/500-nodes", || {
            let mut m = a.clone();
            m.merge(black_box(&c));
            black_box(m);
        });
        let sizes = SizeModel::default();
        b.bench("view/wire_bytes/500-nodes", || {
            black_box(black_box(&a).wire_bytes(&sizes));
        });
        b.bench("view/candidates/500-nodes", || {
            black_box(black_box(&a).candidates(50, 20));
        });
    }

    b.finish();
}
