//! Microbenchmarks of the L3 hot paths (§Perf):
//!
//! * model aggregation — native chunked mean vs naive indexed loop vs the
//!   XLA/Pallas masked-mean executable (if artifacts are present),
//! * DES event-queue throughput — the classic hold model at 1M events,
//!   calendar backend vs the BinaryHeap shim (the acceptance bar is >= 2x),
//! * zero-copy fan-out — Arc payload sharing vs deep copies, and a 10k-way
//!   broadcast through the contended fabric,
//! * the sampler's per-round hash+sort candidate ordering,
//! * peer sampling — the frozen V1 full shuffle vs the O(k) V2 partial
//!   shuffle at n ∈ {1k, 10k, 100k}, k = 10 (the 100k-node fast path),
//!   plus the churned path (30% dead) through the Population's Fenwick
//!   rank/select index — O(k log n) under v2, no alive-list
//!   materialization,
//! * registry/view merge, and view wire-size computation,
//! * the **memory budget**: live heap bytes per node for a fully-built
//!   gossip session at n ∈ {10k, 100k, 1M}, counted by a wrapping global
//!   allocator (bench binary only) and recorded as `mem/bytes-per-node/*`
//!   value rows — guarded by the CI bench-diff gate like the timings,
//! * **loss fault injection** — the per-transfer drop decision under the
//!   Gilbert–Elliott burst model at n ∈ {10k, 100k} (`loss/decide/*`),
//!   plus a full 64-node lossy gossip sweep exercising the reliable
//!   outbox end-to-end (`reliability/retransmit-sweep/*`) — both guarded,
//! * **checkpoint/restore** at n=100k — full-session snapshot
//!   serialization (`snapshot/write`), the complete resume path
//!   (`snapshot/read`), and the on-disk size (`snapshot/bytes`), all
//!   guarded rows,
//! * **streaming observability** — histogram record and HLL insert on the
//!   per-transfer hot path, plus one full progress-tick render over
//!   100k-sample state (`obs/*`, guarded).
//!
//! Run: `cargo bench --bench hotpaths` (BENCH_FAST=1 for a smoke pass).
//! Results are also written machine-readable to `BENCH_hotpaths.json`
//! (override the path with `BENCH_JSON=...`) so future PRs can track the
//! trajectory.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use modest_dl::gossip::{GossipConfig, GossipSession};
use modest_dl::learning::{aggregate_native, ComputeModel, MockTask, Model};
use modest_dl::modest::node::{Msg, ViewRef};
use modest_dl::modest::registry::MembershipEvent;
use modest_dl::modest::sampler::candidate_order;
use modest_dl::modest::View;
use modest_dl::net::{LatencyMatrix, LossLayer, LossModel, MsgKind, NetworkFabric, SizeModel};
use modest_dl::scenario::{resume_session, run_scenario, ScenarioSpec};
#[cfg(feature = "xla")]
use modest_dl::runtime::XlaRuntime;
use modest_dl::sim::{
    CalendarEventQueue, ChurnSchedule, EventQueue, HeapEventQueue, Hll, Population,
    ProgressLine, ReliabilityConfig, SamplingVersion, SessionQueue, ShardedQueue, SimRng,
    SimTime, StreamHistogram,
};
use modest_dl::util::bench::{black_box, Bencher};
use modest_dl::NodeId;

/// Live-heap-byte counter wrapping the system allocator. Only the bench
/// binary pays the two relaxed atomics per (de)allocation; the library and
/// the test suite run on the plain system allocator.
struct CountingAlloc;

static LIVE_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            LIVE_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        p
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            LIVE_BYTES.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn live_bytes() -> u64 {
    LIVE_BYTES.load(Ordering::Relaxed)
}

/// A fully-assembled no-churn gossip session at `n` nodes (mock task,
/// dim-8 models): what the `mem/bytes-per-node` budget rows measure.
fn mem_probe_session(n: usize) -> GossipSession {
    let cfg = GossipConfig { max_rounds: 3, ..GossipConfig::default() };
    let mut rng = SimRng::new(cfg.seed);
    let task = MockTask::new(n, 8, 0.5, cfg.seed);
    let latency = LatencyMatrix::synthetic(&Default::default(), n, &mut rng);
    let fabric = NetworkFabric::uniform(latency, 50e6, n);
    let compute = ComputeModel::uniform(n, 0.05);
    GossipSession::new(cfg, n, Box::new(task), compute, fabric, ChurnSchedule::empty())
}

/// Naive baseline: per-element indexed accumulation (what the optimized
/// `aggregate_native` is measured against).
fn aggregate_naive(models: &[&Model]) -> Model {
    let n = models[0].len();
    let mut out = vec![0f32; n];
    for i in 0..n {
        let mut acc = 0f32;
        for m in models {
            acc += m[i];
        }
        out[i] = acc / models.len() as f32;
    }
    out
}

/// The two queue backends under one local trait so the hold model is
/// written once.
trait Queue {
    fn push(&mut self, at: SimTime, v: u64);
    fn pop_next(&mut self) -> Option<(SimTime, u64)>;
}

impl Queue for CalendarEventQueue<u64> {
    fn push(&mut self, at: SimTime, v: u64) {
        self.schedule_at(at, v);
    }
    fn pop_next(&mut self) -> Option<(SimTime, u64)> {
        self.pop()
    }
}

impl Queue for HeapEventQueue<u64> {
    fn push(&mut self, at: SimTime, v: u64) {
        self.schedule_at(at, v);
    }
    fn pop_next(&mut self) -> Option<(SimTime, u64)> {
        self.pop()
    }
}

/// Classic DES hold model: steady-state queue of `resident` events; each
/// operation pops the head and reschedules it a short random delay ahead —
/// the exact access pattern of a running session. Returns a checksum so
/// the work cannot be optimized away.
fn hold_model<Q: Queue>(q: &mut Q, resident: u64, ops: u64) -> u64 {
    let mut rng = SimRng::new(0xbe9c);
    for i in 0..resident {
        q.push(SimTime::from_micros(rng.gen_range(1_000_000)), i);
    }
    let mut sum = 0u64;
    for i in 0..ops {
        let (t, v) = q.pop_next().expect("hold model under-filled");
        sum = sum.wrapping_add(t.0 ^ v);
        let delay = 1 + rng.gen_range(2_000);
        q.push(SimTime::from_micros(t.0 + delay), i);
    }
    while let Some((t, v)) = q.pop_next() {
        sum = sum.wrapping_add(t.0 ^ v);
    }
    sum
}

/// Shard router for the `par/` rows: the event payload *is* the routing
/// key, mirroring how the harness routes on the destination node id.
fn route_id(e: &u64) -> u64 {
    *e
}

/// Conservative lookahead for the `par/` rows (20ms — a typical quantized
/// WAN latency floor, wide enough to batch thousands of events per
/// synchronous window at a 100k resident set).
const PAR_LOOKAHEAD_US: u64 = 20_000;

/// Hold model over the session-level queue (single-threaded or sharded).
/// Reschedule delays are drawn at or above the lookahead so new events
/// take the cross-shard mailbox path into the worker partitions — the
/// steady state a parallel session sits in. (Delays inside the current
/// window would land in the main-thread overlay and measure nothing
/// parallel.)
fn par_hold(q: &mut SessionQueue<u64>, resident: u64, ops: u64) -> u64 {
    let mut rng = SimRng::new(0xbe9c);
    for i in 0..resident {
        q.schedule_at(SimTime::from_micros(rng.gen_range(1_000_000)), i);
    }
    let mut sum = 0u64;
    for i in 0..ops {
        let (t, v) = q.pop().expect("hold model under-filled");
        sum = sum.wrapping_add(t.0 ^ v);
        let delay = PAR_LOOKAHEAD_US + rng.gen_range(1_000_000);
        q.schedule_at(SimTime::from_micros(t.0 + delay), i);
    }
    while let Some((t, v)) = q.pop() {
        sum = sum.wrapping_add(t.0 ^ v);
    }
    sum
}

fn main() {
    let mut b = Bencher::new("hotpaths");
    let mut rng = SimRng::new(42);
    let fast = std::env::var("BENCH_FAST").is_ok();

    // ---- aggregation: s models x P params (FEMNIST-sized and CIFAR-sized)
    for (label, s, p) in [
        ("aggregate/native/8x1.75M(femnist)", 8usize, 1_754_430usize),
        ("aggregate/native/10x86k(cifar10)", 10, 86_314),
    ] {
        let models: Vec<Model> = (0..s)
            .map(|_| (0..p).map(|_| rng.next_f32()).collect())
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        b.bench(label, || {
            black_box(aggregate_native(black_box(&refs)));
        });
    }
    {
        let s = 8;
        let p = 1_754_430;
        let models: Vec<Model> = (0..s)
            .map(|_| (0..p).map(|_| rng.next_f32()).collect())
            .collect();
        let refs: Vec<&Model> = models.iter().collect();
        b.bench("aggregate/naive/8x1.75M(femnist)", || {
            black_box(aggregate_naive(black_box(&refs)));
        });
        // XLA/Pallas path (needs the `xla` feature + artifacts; includes
        // stack copy + PJRT).
        #[cfg(feature = "xla")]
        {
            if let Ok(rt) = XlaRuntime::load("artifacts") {
                if let Ok(v) = rt.variant("femnist") {
                    let slices: Vec<&[f32]> = refs.iter().map(|m| m.as_slice()).collect();
                    b.bench("aggregate/xla-pallas/8x1.75M(femnist)", || {
                        black_box(v.aggregate(black_box(&slices)).unwrap());
                    });
                }
            }
        }
    }

    // ---- DES queue: the acceptance benchmark. 1M hold-model operations
    // over a 10k-event resident set, calendar vs heap shim.
    let ops: u64 = if fast { 100_000 } else { 1_000_000 };
    let resident: u64 = 10_000;
    let cal = b
        .bench_once(&format!("des/queue/hold-{ops}/calendar"), || {
            let mut q = CalendarEventQueue::new();
            black_box(hold_model(&mut q, resident, ops));
        })
        .mean;
    let heap = b
        .bench_once(&format!("des/queue/hold-{ops}/heap"), || {
            let mut q = HeapEventQueue::new();
            black_box(hold_model(&mut q, resident, ops));
        })
        .mean;
    println!(
        "des/queue: calendar is {:.2}x the heap at {ops} hold-model ops",
        heap.as_secs_f64() / cal.as_secs_f64().max(1e-12)
    );

    // Legacy pattern kept for cross-PR comparability: batch-push then drain.
    b.bench("des/queue/10k-events", || {
        let mut q = CalendarEventQueue::new();
        for i in 0..10_000u64 {
            q.schedule_at(SimTime::from_micros((i * 7919) % 100_000), i);
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        black_box(n);
    });

    // ---- parallel DES: the sharded conservative-window scheduler. The
    // acceptance row pair: the same hold model driven through the
    // SessionQueue at t=1 (today's single-threaded loop) and t=4 (four
    // shard workers doing the calendar inserts/pops off the main thread).
    {
        let n: u64 = 100_000;
        let ops: u64 = if fast { 200_000 } else { 1_000_000 };

        // One full window cycle in isolation: 100k mailboxed inserts
        // flushed to 4 shards, then drained back through the (at, seq)
        // merge — the per-barrier machinery without the steady-state loop.
        b.bench_once(&format!("par/window-merge/n={}k", n / 1_000), || {
            let mut q: ShardedQueue<u64> =
                ShardedQueue::new(4, SimTime::from_micros(PAR_LOOKAHEAD_US), route_id);
            let mut rng = SimRng::new(0x9e37);
            for i in 0..n {
                q.schedule_at(SimTime::from_micros(rng.gen_range(1_000_000)), i);
            }
            let mut sum = 0u64;
            while let Some((t, v)) = q.pop() {
                sum = sum.wrapping_add(t.0 ^ v);
            }
            black_box(sum);
        });

        let mut sum1 = 0u64;
        let t1 = b
            .bench_once(&format!("par/harness-step/n={}k,t=1", n / 1_000), || {
                let mut q = SessionQueue::Single(EventQueue::new());
                sum1 = black_box(par_hold(&mut q, n, ops));
            })
            .mean;
        let mut sum4 = 0u64;
        let t4 = b
            .bench_once(&format!("par/harness-step/n={}k,t=4", n / 1_000), || {
                let mut q = SessionQueue::Sharded(ShardedQueue::new(
                    4,
                    SimTime::from_micros(PAR_LOOKAHEAD_US),
                    route_id,
                ));
                sum4 = black_box(par_hold(&mut q, n, ops));
            })
            .mean;
        // The checksum folds in every (time ^ payload) in pop order, so
        // equality here is the bit-identity contract holding at bench scale.
        assert_eq!(sum1, sum4, "sharded pop order diverged from single-threaded");
        println!(
            "par/harness-step: t=4 is {:.2}x t=1 at {ops} hold-model ops over {n} resident",
            t1.as_secs_f64() / t4.as_secs_f64().max(1e-12)
        );
    }

    // ---- zero-copy fan-out: constructing the s in-flight copies of a
    // Train broadcast. Arc sharing must be O(refcount), independent of
    // model size; the deep-copy baseline shows what each delivery used to
    // pay (s * model bytes + s * view clones).
    {
        let model: Arc<Model> = Arc::new((0..1_754_430).map(|_| rng.next_f32()).collect());
        let mut view = View::default();
        for node in 0..10_000u32 {
            view.registry.update(node, 1, MembershipEvent::Joined);
            view.activity.update(node, (node % 60) as u64);
        }
        let view: ViewRef = Arc::new(view);
        // Same fan-out count in both, so the JSON rows compare directly.
        b.bench("fanout/arc-msgs/8-of-1.75M", || {
            let msgs: Vec<Msg> = (0..8)
                .map(|_| Msg::Train {
                    seq: 0,
                    from: 0,
                    round: 7,
                    model: black_box(&model).clone(),
                    view: black_box(&view).clone(),
                })
                .collect();
            black_box(msgs);
        });
        b.bench("fanout/deep-copy-baseline/8-of-1.75M", || {
            // What 8 deliveries cost pre-Arc: a full model + view copy each.
            let msgs: Vec<(Model, View)> = (0..8)
                .map(|_| (black_box(&model).as_ref().clone(), black_box(&view).as_ref().clone()))
                .collect();
            black_box(msgs);
        });
        // The 10k-node scale point for the Arc path (no deep-copy twin —
        // 10k deep copies would be ~70 GB of memcpy per iteration).
        b.bench("fanout/arc-msgs/10k-of-1.75M", || {
            let msgs: Vec<Msg> = (0..10_000)
                .map(|_| Msg::Train {
                    seq: 0,
                    from: 0,
                    round: 7,
                    model: black_box(&model).clone(),
                    view: black_box(&view).clone(),
                })
                .collect();
            black_box(msgs);
        });
    }

    // ---- fabric: a 10k-way broadcast through the FIFO link queues (the
    // n=10k harness hot path: per-transfer latency lookup + capacity
    // bookkeeping, no allocation).
    {
        let n = 10_000usize;
        let mut frng = SimRng::new(7);
        let latency = LatencyMatrix::synthetic(&Default::default(), n, &mut frng);
        let mut fabric = NetworkFabric::uniform(latency, 50e6, n);
        let mut t = 0u64;
        b.bench("fabric/transfer-fanout/n=10k", || {
            t += 1_000_000;
            let now = SimTime::from_micros(t);
            let mut last = SimTime::ZERO;
            for to in 1..n as NodeId {
                last = fabric.transfer(now, 0, to, &[(MsgKind::ModelPayload, 1_000)]);
            }
            black_box(last);
        });
    }

    // ---- loss fault injection: the per-transfer drop decision on the
    // fabric hot path. Burst (Gilbert–Elliott) is the worst case — every
    // decision advances the receiver's two-state channel — so these rows
    // bound what `network.loss` adds to every try_transfer. Guarded
    // (`loss/` prefix in the CI bench-diff gate): the decision must stay
    // O(1) per transfer with no allocation.
    for n in [10_000usize, 100_000] {
        let mut layer = LossLayer::new(
            LossModel::Burst { p_good: 0.01, p_bad: 0.5, good_mean_s: 10.0, bad_mean_s: 1.0 },
            SimRng::new(0x1055),
        );
        let mut t = 0u64;
        b.bench(&format!("loss/decide/n={n}"), || {
            t += 250_000;
            let now = SimTime::from_micros(t);
            let mut drops = 0u32;
            for to in 1..n {
                drops += layer.decide(now, 0, to, 0, 0) as u32;
            }
            black_box(drops);
        });
    }

    // ---- reliability: a full lossy session sweep — 64-node gossip under
    // 30% uniform loss, exercising track/ack bookkeeping, timer routing,
    // and the retransmit path end-to-end. Guarded (`reliability/` prefix)
    // so outbox overhead regressions surface in CI.
    {
        let mk = || {
            let n = 64usize;
            let cfg = GossipConfig {
                max_rounds: 6,
                reliability: Some(ReliabilityConfig {
                    timeout: SimTime::from_secs_f64(2.0),
                    backoff: 2.0,
                    max_timeout: SimTime::from_secs_f64(8.0),
                    retries: 3,
                }),
                ..GossipConfig::default()
            };
            let mut srng = SimRng::new(cfg.seed);
            let task = MockTask::new(n, 8, 0.5, cfg.seed);
            let latency = LatencyMatrix::synthetic(&Default::default(), n, &mut srng);
            let mut fabric = NetworkFabric::uniform(latency, 50e6, n);
            fabric.set_loss(LossModel::Uniform { p: 0.3 }, srng.fork("loss"));
            let compute = ComputeModel::uniform(n, 0.05);
            GossipSession::new(cfg, n, Box::new(task), compute, fabric, ChurnSchedule::empty())
        };
        b.bench_once("reliability/retransmit-sweep/n=64,p=0.3", || {
            let (_, ledger) = mk().run();
            black_box(ledger.retransmitted_bytes());
        });
    }

    // ---- sampler ordering at population scales
    for n in [100usize, 1_000, 10_000] {
        let cands: Vec<NodeId> = (0..n as NodeId).collect();
        let mut round = 0u64;
        b.bench(&format!("sampler/candidate_order/n={n}"), || {
            round += 1;
            black_box(candidate_order(round, black_box(&cands)));
        });
    }

    // ---- peer sampling: the V1 full shuffle vs the V2 partial shuffle at
    // gossip fan-out shape (k=10). V1 is O(n) — materialize + shuffle the
    // whole population; V2 is O(k) and must stay flat across n (the
    // 100k-node fast path; rows are guarded by the CI bench-diff gate).
    for n in [1_000usize, 10_000, 100_000] {
        let mut r1 = SimRng::new(0x5a);
        b.bench(&format!("sample/v1-shuffle/n={n},k=10"), || {
            black_box(r1.sample_indices(black_box(n), 10));
        });
        let mut r2 = SimRng::new(0x5a);
        b.bench(&format!("sample/v2-partial/n={n},k=10"), || {
            black_box(r2.sample_indices_v2(black_box(n), 10));
        });
    }

    // ---- churned peer sampling: the non-all-alive path over a
    // Population with 30% of the nodes dead. v1 still burns the frozen
    // O(alive) draw stream by contract; v2 is the tentpole — O(k log n)
    // Fenwick rank/select with zero peer-list materialization, near-flat
    // across n (guarded rows: the CI bench-diff gate fails a >2x p50
    // regression on any `sample/` row).
    for n in [1_000usize, 10_000, 100_000] {
        let mut pop = Population::new(n, n);
        let mut killer = SimRng::new(0xDEAD ^ n as u64);
        for i in killer.sample_indices_v2(n, (3 * n) / 10) {
            pop.mark_dead(i);
        }
        let of = pop.select(0);
        let mut r1 = SimRng::new(0x5a);
        b.bench(&format!("sample/churned-v1/n={n},k=10"), || {
            black_box(pop.sample_alive_excluding(
                &mut r1,
                SamplingVersion::V1Shuffle,
                black_box(of),
                10,
            ));
        });
        let mut r2 = SimRng::new(0x5a);
        b.bench(&format!("sample/churned-v2/n={n},k=10"), || {
            black_box(pop.sample_alive_excluding(
                &mut r2,
                SamplingVersion::V2Partial,
                black_box(of),
                10,
            ));
        });
    }

    // ---- memory budget: live heap bytes per node for a fully-built
    // gossip session. Recorded as guarded value rows — the bench-diff
    // gate fails the build if the per-node footprint more than doubles
    // (the SoA NodeTable / arena-queue / compact-ledger diet quietly
    // regrowing). The 1M point is one-shot session *construction*, not a
    // run, so it stays cheap enough for the BENCH_FAST smoke too.
    for n in [10_000usize, 100_000, 1_000_000] {
        let before = live_bytes();
        let session = mem_probe_session(n);
        let after = live_bytes();
        black_box(&session);
        drop(session);
        let per_node = after.saturating_sub(before) / n as u64;
        b.record_value(&format!("mem/bytes-per-node/n={n}"), per_node);
    }

    // ---- snapshot: checkpoint/restore cost at the 100k-node scale point
    // (guarded rows — the `snapshot/` prefix is in the CI bench-diff
    // gate). The session is the CI smoke shape — mock gossip, sampling
    // v2 — snapshotted 5 sim-seconds in, so the captured state has live
    // fan-out traffic, interned Arc models, and a populated event arena.
    // `write` is the in-memory serialization of the full session; `read`
    // is the complete resume path (rebuild the statics from the embedded
    // spec, replay the dynamic state); `bytes` is the on-disk size, parked
    // in the ns field like the mem/ budget rows and guarded the same way.
    {
        let spec = ScenarioSpec::from_json(
            r#"{
                "workload": {"dataset": "mock"},
                "population": {"nodes": 100000},
                "protocol": {"name": "gossip"},
                "run": {"max_time_s": 40.0, "max_rounds": 2,
                        "eval_interval_s": 10.0, "seed": 77, "sampling": "v2"}
            }"#,
        )
        .unwrap();
        let path = std::env::temp_dir().join("bench_snapshot_100k.snap");
        let mut ck = spec;
        ck.run.checkpoint_at_s = Some(5.0);
        ck.run.checkpoint_out = Some(path.to_string_lossy().into_owned());
        let _ = run_scenario(&ck, None, ChurnSchedule::empty()).unwrap();
        let bytes = std::fs::read(&path).expect("checkpoint never written");
        let _ = std::fs::remove_file(&path);
        b.record_value("snapshot/bytes/n=100k", bytes.len() as u64);
        let (_, session) = resume_session(&bytes, None, None, None).unwrap();
        b.bench_once("snapshot/write/n=100k", || {
            black_box(session.snapshot_bytes().unwrap());
        });
        b.bench_once("snapshot/read/n=100k", || {
            black_box(resume_session(black_box(&bytes), None, None, None).unwrap());
        });
    }

    // ---- view merge + wire size at population 500 (celeba scale)
    {
        let mut a = View::default();
        let mut c = View::default();
        for node in 0..500u32 {
            a.registry.update(node, 1, MembershipEvent::Joined);
            a.activity.update(node, (node % 60) as u64);
            c.registry.update(node, 2, MembershipEvent::Joined);
            c.activity.update(node, (node % 90) as u64);
        }
        b.bench("view/merge/500-nodes", || {
            let mut m = a.clone();
            m.merge(black_box(&c));
            black_box(m);
        });
        let sizes = SizeModel::default();
        b.bench("view/wire_bytes/500-nodes", || {
            black_box(black_box(&a).wire_bytes(&sizes));
        });
        b.bench("view/candidates/500-nodes", || {
            black_box(black_box(&a).candidates(50, 20));
        });
    }

    // ---- streaming observability: the sketch operations sit on the
    // per-transfer (histogram record, HLL insert) and per-round hot paths
    // of every instrumented session, and the progress tick is promised to
    // be bounded work regardless of session size. All rows are guarded
    // (`obs/` prefix in the CI bench-diff gate). Single records are a few
    // ns — below MIN_GUARDED_NS — so the record/insert rows batch enough
    // work per iteration to sit safely above the noise exemption.
    {
        let mut h = StreamHistogram::new();
        let mut x = 0x0B5u64;
        b.bench("obs/hist-record/x1024", || {
            for _ in 0..1024 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                h.record(x >> 40);
            }
            black_box(h.total());
        });
        for n in [10_000u64, 100_000] {
            let mut hll = Hll::with_salt(0x0B5);
            b.bench(&format!("obs/hll-insert/n={n}"), || {
                for i in 0..n {
                    hll.insert(i);
                }
                black_box(hll.inserts());
            });
        }
        // One progress tick over 100k-sample state: the quantile scans and
        // HLL estimates dominate; the render reuses one buffer, so the
        // steady-state tick allocates nothing (the /proc RSS read is left
        // out — fs latency would only add CI noise to the guarded row).
        let mut round_hist = StreamHistogram::new();
        let mut lat_hist = StreamHistogram::new();
        let mut peers = Hll::with_salt(0x7151);
        let mut trainers = Hll::with_salt(0x7152);
        for i in 0..100_000u64 {
            round_hist.record(1_000_000 + (i * 7919) % 5_000_000);
            lat_hist.record(10_000 + (i * 104_729) % 900_000);
            peers.insert(i);
            trainers.insert(i / 10);
        }
        let mut buf = String::new();
        b.bench("obs/progress-tick/n=100000", || {
            let line = ProgressLine {
                t_s: 40.0,
                alive: 100_000,
                rounds: 2,
                events: 1_000_000,
                msgs: round_hist.total(),
                bytes_total: 1 << 30,
                bytes_goodput: 1 << 30,
                round_p50_s: round_hist.quantile(0.5) as f64 / 1e6,
                round_p95_s: round_hist.quantile(0.95) as f64 / 1e6,
                lat_p50_ms: lat_hist.quantile(0.5) as f64 / 1e3,
                lat_p95_ms: lat_hist.quantile(0.95) as f64 / 1e3,
                xfer_p50_b: lat_hist.quantile(0.5),
                peers_est: peers.count(),
                trainers_est: trainers.count(),
                ..Default::default()
            };
            buf.clear();
            line.render(&mut buf);
            black_box(buf.len());
        });
    }

    let json_path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpaths.json".to_string());
    b.write_json(&json_path);
    b.finish();
}
