//! Dataset presets (paper Table 3).
//!
//! Everything else that used to live here — the `Algo` enum, the flat
//! `SessionSpec`, and the per-algorithm builders — moved to the layered
//! Scenario API in [`crate::scenario`]: sessions are described by a
//! [`crate::scenario::ScenarioSpec`] and assembled through the
//! [`crate::scenario::ProtocolRegistry`].

use anyhow::Result;

use crate::data::Partition;

/// Paper-aligned per-dataset defaults.
#[derive(Debug, Clone)]
pub struct DatasetPreset {
    pub variant: &'static str,
    /// Paper Table 3 network size.
    pub nodes: usize,
    pub partition: Partition,
    pub samples_per_node: usize,
    pub s: usize,
    pub a: usize,
    /// Convergence target used by time-to-accuracy experiments
    /// (accuracy for classifiers, MSE for movielens).
    pub target: f64,
}

/// Defaults per learning task. `s`/`a` follow the paper where stated
/// (§4.6 uses s=10, a=5 on CIFAR10; Fig. 4 sweeps FEMNIST).
pub fn preset(dataset: &str) -> Result<DatasetPreset> {
    Ok(match dataset {
        "cifar10" => DatasetPreset {
            variant: "cifar10",
            nodes: 100,
            partition: Partition::Iid,
            samples_per_node: 100,
            s: 10,
            a: 3,
            target: 0.80,
        },
        "celeba" => DatasetPreset {
            variant: "celeba",
            nodes: 500,
            partition: Partition::non_iid(),
            samples_per_node: 60,
            s: 10,
            a: 3,
            target: 0.85,
        },
        "femnist" => DatasetPreset {
            variant: "femnist",
            nodes: 355,
            partition: Partition::non_iid(),
            // LEAF FEMNIST writers hold small shards; 60 keeps the 6.7MB
            // model the cost driver (3 batches/epoch) like the paper.
            samples_per_node: 60,
            s: 4,
            a: 3,
            target: 0.83,
        },
        "movielens" => DatasetPreset {
            variant: "movielens",
            nodes: 610,
            partition: Partition::Iid, // one-user-one-node handled by ratings gen
            samples_per_node: 40,      // ratings per user (scaled 100K corpus)
            s: 10,
            a: 3,
            target: 0.40, // MSE target on the synthetic ratings
        },
        "transformer" => DatasetPreset {
            variant: "transformer",
            nodes: 32,
            partition: Partition::Iid,
            samples_per_node: 64,
            s: 8,
            a: 2,
            target: 0.55,
        },
        "mock" => DatasetPreset {
            variant: "mock",
            nodes: 50,
            partition: Partition::Iid,
            samples_per_node: 0,
            s: 8,
            a: 3,
            target: 0.95,
        },
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_paper_table3() {
        for d in ["cifar10", "celeba", "femnist", "movielens"] {
            let p = preset(d).unwrap();
            assert!(p.nodes >= 100);
            assert!(p.s >= 1 && p.a >= 1);
        }
        assert!(preset("nope").is_err());
    }
}
