//! Session configuration: dataset presets (paper Table 3), algorithm
//! selection, scaling, network shaping, and the builders that assemble a
//! runnable session from config + artifacts.
//!
//! Every experiment driver and example goes through this module, so a
//! session is fully described by a [`SessionSpec`] (loadable from a JSON
//! config file via the launcher, parsed by the in-tree [`crate::util::json`]
//! module). The spec builds the [`NetworkFabric`] (latency + per-node
//! uplink/downlink capacities) every protocol charges its transfers
//! against; `bandwidth_sigma > 0` samples heterogeneous capacities
//! lognormally around `bandwidth_mbps`.

use anyhow::Result;

use crate::baselines::{fedavg_config, DsgdConfig, DsgdSession};
#[cfg(feature = "xla")]
use crate::data::{
    classif::ClassifParams, ratings::RatingsParams, tokens::TokensParams, ClassifData,
    RatingsData, TokensData,
};
use crate::data::Partition;
#[cfg(feature = "xla")]
use crate::learning::{TaskData, XlaTask};
use crate::learning::{ComputeModel, MockTask, Task};
use crate::modest::{ModestConfig, ModestSession};
use crate::net::{BandwidthConfig, LatencyMatrix, LatencyParams, NetworkFabric};
use crate::runtime::XlaRuntime;
use crate::sim::{ChurnSchedule, SimRng, SimTime};
use crate::util::Json;

/// Which algorithm runs the session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    Modest,
    Fedavg,
    Dsgd,
}

impl std::str::FromStr for Algo {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "modest" => Ok(Algo::Modest),
            "fedavg" | "fl" => Ok(Algo::Fedavg),
            "dsgd" | "d-sgd" | "dl" => Ok(Algo::Dsgd),
            other => anyhow::bail!("unknown algorithm {other:?} (modest|fedavg|dsgd)"),
        }
    }
}

/// Paper-aligned per-dataset defaults.
#[derive(Debug, Clone)]
pub struct DatasetPreset {
    pub variant: &'static str,
    /// Paper Table 3 network size.
    pub nodes: usize,
    pub partition: Partition,
    pub samples_per_node: usize,
    pub s: usize,
    pub a: usize,
    /// Convergence target used by time-to-accuracy experiments
    /// (accuracy for classifiers, MSE for movielens).
    pub target: f64,
}

/// Defaults per learning task. `s`/`a` follow the paper where stated
/// (§4.6 uses s=10, a=5 on CIFAR10; Fig. 4 sweeps FEMNIST).
pub fn preset(dataset: &str) -> Result<DatasetPreset> {
    Ok(match dataset {
        "cifar10" => DatasetPreset {
            variant: "cifar10",
            nodes: 100,
            partition: Partition::Iid,
            samples_per_node: 100,
            s: 10,
            a: 3,
            target: 0.80,
        },
        "celeba" => DatasetPreset {
            variant: "celeba",
            nodes: 500,
            partition: Partition::non_iid(),
            samples_per_node: 60,
            s: 10,
            a: 3,
            target: 0.85,
        },
        "femnist" => DatasetPreset {
            variant: "femnist",
            nodes: 355,
            partition: Partition::non_iid(),
            // LEAF FEMNIST writers hold small shards; 60 keeps the 6.7MB
            // model the cost driver (3 batches/epoch) like the paper.
            samples_per_node: 60,
            s: 4,
            a: 3,
            target: 0.83,
        },
        "movielens" => DatasetPreset {
            variant: "movielens",
            nodes: 610,
            partition: Partition::Iid, // one-user-one-node handled by ratings gen
            samples_per_node: 40,      // ratings per user (scaled 100K corpus)
            s: 10,
            a: 3,
            target: 0.40, // MSE target on the synthetic ratings
        },
        "transformer" => DatasetPreset {
            variant: "transformer",
            nodes: 32,
            partition: Partition::Iid,
            samples_per_node: 64,
            s: 8,
            a: 2,
            target: 0.55,
        },
        "mock" => DatasetPreset {
            variant: "mock",
            nodes: 50,
            partition: Partition::Iid,
            samples_per_node: 0,
            s: 8,
            a: 3,
            target: 0.95,
        },
        other => anyhow::bail!("unknown dataset {other:?}"),
    })
}

/// Full session specification.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub dataset: String,
    pub algo: Algo,
    /// 0 = paper node count (times `scale`).
    pub nodes: usize,
    /// Scale factor on the node count for CI-speed runs.
    pub scale: f64,
    /// 0 = preset.
    pub s: usize,
    pub a: usize,
    pub sf: f64,
    pub dt_s: f64,
    pub dk: u64,
    pub max_time_s: f64,
    pub max_rounds: u64,
    pub eval_interval_s: f64,
    pub target_metric: Option<f64>,
    pub seed: u64,
    /// Median per-node capacity (symmetric) in Mbit/s.
    pub bandwidth_mbps: f64,
    /// Capacity heterogeneity (lognormal sigma around `bandwidth_mbps`;
    /// 0 = every node identical).
    pub bandwidth_sigma: f64,
    /// Base per-batch train time (s) on a speed-1 node.
    pub base_batch_s: f64,
    /// Compute heterogeneity (lognormal sigma; 0 = uniform).
    pub hetero_sigma: f64,
    pub artifacts_dir: String,
}

impl Default for SessionSpec {
    fn default() -> Self {
        SessionSpec {
            dataset: "cifar10".into(),
            algo: Algo::Modest,
            nodes: 0,
            scale: 1.0,
            s: 0,
            a: 0,
            sf: 1.0,
            dt_s: 2.0,
            dk: 20,
            max_time_s: 1800.0,
            max_rounds: 0,
            eval_interval_s: 20.0,
            target_metric: None,
            seed: 42,
            bandwidth_mbps: 50.0,
            bandwidth_sigma: 0.0,
            base_batch_s: 0.05,
            hetero_sigma: 0.35,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl SessionSpec {
    /// Load from a JSON config file body: unknown keys are rejected, all
    /// keys are optional and override the defaults.
    pub fn from_json(text: &str) -> Result<SessionSpec> {
        let v = Json::parse(text)?;
        let mut spec = SessionSpec::default();
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "dataset" => spec.dataset = val.as_str()?.to_string(),
                "algo" => spec.algo = val.as_str()?.parse()?,
                "nodes" => spec.nodes = val.as_usize()?,
                "scale" => spec.scale = val.as_f64()?,
                "s" => spec.s = val.as_usize()?,
                "a" => spec.a = val.as_usize()?,
                "sf" => spec.sf = val.as_f64()?,
                "dt_s" => spec.dt_s = val.as_f64()?,
                "dk" => spec.dk = val.as_u64()?,
                "max_time_s" => spec.max_time_s = val.as_f64()?,
                "max_rounds" => spec.max_rounds = val.as_u64()?,
                "eval_interval_s" => spec.eval_interval_s = val.as_f64()?,
                "target_metric" => {
                    spec.target_metric =
                        if *val == Json::Null { None } else { Some(val.as_f64()?) }
                }
                "seed" => spec.seed = val.as_u64()?,
                "bandwidth_mbps" => spec.bandwidth_mbps = val.as_f64()?,
                "bandwidth_sigma" => spec.bandwidth_sigma = val.as_f64()?,
                "base_batch_s" => spec.base_batch_s = val.as_f64()?,
                "hetero_sigma" => spec.hetero_sigma = val.as_f64()?,
                "artifacts_dir" => spec.artifacts_dir = val.as_str()?.to_string(),
                other => anyhow::bail!("unknown config key {other:?}"),
            }
        }
        Ok(spec)
    }

    pub fn resolved_nodes(&self) -> Result<usize> {
        let p = preset(&self.dataset)?;
        let n = if self.nodes > 0 {
            self.nodes
        } else {
            ((p.nodes as f64 * self.scale).round() as usize).max(8)
        };
        Ok(n)
    }

    pub fn resolved_s(&self) -> Result<usize> {
        Ok(if self.s > 0 { self.s } else { preset(&self.dataset)?.s })
    }

    pub fn resolved_a(&self) -> Result<usize> {
        Ok(if self.a > 0 { self.a } else { preset(&self.dataset)?.a })
    }

    pub fn modest_config(&self) -> Result<ModestConfig> {
        Ok(ModestConfig {
            s: self.resolved_s()?,
            a: self.resolved_a()?,
            sf: self.sf,
            dt: SimTime::from_secs_f64(self.dt_s),
            dk: self.dk,
            max_time: SimTime::from_secs_f64(self.max_time_s),
            max_rounds: self.max_rounds,
            eval_interval: SimTime::from_secs_f64(self.eval_interval_s),
            target_metric: self.target_metric,
            seed: self.seed,
            fedavg_server: None,
        })
    }

    pub fn dsgd_config(&self) -> DsgdConfig {
        DsgdConfig {
            max_time: SimTime::from_secs_f64(self.max_time_s),
            max_rounds: self.max_rounds,
            eval_interval: SimTime::from_secs_f64(self.eval_interval_s),
            // Evaluating individual node models is the D-SGD probe cost;
            // 4 models keeps big-model probes affordable.
            eval_nodes: 4,
            eval_avg_model: self.dataset == "movielens",
            target_metric: self.target_metric,
            seed: self.seed,
        }
    }

    /// Build the learning task for this spec. `runtime` may be `None` only
    /// for the mock dataset.
    pub fn build_task(&self, runtime: Option<&XlaRuntime>) -> Result<Box<dyn Task>> {
        self.build_task_for(runtime, self.resolved_nodes()?)
    }

    /// Build the task sized for `n` nodes (>= resolved_nodes when a churn
    /// script adds joiners whose shards must exist).
    pub fn build_task_for(
        &self,
        runtime: Option<&XlaRuntime>,
        n: usize,
    ) -> Result<Box<dyn Task>> {
        if self.dataset == "mock" {
            return Ok(Box::new(MockTask::new(n.max(64), 32, 0.8, self.seed)));
        }
        self.build_artifact_task(runtime, n)
    }

    /// Artifact-backed datasets need the PJRT engine: without the `xla`
    /// feature this is a clear runtime error instead of a build break.
    #[cfg(not(feature = "xla"))]
    fn build_artifact_task(
        &self,
        _runtime: Option<&XlaRuntime>,
        _n: usize,
    ) -> Result<Box<dyn Task>> {
        anyhow::bail!(
            "dataset {:?} needs AOT artifacts; uncomment the `xla` dependency \
             in rust/Cargo.toml and rebuild with `--features xla`, or run with \
             the mock dataset",
            self.dataset
        )
    }

    #[cfg(feature = "xla")]
    fn build_artifact_task(
        &self,
        runtime: Option<&XlaRuntime>,
        n: usize,
    ) -> Result<Box<dyn Task>> {
        let p = preset(&self.dataset)?;
        let mut rng = SimRng::new(self.seed).fork("data");
        let runtime = runtime
            .ok_or_else(|| anyhow::anyhow!("dataset {} needs artifacts", self.dataset))?;
        let manifest = runtime.manifest().variant(p.variant)?.clone();
        let data = match manifest.kind.as_str() {
            "classifier" => {
                let classes = manifest.meta_usize("classes").unwrap_or(10);
                let input_dim = manifest.meta_usize("input_dim").unwrap_or(128);
                TaskData::Classif(ClassifData::generate(
                    &ClassifParams {
                        dim: input_dim,
                        classes,
                        nodes: n,
                        samples_per_node: p.samples_per_node,
                        test_samples: 2048,
                        partition: p.partition,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            "matfact" => {
                let users = manifest.meta_usize("users").unwrap_or(610);
                let items = manifest.meta_usize("items").unwrap_or(9724);
                TaskData::Ratings(RatingsData::generate(
                    &RatingsParams {
                        users,
                        items,
                        nodes: n,
                        ratings_per_user: p.samples_per_node,
                        test_per_user: 25,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            "lm" => {
                let vocab = manifest.meta_usize("vocab").unwrap_or(64);
                let max_t = manifest.meta_usize("max_t").unwrap_or(64);
                TaskData::Tokens(TokensData::generate(
                    &TokensParams {
                        vocab,
                        seq_len: max_t,
                        nodes: n,
                        seqs_per_node: p.samples_per_node,
                        test_seqs: 128,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            other => anyhow::bail!("unknown variant kind {other}"),
        };
        Ok(Box::new(XlaTask::new(runtime, p.variant, data)?))
    }

    pub fn build_latency(&self, n: usize) -> LatencyMatrix {
        let mut rng = SimRng::new(self.seed).fork("latency");
        LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng)
    }

    /// The per-node capacity distribution this spec describes.
    pub fn bandwidth_config(&self) -> BandwidthConfig {
        if self.bandwidth_sigma > 0.0 {
            BandwidthConfig::LogNormal {
                median_bps: self.bandwidth_mbps * 1e6,
                sigma: self.bandwidth_sigma,
            }
        } else {
            BandwidthConfig::Uniform { bps: self.bandwidth_mbps * 1e6 }
        }
    }

    /// Assemble the network fabric: synthetic geography + per-node
    /// capacities, both seeded from the session seed.
    pub fn build_fabric(&self, n: usize) -> NetworkFabric {
        let latency = self.build_latency(n);
        let mut rng = SimRng::new(self.seed).fork("bandwidth");
        NetworkFabric::new(latency, &self.bandwidth_config(), n, &mut rng)
    }

    pub fn build_compute(&self, n: usize) -> ComputeModel {
        let mut rng = SimRng::new(self.seed).fork("compute");
        if self.hetero_sigma > 0.0 {
            ComputeModel::heterogeneous(n, self.base_batch_s, self.hetero_sigma, &mut rng)
        } else {
            ComputeModel::uniform(n, self.base_batch_s)
        }
    }

    /// Assemble a MoDeST (or FedAvg-emulation) session.
    pub fn build_modest(
        &self,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<ModestSession> {
        let n = self.resolved_nodes()?;
        // Churn scripts may introduce node ids beyond the initial
        // population; the dataset/fabric/compute substrates must cover
        // them too.
        let max_n = n.max(
            churn.events().iter().map(|e| e.node as usize + 1).max().unwrap_or(0),
        );
        let task = self.build_task_for(runtime, max_n)?;
        let fabric = self.build_fabric(max_n);
        let compute = self.build_compute(max_n);
        let mut cfg = self.modest_config()?;
        if self.algo == Algo::Fedavg {
            cfg = fedavg_config(&cfg, fabric.latency(), n);
        }
        Ok(ModestSession::new(cfg, n, task, compute, fabric, churn))
    }

    /// Assemble a D-SGD session.
    pub fn build_dsgd(&self, runtime: Option<&XlaRuntime>) -> Result<DsgdSession> {
        let n = self.resolved_nodes()?;
        let task = self.build_task(runtime)?;
        let fabric = self.build_fabric(n);
        let compute = self.build_compute(n);
        Ok(DsgdSession::new(self.dsgd_config(), n, task, compute, fabric))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_paper_table3() {
        for d in ["cifar10", "celeba", "femnist", "movielens"] {
            let p = preset(d).unwrap();
            assert!(p.nodes >= 100);
            assert!(p.s >= 1 && p.a >= 1);
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn scale_shrinks_node_count() {
        let spec = SessionSpec { dataset: "celeba".into(), scale: 0.1, ..Default::default() };
        assert_eq!(spec.resolved_nodes().unwrap(), 50);
    }

    #[test]
    fn explicit_nodes_override_scale() {
        let spec =
            SessionSpec { dataset: "cifar10".into(), nodes: 24, scale: 0.1, ..Default::default() };
        assert_eq!(spec.resolved_nodes().unwrap(), 24);
    }

    #[test]
    fn algo_parses() {
        assert_eq!("modest".parse::<Algo>().unwrap(), Algo::Modest);
        assert_eq!("FL".parse::<Algo>().unwrap(), Algo::Fedavg);
        assert_eq!("d-sgd".parse::<Algo>().unwrap(), Algo::Dsgd);
        assert!("x".parse::<Algo>().is_err());
    }

    #[test]
    fn mock_session_builds_without_artifacts() {
        let spec = SessionSpec {
            dataset: "mock".into(),
            nodes: 12,
            max_time_s: 5.0,
            ..Default::default()
        };
        let session = spec.build_modest(None, ChurnSchedule::empty());
        assert!(session.is_ok());
    }

    #[test]
    fn spec_parses_from_json() {
        let spec = SessionSpec::from_json(
            r#"{"dataset": "femnist", "algo": "dsgd", "scale": 0.2, "seed": 7}"#,
        )
        .unwrap();
        assert_eq!(spec.dataset, "femnist");
        assert_eq!(spec.algo, Algo::Dsgd);
        assert_eq!(spec.seed, 7);
        assert!((spec.scale - 0.2).abs() < 1e-12);
        // defaults retained
        assert_eq!(spec.dk, 20);
    }

    #[test]
    fn spec_rejects_unknown_keys() {
        assert!(SessionSpec::from_json(r#"{"datset": "x"}"#).is_err());
    }

    #[test]
    fn bandwidth_spec_builds_hetero_fabric() {
        let spec = SessionSpec {
            dataset: "mock".into(),
            nodes: 16,
            bandwidth_mbps: 10.0,
            bandwidth_sigma: 0.6,
            ..Default::default()
        };
        let fabric = spec.build_fabric(16);
        let min = (0..16u32).map(|n| fabric.up_bps(n)).fold(f64::MAX, f64::min);
        let max = (0..16u32).map(|n| fabric.up_bps(n)).fold(0.0f64, f64::max);
        assert!(max > min, "no heterogeneity: {min}..{max}");
        // sigma = 0 gives a flat fabric
        let flat = SessionSpec {
            dataset: "mock".into(),
            nodes: 16,
            ..Default::default()
        }
        .build_fabric(16);
        for n in 0..16u32 {
            assert_eq!(flat.up_bps(n), 50e6);
            assert_eq!(flat.down_bps(n), 50e6);
        }
    }

    #[test]
    fn bandwidth_sigma_parses_from_json() {
        let spec = SessionSpec::from_json(
            r#"{"dataset": "mock", "bandwidth_mbps": 25.0, "bandwidth_sigma": 0.4}"#,
        )
        .unwrap();
        assert!((spec.bandwidth_mbps - 25.0).abs() < 1e-12);
        assert!((spec.bandwidth_sigma - 0.4).abs() < 1e-12);
    }
}
