//! Synthetic classification task: Gaussian class clusters in feature space.
//!
//! Stands in for CIFAR10/CelebA/FEMNIST (DESIGN.md §3): each class is a
//! Gaussian cluster around a random center on a scaled sphere; per-node
//! shards are IID or label-Dirichlet skewed. The task is learnable by the
//! equal-byte-size MLP variants but not trivially so (noise overlaps the
//! clusters), giving convergence curves with the same FL-vs-DL shape the
//! paper reports.

use crate::sim::SimRng;

use super::partition::Partition;

/// Generated classification data with per-node shards and a global test set.
#[derive(Debug, Clone)]
pub struct ClassifData {
    pub dim: usize,
    pub classes: usize,
    /// Flattened train features, row-major `[n_train, dim]`.
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    /// Flattened test features, row-major `[n_test, dim]`.
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
    /// Per-node sample indices into the train pool.
    pub shards: Vec<Vec<u32>>,
}

/// Controls cluster geometry; defaults give ~85-95% achievable accuracy.
#[derive(Debug, Clone)]
pub struct ClassifParams {
    pub dim: usize,
    pub classes: usize,
    pub nodes: usize,
    pub samples_per_node: usize,
    pub test_samples: usize,
    /// Distance of class centers from the origin.
    pub center_scale: f32,
    /// Per-feature noise sigma (relative to center scale 1).
    pub noise: f32,
    pub partition: Partition,
}

impl Default for ClassifParams {
    fn default() -> Self {
        ClassifParams {
            dim: 128,
            classes: 10,
            nodes: 100,
            samples_per_node: 100,
            test_samples: 2048,
            center_scale: 1.0,
            noise: 1.4,
            partition: Partition::Iid,
        }
    }
}

impl ClassifData {
    pub fn generate(p: &ClassifParams, rng: &mut SimRng) -> ClassifData {
        let mut centers = vec![0f32; p.classes * p.dim];
        for c in 0..p.classes {
            // Random direction scaled to `center_scale`.
            let mut norm = 0f64;
            let row = &mut centers[c * p.dim..(c + 1) * p.dim];
            for v in row.iter_mut() {
                *v = rng.next_gaussian() as f32;
                norm += (*v as f64) * (*v as f64);
            }
            let norm = norm.sqrt().max(1e-9) as f32;
            for v in row.iter_mut() {
                *v *= p.center_scale * (p.dim as f32).sqrt() / norm;
            }
        }

        let sample = |class: usize, rng: &mut SimRng, out_x: &mut Vec<f32>| {
            let row = &centers[class * p.dim..(class + 1) * p.dim];
            for &c in row {
                out_x.push(c + p.noise * rng.next_gaussian() as f32);
            }
        };

        // Per-node class distributions.
        let node_dists: Vec<Vec<f64>> = (0..p.nodes)
            .map(|_| match p.partition {
                Partition::Iid => vec![1.0 / p.classes as f64; p.classes],
                Partition::Dirichlet(alpha) => rng.next_dirichlet(alpha, p.classes),
            })
            .collect();

        let n_train = p.nodes * p.samples_per_node;
        let mut train_x = Vec::with_capacity(n_train * p.dim);
        let mut train_y = Vec::with_capacity(n_train);
        let mut shards = vec![Vec::with_capacity(p.samples_per_node); p.nodes];
        for (node, dist) in node_dists.iter().enumerate() {
            for _ in 0..p.samples_per_node {
                let u = rng.next_f64();
                let mut acc = 0.0;
                let mut class = p.classes - 1;
                for (c, &w) in dist.iter().enumerate() {
                    acc += w;
                    if u < acc {
                        class = c;
                        break;
                    }
                }
                shards[node].push(train_y.len() as u32);
                sample(class, rng, &mut train_x);
                train_y.push(class as i32);
            }
        }

        let mut test_x = Vec::with_capacity(p.test_samples * p.dim);
        let mut test_y = Vec::with_capacity(p.test_samples);
        for i in 0..p.test_samples {
            let class = i % p.classes; // balanced test set
            sample(class, rng, &mut test_x);
            test_y.push(class as i32);
        }

        ClassifData {
            dim: p.dim,
            classes: p.classes,
            train_x,
            train_y,
            test_x,
            test_y,
            shards,
        }
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// Copy one train sample's features into `out`.
    pub fn train_row(&self, idx: u32) -> &[f32] {
        let i = idx as usize;
        &self.train_x[i * self.dim..(i + 1) * self.dim]
    }

    /// Empirical class distribution of one node's shard.
    pub fn shard_class_hist(&self, node: usize) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &i in &self.shards[node] {
            h[self.train_y[i as usize] as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(partition: Partition) -> ClassifData {
        let mut rng = SimRng::new(1);
        ClassifData::generate(
            &ClassifParams {
                nodes: 20,
                samples_per_node: 50,
                test_samples: 200,
                classes: 10,
                partition,
                ..Default::default()
            },
            &mut rng,
        )
    }

    #[test]
    fn shapes_consistent() {
        let d = gen(Partition::Iid);
        assert_eq!(d.n_train(), 1000);
        assert_eq!(d.train_x.len(), 1000 * d.dim);
        assert_eq!(d.n_test(), 200);
        assert_eq!(d.shards.len(), 20);
        assert!(d.shards.iter().all(|s| s.len() == 50));
    }

    #[test]
    fn labels_in_range() {
        let d = gen(Partition::Iid);
        assert!(d.train_y.iter().all(|&y| (0..10).contains(&y)));
        assert!(d.test_y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn iid_shards_are_balanced() {
        let d = gen(Partition::Iid);
        // Each node's most common class should hold well under half the shard.
        let mut skews = Vec::new();
        for node in 0..20 {
            let h = d.shard_class_hist(node);
            skews.push(*h.iter().max().unwrap() as f64 / 50.0);
        }
        let mean_skew = skews.iter().sum::<f64>() / skews.len() as f64;
        assert!(mean_skew < 0.35, "IID shards too skewed: {mean_skew}");
    }

    #[test]
    fn dirichlet_shards_are_skewed() {
        let d = gen(Partition::Dirichlet(0.1));
        let mut skews = Vec::new();
        for node in 0..20 {
            let h = d.shard_class_hist(node);
            skews.push(*h.iter().max().unwrap() as f64 / 50.0);
        }
        let mean_skew = skews.iter().sum::<f64>() / skews.len() as f64;
        assert!(mean_skew > 0.5, "Dirichlet(0.1) shards too uniform: {mean_skew}");
    }

    #[test]
    fn deterministic() {
        let a = gen(Partition::Iid);
        let b = gen(Partition::Iid);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.train_x[..256], b.train_x[..256]);
    }

    #[test]
    fn test_set_balanced() {
        let d = gen(Partition::Iid);
        let mut h = vec![0; 10];
        for &y in &d.test_y {
            h[y as usize] += 1;
        }
        assert!(h.iter().all(|&c| c == 20));
    }
}
