//! Synthetic federated datasets — the data substrate (DESIGN.md §3).
//!
//! The paper's datasets (CIFAR10, CelebA, FEMNIST, MovieLens-100K) are
//! replaced by seeded synthetic tasks that exercise the identical code
//! paths: private per-node shards, IID and label-Dirichlet non-IID
//! partitions (the non-IIDness is what slows D-SGD in Fig. 3), a
//! one-user-one-node ratings task for matrix factorization, and a Markov
//! token stream for the transformer example.

pub mod classif;
pub mod partition;
pub mod ratings;
pub mod tokens;

pub use classif::ClassifData;
pub use partition::Partition;
pub use ratings::RatingsData;
pub use tokens::TokensData;
