//! Synthetic token stream for the transformer example: a sparse first-order
//! Markov chain over the vocabulary. The chain's structure (few likely
//! successors per token) is exactly what a small causal LM can learn, so
//! the loss curve of the end-to-end example has real signal.

use crate::sim::SimRng;

#[derive(Debug, Clone)]
pub struct TokensParams {
    pub vocab: usize,
    pub seq_len: usize,
    pub nodes: usize,
    pub seqs_per_node: usize,
    pub test_seqs: usize,
    /// Number of likely successors per token.
    pub branching: usize,
    /// Probability mass on the likely successors.
    pub peak_mass: f64,
}

impl Default for TokensParams {
    fn default() -> Self {
        TokensParams {
            vocab: 64,
            seq_len: 64,
            nodes: 32,
            seqs_per_node: 64,
            test_seqs: 128,
            branching: 4,
            peak_mass: 0.9,
        }
    }
}

/// Sequences stored flattened: each is `seq_len + 1` tokens (x = s[..T],
/// y = s[1..]).
#[derive(Debug, Clone)]
pub struct TokensData {
    pub vocab: usize,
    pub seq_len: usize,
    pub train: Vec<i32>,
    pub test: Vec<i32>,
    pub seqs_per_node: usize,
    pub nodes: usize,
}

impl TokensData {
    pub fn generate(p: &TokensParams, rng: &mut SimRng) -> TokensData {
        // Build the chain: token t -> `branching` preferred successors.
        let succ: Vec<Vec<usize>> = (0..p.vocab)
            .map(|_| (0..p.branching).map(|_| rng.gen_range(p.vocab as u64) as usize).collect())
            .collect();
        let gen_seq = |rng: &mut SimRng, out: &mut Vec<i32>| {
            let mut t = rng.gen_range(p.vocab as u64) as usize;
            out.push(t as i32);
            for _ in 0..p.seq_len {
                t = if rng.next_f64() < p.peak_mass {
                    succ[t][rng.gen_range(p.branching as u64) as usize]
                } else {
                    rng.gen_range(p.vocab as u64) as usize
                };
                out.push(t as i32);
            }
        };
        let stride = p.seq_len + 1;
        let mut train = Vec::with_capacity(p.nodes * p.seqs_per_node * stride);
        for _ in 0..p.nodes * p.seqs_per_node {
            gen_seq(rng, &mut train);
        }
        let mut test = Vec::with_capacity(p.test_seqs * stride);
        for _ in 0..p.test_seqs {
            gen_seq(rng, &mut test);
        }
        TokensData {
            vocab: p.vocab,
            seq_len: p.seq_len,
            train,
            test,
            seqs_per_node: p.seqs_per_node,
            nodes: p.nodes,
        }
    }

    pub fn stride(&self) -> usize {
        self.seq_len + 1
    }

    pub fn n_train_seqs(&self) -> usize {
        self.train.len() / self.stride()
    }

    pub fn n_test_seqs(&self) -> usize {
        self.test.len() / self.stride()
    }

    /// Sequence `i` of the train pool (length `seq_len + 1`).
    pub fn train_seq(&self, i: usize) -> &[i32] {
        &self.train[i * self.stride()..(i + 1) * self.stride()]
    }

    pub fn test_seq(&self, i: usize) -> &[i32] {
        &self.test[i * self.stride()..(i + 1) * self.stride()]
    }

    /// Node shard: sequence indices owned by `node`.
    pub fn shard(&self, node: usize) -> std::ops::Range<usize> {
        node * self.seqs_per_node..(node + 1) * self.seqs_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TokensData {
        let mut rng = SimRng::new(3);
        TokensData::generate(
            &TokensParams { nodes: 4, seqs_per_node: 8, test_seqs: 16, ..Default::default() },
            &mut rng,
        )
    }

    #[test]
    fn counts_and_ranges() {
        let d = gen();
        assert_eq!(d.n_train_seqs(), 32);
        assert_eq!(d.n_test_seqs(), 16);
        assert_eq!(d.train_seq(0).len(), 65);
        assert!(d.train.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn shards_disjoint_and_cover() {
        let d = gen();
        let mut covered = vec![false; d.n_train_seqs()];
        for node in 0..4 {
            for i in d.shard(node) {
                assert!(!covered[i]);
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn markov_structure_present() {
        // Successor distribution must be peaked: measure how often the next
        // token is one seen after the same token elsewhere.
        let d = gen();
        let mut succ: Vec<std::collections::HashSet<i32>> = vec![Default::default(); 64];
        for s in 0..d.n_train_seqs() {
            let seq = d.train_seq(s);
            for w in seq.windows(2) {
                succ[w[0] as usize].insert(w[1]);
            }
        }
        let avg: f64 =
            succ.iter().map(|s| s.len() as f64).sum::<f64>() / 64.0;
        // With branching 4 + 10% uniform leak, distinct successors per token
        // should be far below vocab size.
        assert!(avg < 32.0, "avg successors {avg}");
    }
}
