//! Synthetic low-rank ratings task (MovieLens-100K substitute).
//!
//! Ground truth is a rank-`k` latent model plus user/item biases and noise,
//! clipped to the 0.5–5 star range. Each *user* rates a random subset of
//! items; the paper's one-user-one-node setup maps users round-robin onto
//! nodes (identity map at full scale, several users per node in scaled
//! runs — the model still has the full 610-user embedding table because
//! the AOT'd parameter shapes are fixed).

use crate::sim::{SamplingVersion, SimRng};

/// One (user, item, rating) triple.
pub type RatingRow = (u32, u32, f32);

#[derive(Debug, Clone)]
pub struct RatingsParams {
    pub users: usize,
    pub items: usize,
    pub nodes: usize,
    pub latent_dim: usize,
    pub ratings_per_user: usize,
    pub test_per_user: usize,
    pub noise: f32,
    /// Which sampling stream draws each user's rated-item subset. `v1`
    /// full-shuffles the whole 9.7k-item catalogue per user (the frozen
    /// historical stream); `v2` is O(ratings_per_user) per user.
    pub sampling: SamplingVersion,
}

impl Default for RatingsParams {
    fn default() -> Self {
        RatingsParams {
            users: 610,
            items: 9724,
            nodes: 610,
            latent_dim: 10,
            ratings_per_user: 140, // ~100k ratings over 610 users + test
            test_per_user: 25,
            noise: 0.3,
            sampling: SamplingVersion::default(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct RatingsData {
    pub users: usize,
    pub items: usize,
    pub train: Vec<RatingRow>,
    pub test: Vec<RatingRow>,
    /// Per-node indices into `train` (users mapped round-robin to nodes).
    pub shards: Vec<Vec<u32>>,
}

impl RatingsData {
    pub fn generate(p: &RatingsParams, rng: &mut SimRng) -> RatingsData {
        let k = p.latent_dim;
        let gauss_vec = |n: usize, scale: f32, rng: &mut SimRng| -> Vec<f32> {
            (0..n).map(|_| scale * rng.next_gaussian() as f32).collect()
        };
        let u_lat = gauss_vec(p.users * k, 0.6, rng);
        let i_lat = gauss_vec(p.items * k, 0.6, rng);
        let u_bias = gauss_vec(p.users, 0.4, rng);
        let i_bias = gauss_vec(p.items, 0.4, rng);

        let rate = |u: usize, i: usize, rng: &mut SimRng| -> f32 {
            let dot: f32 = (0..k).map(|d| u_lat[u * k + d] * i_lat[i * k + d]).sum();
            let r = 3.0 + u_bias[u] + i_bias[i] + dot + p.noise * rng.next_gaussian() as f32;
            r.clamp(0.5, 5.0)
        };

        let mut train = Vec::new();
        let mut test = Vec::new();
        let mut shards = vec![Vec::new(); p.nodes];
        for u in 0..p.users {
            let node = u % p.nodes;
            let total = p.ratings_per_user + p.test_per_user;
            let items =
                rng.sample_indices_versioned(p.sampling, p.items, total.min(p.items));
            for (j, &i) in items.iter().enumerate() {
                let r = rate(u, i, rng);
                if j < p.ratings_per_user {
                    shards[node].push(train.len() as u32);
                    train.push((u as u32, i as u32, r));
                } else {
                    test.push((u as u32, i as u32, r));
                }
            }
        }
        RatingsData { users: p.users, items: p.items, train, test, shards }
    }

    /// Baseline MSE of predicting the global mean — training must beat this.
    pub fn global_mean_mse(&self) -> f64 {
        let mean: f64 =
            self.test.iter().map(|&(_, _, r)| r as f64).sum::<f64>() / self.test.len() as f64;
        self.test
            .iter()
            .map(|&(_, _, r)| (r as f64 - mean).powi(2))
            .sum::<f64>()
            / self.test.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> RatingsData {
        let mut rng = SimRng::new(2);
        RatingsData::generate(
            &RatingsParams {
                users: 60,
                items: 500,
                nodes: 30,
                ratings_per_user: 40,
                test_per_user: 10,
                ..Default::default()
            },
            &mut rng,
        )
    }

    #[test]
    fn counts() {
        let d = gen();
        assert_eq!(d.train.len(), 60 * 40);
        assert_eq!(d.test.len(), 60 * 10);
        assert_eq!(d.shards.len(), 30);
        // 2 users per node
        assert!(d.shards.iter().all(|s| s.len() == 80));
    }

    #[test]
    fn ratings_in_star_range() {
        let d = gen();
        assert!(d.train.iter().all(|&(_, _, r)| (0.5..=5.0).contains(&r)));
    }

    #[test]
    fn indices_in_range() {
        let d = gen();
        assert!(d.train.iter().all(|&(u, i, _)| u < 60 && i < 500));
    }

    #[test]
    fn shards_partition_train() {
        let d = gen();
        let mut seen: Vec<u32> = d.shards.iter().flatten().copied().collect();
        seen.sort_unstable();
        let expect: Vec<u32> = (0..d.train.len() as u32).collect();
        assert_eq!(seen, expect);
    }

    #[test]
    fn users_stay_on_their_node() {
        let d = gen();
        for (node, shard) in d.shards.iter().enumerate() {
            for &idx in shard {
                let (u, _, _) = d.train[idx as usize];
                assert_eq!(u as usize % 30, node);
            }
        }
    }

    #[test]
    fn structure_is_learnable() {
        // Latent structure should give the test set variance well above the
        // noise floor, so MF training has signal to extract.
        let d = gen();
        assert!(d.global_mean_mse() > 0.3, "{}", d.global_mean_mse());
    }

    #[test]
    fn v2_sampling_is_deterministic_with_identical_shape() {
        let mk = |sampling| {
            let mut rng = SimRng::new(2);
            RatingsData::generate(
                &RatingsParams {
                    users: 60,
                    items: 500,
                    nodes: 30,
                    ratings_per_user: 40,
                    test_per_user: 10,
                    sampling,
                    ..Default::default()
                },
                &mut rng,
            )
        };
        let a = mk(SamplingVersion::V2Partial);
        let b = mk(SamplingVersion::V2Partial);
        assert_eq!(a.train, b.train);
        assert_eq!(a.test, b.test);
        assert_eq!(a.shards, b.shards);
        // Same dataset shape as V1 — only the drawn item subsets differ.
        let v1 = mk(SamplingVersion::V1Shuffle);
        assert_eq!(v1.train.len(), a.train.len());
        assert_eq!(v1.test.len(), a.test.len());
        assert!(a.train.iter().all(|&(u, i, _)| u < 60 && i < 500));
    }
}
