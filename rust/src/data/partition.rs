//! How training data is split across nodes.

/// Partitioning strategy for per-node shards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniformly random class assignment (paper's CIFAR10 setup).
    Iid,
    /// Label-Dirichlet non-IIDness: each node draws a class distribution
    /// from Dirichlet(alpha); small alpha = highly skewed shards (stands in
    /// for the LEAF CelebA/FEMNIST per-writer splits).
    Dirichlet(f64),
}

impl Partition {
    /// The alpha used by our non-IID experiments when reproducing the
    /// paper's LEAF tasks. 0.3 gives a skew comparable to per-writer
    /// FEMNIST shards (most nodes see a handful of dominant classes).
    pub const NON_IID_ALPHA: f64 = 0.3;

    pub fn non_iid() -> Partition {
        Partition::Dirichlet(Self::NON_IID_ALPHA)
    }

    pub fn is_iid(&self) -> bool {
        matches!(self, Partition::Iid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert!(Partition::Iid.is_iid());
        assert!(!Partition::non_iid().is_iid());
    }
}
