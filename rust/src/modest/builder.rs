//! Scenario-API glue for MoDeST: the [`SessionBuilder`] registered under
//! `modest`, plus the shared assembly the FedAvg emulation reuses.

use anyhow::Result;

use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolMeta, ScenarioSpec, Session, SessionBuilder};
use crate::sim::{ChurnSchedule, ResumeOptions, SimTime, SnapshotReader};

use super::session::{ModestConfig, ModestSession};

/// Derive the MoDeST protocol config from a scenario spec.
pub fn modest_config(spec: &ScenarioSpec) -> Result<ModestConfig> {
    Ok(ModestConfig {
        s: spec.resolved_s()?,
        a: spec.resolved_a()?,
        sf: spec.protocol.sf,
        dt: SimTime::from_secs_f64(spec.protocol.dt_s),
        dk: spec.protocol.dk,
        max_time: SimTime::from_secs_f64(spec.run.max_time_s),
        max_rounds: spec.run.max_rounds,
        eval_interval: SimTime::from_secs_f64(spec.run.eval_interval_s),
        target_metric: spec.run.target_metric,
        seed: spec.run.seed,
        sampling: spec.run.sampling,
        fedavg_server: None,
        spec_json: Some(spec.snapshot_json()),
        checkpoint_at: spec.run.checkpoint_at_s.map(SimTime::from_secs_f64),
        checkpoint_out: spec.run.checkpoint_out.clone(),
        reliability: spec.network.reliability(),
        progress: spec.progress_config()?,
        threads: spec.run.threads,
    })
}

/// Assemble a [`ModestSession`] from a scenario. `fedavg` switches on the
/// §4.3 emulation (fixed best-connected aggregator, unlimited server
/// capacity, sf = 1) — shared here because FedAvg *is* the MoDeST stack
/// under a degenerate config, not a separate protocol implementation.
pub fn assemble_modest(
    spec: &ScenarioSpec,
    runtime: Option<&XlaRuntime>,
    churn: ChurnSchedule,
    fedavg: bool,
) -> Result<ModestSession> {
    let n = spec.resolved_nodes()?;
    // Churn scripts may introduce node ids beyond the initial population;
    // the dataset/fabric/compute substrates must cover them too.
    let max_n = n.max(churn.node_extent());
    let task = spec.build_task_for(runtime, max_n)?;
    let fabric = spec.build_fabric(max_n)?;
    let compute = spec.build_compute(max_n);
    let mut cfg = modest_config(spec)?;
    if fedavg {
        cfg = crate::baselines::fedavg_config(&cfg, fabric.latency(), n);
    }
    Ok(ModestSession::new(cfg, n, task, compute, fabric, churn))
}

impl Session for ModestSession {
    fn run(self: Box<Self>) -> (crate::metrics::SessionMetrics, crate::net::TrafficLedger) {
        ModestSession::run(*self)
    }

    fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        ModestSession::snapshot_bytes(self)
    }

    fn resume(&mut self, r: &mut SnapshotReader, opts: &ResumeOptions) -> Result<()> {
        ModestSession::resume(self, r, opts)
    }
}

/// Registry factory for MoDeST.
pub struct ModestBuilder;

impl SessionBuilder for ModestBuilder {
    fn meta(&self) -> ProtocolMeta {
        ProtocolMeta {
            name: "modest",
            label: "MoDeST",
            aliases: &[],
            summary: "the paper's protocol: decentralized client sampling, `s` \
                      trainers + `a` aggregators per round, churn-tolerant views",
            default_round_budget: 200,
            default_params: &[],
        }
    }

    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        Ok(Box::new(assemble_modest(spec, runtime, churn, false)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_session_builds_without_artifacts() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.population.nodes = 12;
        spec.run.max_time_s = 5.0;
        assert!(assemble_modest(&spec, None, ChurnSchedule::empty(), false).is_ok());
    }

    #[test]
    fn config_resolves_preset_s_and_a() {
        let spec = ScenarioSpec::new("cifar10", "modest");
        let cfg = modest_config(&spec).unwrap();
        assert_eq!(cfg.s, 10);
        assert_eq!(cfg.a, 3);
        assert_eq!(cfg.fedavg_server, None);
    }
}
