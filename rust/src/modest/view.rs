//! The network *view*: registry + activity, piggybacked on model transfers.

use crate::net::SizeModel;
use crate::{NodeId, Round};

use super::activity::ActivityClock;
use super::registry::Registry;

/// `V_i = (C_i, E_i, N_i)` — what Alg. 4 piggybacks on train/aggregate.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct View {
    pub registry: Registry,
    pub activity: ActivityClock,
}

impl View {
    /// `MergeView(V_j)`.
    pub fn merge(&mut self, other: &View) {
        self.registry.merge(&other.registry);
        self.activity.merge(&other.activity);
    }

    /// `Candidates(k)`: registered AND active within `Δk` rounds, sorted by
    /// id (deterministic input to the sampler's hash ordering).
    pub fn candidates(&self, k: Round, dk: Round) -> Vec<NodeId> {
        self.registry
            .registered()
            .filter(|&j| self.activity.active_within(j, k, dk))
            .collect()
    }

    /// Serialized size of this view in the wire-size model.
    pub fn wire_bytes(&self, sizes: &SizeModel) -> u64 {
        sizes.registry_entry * self.registry.len() as u64
            + sizes.activity_entry * self.activity.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modest::registry::MembershipEvent::*;

    fn view_with(nodes: &[(NodeId, u64, bool, Round)]) -> View {
        let mut v = View::default();
        for &(n, c, joined, act) in nodes {
            v.registry.update(n, c, if joined { Joined } else { Left });
            v.activity.update(n, act);
        }
        v
    }

    #[test]
    fn candidates_require_registered_and_active() {
        let v = view_with(&[
            (1, 1, true, 95),  // in
            (2, 1, true, 50),  // too old
            (3, 2, false, 99), // left
            (4, 1, true, 100), // in
        ]);
        assert_eq!(v.candidates(100, 20), vec![1, 4]);
    }

    #[test]
    fn merge_combines_both_parts() {
        let mut a = view_with(&[(1, 1, true, 5)]);
        let b = view_with(&[(1, 2, false, 9), (2, 1, true, 3)]);
        a.merge(&b);
        assert!(!a.registry.is_registered(1));
        assert_eq!(a.activity.get(1), Some(9));
        assert_eq!(a.candidates(4, 20), vec![2]);
    }

    #[test]
    fn wire_bytes_scale_with_entries() {
        let sizes = SizeModel::default();
        let small = view_with(&[(1, 1, true, 0)]);
        let big = view_with(&[(1, 1, true, 0), (2, 1, true, 0), (3, 1, true, 0)]);
        assert!(big.wire_bytes(&sizes) == 3 * small.wire_bytes(&sizes));
    }
}
