//! The MoDeST session: Alg. 1–4 driven over the discrete-event simulator.
//!
//! One `ModestSession` owns the node table, the virtual network (latency +
//! traffic ledger), the learning [`Task`], a churn script, and the event
//! queue. `run()` executes the session to its time/round budget and returns
//! [`SessionMetrics`].
//!
//! Faithfulness notes:
//! * Sampling (Alg. 1) pings the first `need` candidates in parallel, then
//!   walks the tail one-by-one, each wait bounded by `Δt`; exhausted
//!   candidate lists retry after `Δt` with a freshly recomputed order
//!   ("network may be asynchronous, retry").
//! * Views travel only on `train`/`aggregate` messages (§3.6).
//! * The multi-aggregator fast path falls out of `k_train` dedup: the first
//!   aggregator's `train` starts local training, later copies are ignored.
//! * FedAvg emulation (§4.3) is available via [`ModestConfig::fedavg_mode`]:
//!   aggregator fixed to one node, no sampling pings for it.

use std::sync::Arc;


use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::{JoinTrace, SessionMetrics, TrafficSummary};
use crate::net::{LatencyMatrix, MsgKind, SizeModel, TrafficLedger};
use crate::sim::{ChurnKind, ChurnSchedule, EventQueue, SimRng, SimTime};
use crate::{NodeId, Round};

use super::node::{ModelRef, ModestNode, Msg, NodeAction, Purpose, SampleOp};
use super::registry::MembershipEvent;
use super::sampler::candidate_order;

/// MoDeST parameters (paper Table 2) plus session plumbing.
#[derive(Debug, Clone)]
pub struct ModestConfig {
    /// Sample size `s` (trainers per round).
    pub s: usize,
    /// Aggregators per round `a` (choose z+1 for z expected failures).
    pub a: usize,
    /// Success fraction `sf` of models required to aggregate.
    pub sf: f64,
    /// Ping timeout `Δt`.
    pub dt: SimTime,
    /// Activity window `Δk` in rounds.
    pub dk: Round,
    /// Stop after this much virtual time.
    pub max_time: SimTime,
    /// Stop once this round has been dispatched (0 = unlimited).
    pub max_rounds: Round,
    /// Evaluate the latest global model this often.
    pub eval_interval: SimTime,
    /// Stop early when the metric crosses this target (accuracy >=, mse <=).
    pub target_metric: Option<f64>,
    /// RNG seed for everything in the session.
    pub seed: u64,
    /// Uplink/downlink bandwidth in bits/s applied to transfers.
    pub bandwidth_bps: f64,
    /// FedAvg emulation (§4.3): fix this node as the only aggregator, skip
    /// sampling pings toward it, give it infinite bandwidth.
    pub fedavg_server: Option<NodeId>,
}

impl Default for ModestConfig {
    fn default() -> Self {
        ModestConfig {
            s: 10,
            a: 3,
            sf: 0.9,
            dt: SimTime::from_secs_f64(2.0),
            dk: 20,
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            target_metric: None,
            seed: 42,
            bandwidth_bps: 50e6,
            fedavg_server: None,
        }
    }
}

/// Internal DES events.
enum Event {
    Deliver { to: NodeId, msg: Msg },
    SampleTimer { node: NodeId, op: u64 },
    TrainDone { node: NodeId, seq: u64 },
    Churn(usize),
    Probe,
}

/// Liveness status of a simulated node process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    Alive,
    /// Crashed or left: drops all messages and timers.
    Dead,
    /// Scripted to join later; does not exist yet.
    NotJoined,
}

pub struct ModestSession {
    cfg: ModestConfig,
    queue: EventQueue<Event>,
    nodes: Vec<ModestNode>,
    status: Vec<Status>,
    task: Box<dyn Task>,
    compute: ComputeModel,
    latency: LatencyMatrix,
    sizes: SizeModel,
    traffic: TrafficLedger,
    churn: ChurnSchedule,
    rng: SimRng,
    /// Latest aggregated model dispatched by any aggregator.
    latest_global: Model,
    latest_round: Round,
    metrics: SessionMetrics,
    /// Ids of the initial population (observers for join traces).
    initial_nodes: usize,
    join_watch: Vec<(NodeId, f64)>,
    done: bool,
}

impl ModestSession {
    /// Build a session over `n_initial` pre-registered nodes (everyone knows
    /// everyone, activity 0) plus whatever the churn script adds later.
    pub fn new(
        cfg: ModestConfig,
        n_initial: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        latency: LatencyMatrix,
        churn: ChurnSchedule,
    ) -> ModestSession {
        let mut rng = SimRng::new(cfg.seed ^ 0x6d6f6465_73740001);
        let max_node = churn
            .events()
            .iter()
            .map(|e| e.node as usize + 1)
            .max()
            .unwrap_or(0)
            .max(n_initial);
        let mut nodes: Vec<ModestNode> = (0..max_node as NodeId).map(ModestNode::new).collect();
        let mut status = vec![Status::NotJoined; max_node];

        // Initial population: registered with counter 1, activity 0.
        for node in nodes.iter_mut().take(n_initial) {
            node.counter = 1;
        }
        for i in 0..n_initial {
            status[i] = Status::Alive;
            for j in 0..n_initial {
                nodes[i]
                    .view
                    .registry
                    .update(j as NodeId, 1, MembershipEvent::Joined);
                nodes[i].view.activity.update(j as NodeId, 0);
            }
        }

        let latest_global = task.init_model();
        let mut compute = compute;
        compute.ensure_nodes(max_node, &mut rng);

        ModestSession {
            cfg,
            queue: EventQueue::new(),
            nodes,
            status,
            task,
            compute,
            latency,
            sizes: SizeModel::default(),
            traffic: TrafficLedger::new(max_node),
            churn,
            rng,
            latest_global,
            latest_round: 0,
            metrics: SessionMetrics::default(),
            initial_nodes: n_initial,
            join_watch: Vec::new(),
            done: false,
        }
    }

    pub fn metrics(&self) -> &SessionMetrics {
        &self.metrics
    }

    pub fn traffic(&self) -> &TrafficLedger {
        &self.traffic
    }

    pub fn latest_global(&self) -> (&Model, Round) {
        (&self.latest_global, self.latest_round)
    }

    // ---------------------------------------------------------------- wiring

    fn is_alive(&self, n: NodeId) -> bool {
        self.status[n as usize] == Status::Alive
    }

    /// Account + schedule a message. Self-sends are loopback: no traffic,
    /// no latency.
    fn send(&mut self, from: NodeId, to: NodeId, msg: Msg) {
        if from == to {
            self.queue.schedule_in(SimTime::ZERO, Event::Deliver { to, msg });
            return;
        }
        let (parts, bytes): (Vec<(MsgKind, u64)>, u64) = match &msg {
            Msg::Ping { .. } | Msg::Pong { .. } => {
                let b = self.sizes.ping_bytes();
                (vec![(MsgKind::Control, b)], b)
            }
            Msg::Joined { .. } | Msg::Left { .. } => {
                let b = self.sizes.membership_bytes();
                (vec![(MsgKind::Membership, b)], b)
            }
            Msg::Train { view, .. } | Msg::Aggregate { view, .. } => {
                let model_b = self.task.model_bytes();
                let view_b = view.wire_bytes(&self.sizes);
                let total = self.sizes.model_transfer_bytes(model_b, 0) + view_b;
                (
                    vec![
                        (MsgKind::ModelPayload, model_b),
                        (MsgKind::ViewPayload, total - model_b),
                    ],
                    total,
                )
            }
        };
        self.traffic.record_parts(from, to, &parts);
        // FedAvg server gets unlimited bandwidth (paper §4.3).
        let unlimited = self.cfg.fedavg_server == Some(from) || self.cfg.fedavg_server == Some(to);
        let bw = if unlimited { f64::INFINITY } else { self.cfg.bandwidth_bps };
        let transfer = SimTime::from_secs_f64((bytes as f64 * 8.0 / bw).min(3600.0));
        let delay = self.latency.one_way(from, to) + transfer;
        self.queue.schedule_in(delay, Event::Deliver { to, msg });
    }

    fn local_seed(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    // ------------------------------------------------------------- sampling

    /// Start `Sample(round, need)` at `node` with the given continuation.
    fn start_sample(&mut self, node: NodeId, round: Round, need: usize, purpose: Purpose, payload: ModelRef) {
        // FedAvg emulation: the sample is fixed — aggregator = the server;
        // participants chosen uniformly by the server without pings.
        if let Some(server) = self.cfg.fedavg_server {
            let targets: Vec<NodeId> = match purpose {
                Purpose::Aggregators => vec![server],
                Purpose::Participants => {
                    let alive: Vec<NodeId> = (0..self.nodes.len() as NodeId)
                        .filter(|&j| self.is_alive(j) && Some(j) != self.cfg.fedavg_server)
                        .collect();
                    let k = need.min(alive.len());
                    let mut rng = SimRng::new(self.local_seed(node, round) ^ 0xfeda);
                    rng.sample_indices(alive.len(), k)
                        .into_iter()
                        .map(|i| alive[i])
                        .collect()
                }
            };
            self.dispatch_payload(node, round, purpose, payload, &targets, SimTime::ZERO, 0);
            return;
        }

        let op_id = {
            let n = &mut self.nodes[node as usize];
            n.next_op += 1;
            let candidates = n.view.candidates(round, self.cfg.dk);
            let order = candidate_order(round, &candidates);
            let op = SampleOp {
                id: n.next_op,
                round,
                need,
                purpose,
                payload,
                order,
                next_tail: 0,
                done: false,
                started: self.queue.now(),
                retries: 0,
            };
            n.ops.push(op);
            n.next_op
        };
        self.pump_sample(node, op_id, true);
    }

    /// Advance a sampling op: initial parallel pings or the sequential tail.
    fn pump_sample(&mut self, node: NodeId, op_id: u64, initial: bool) {
        // Completion may already be possible from earlier pongs this round.
        if self.try_complete(node, op_id) {
            return;
        }
        let mut pings: Vec<NodeId> = Vec::new();
        let round;
        {
            let n = &mut self.nodes[node as usize];
            let Some(pos) = n.ops.iter().position(|o| o.id == op_id && !o.done) else {
                return;
            };
            round = n.ops[pos].round;
            let (need, next_tail, order_len) = {
                let op = &n.ops[pos];
                (op.need, op.next_tail, op.order.len())
            };
            if initial {
                // Alg. 1: ping the first `need` in parallel.
                let op = &mut n.ops[pos];
                let first = need.min(order_len);
                pings.extend_from_slice(&op.order[..first]);
                op.next_tail = first;
            } else if next_tail < order_len {
                // Sequential tail: one more candidate.
                let op = &mut n.ops[pos];
                pings.push(op.order[next_tail]);
                op.next_tail += 1;
            } else {
                // Exhausted: retry with a recomputed order (the view may
                // have changed; the network may have been asynchronous).
                let candidates = n.view.candidates(round, self.cfg.dk);
                let op = &mut n.ops[pos];
                op.retries += 1;
                op.order = candidate_order(round, &candidates);
                let first = need.min(op.order.len());
                pings.extend_from_slice(&op.order[..first]);
                op.next_tail = first;
            }
        }
        for j in pings {
            self.send(node, j, Msg::Ping { round, from: node });
        }
        self.queue
            .schedule_in(self.cfg.dt, Event::SampleTimer { node, op: op_id });
    }

    /// If the op has enough pongs, dispatch its continuation. Returns true
    /// if completed.
    fn try_complete(&mut self, node: NodeId, op_id: u64) -> bool {
        let (round, purpose, payload, targets, started, retries) = {
            let n = &mut self.nodes[node as usize];
            let Some(idx) = n.ops.iter().position(|o| o.id == op_id && !o.done) else {
                return true; // already done/garbage-collected
            };
            let enough = {
                let op = &n.ops[idx];
                n.pongs.get(&op.round).map_or(0, |l| l.len()) >= op.need
            };
            if !enough {
                return false;
            }
            let live = n.live_for(&n.ops[idx]);
            let op = &mut n.ops[idx];
            op.done = true;
            (op.round, op.purpose, op.payload.clone(), live, op.started, op.retries)
        };
        self.metrics
            .record_sample(self.queue.now(), started, round, retries);
        self.dispatch_payload(node, round, purpose, payload, &targets, started, retries);
        self.nodes[node as usize].gc();
        true
    }

    /// Send the continuation messages of a completed sample.
    fn dispatch_payload(
        &mut self,
        node: NodeId,
        round: Round,
        purpose: Purpose,
        payload: ModelRef,
        targets: &[NodeId],
        _started: SimTime,
        _retries: u32,
    ) {
        match purpose {
            Purpose::Aggregators => {
                // Trainer pushes its updated model to A^{round}.
                let view = self.nodes[node as usize].view.clone();
                for &j in targets {
                    self.send(
                        node,
                        j,
                        Msg::Aggregate { round, model: payload.clone(), view: view.clone() },
                    );
                }
            }
            Purpose::Participants => {
                // Aggregator averages Θ and pushes to S^{round}.
                let avg = {
                    let n = &self.nodes[node as usize];
                    let models: Vec<&Model> = n.theta.iter().map(|m| m.as_ref()).collect();
                    if models.is_empty() {
                        return;
                    }
                    Arc::new(self.task.aggregate(&models).expect("aggregate"))
                };
                self.nodes[node as usize].theta.clear();
                // Track the freshest global model for evaluation.
                if round > self.latest_round {
                    self.latest_round = round;
                    self.latest_global = (*avg).clone();
                    self.metrics.record_round_start(round, self.queue.now());
                }
                let view = self.nodes[node as usize].view.clone();
                for &j in targets {
                    self.send(node, j, Msg::Train { round, model: avg.clone(), view: view.clone() });
                }
                let _ = payload; // participants' payload slot unused (avg built here)
            }
        }
    }

    // ------------------------------------------------------------- handlers

    fn handle_deliver(&mut self, to: NodeId, msg: Msg) {
        if !self.is_alive(to) {
            return; // dropped at a dead/not-yet-joined node
        }
        match msg {
            Msg::Ping { round, from } => {
                let act = self.nodes[to as usize].on_ping(round, from);
                if let NodeAction::SendPong { to: peer, round } = act {
                    self.send(to, peer, Msg::Pong { round, from: to });
                }
            }
            Msg::Pong { round, from } => {
                let completable = self.nodes[to as usize].on_pong(round, from);
                for op in completable {
                    self.try_complete(to, op);
                }
            }
            Msg::Joined { node, counter } => {
                self.nodes[to as usize].on_membership(node, counter, true);
            }
            Msg::Left { node, counter } => {
                self.nodes[to as usize].on_membership(node, counter, false);
            }
            Msg::Aggregate { round, model, view } => {
                self.nodes[to as usize].last_active = self.queue.now();
                let act = self.nodes[to as usize].on_aggregate(
                    round,
                    model,
                    &view,
                    self.cfg.s,
                    self.cfg.sf,
                );
                if let NodeAction::BeginParticipantSample { round } = act {
                    // Virtual cost of the averaging itself.
                    let k = self.nodes[to as usize].theta.len();
                    let _cost = self
                        .compute
                        .aggregate_time(to, k, self.task.model_bytes());
                    // Aggregator samples the round's participants (Alg. 4 l.19).
                    let dummy = Arc::new(Vec::new());
                    self.start_sample(to, round, self.cfg.s, Purpose::Participants, dummy);
                }
            }
            Msg::Train { round, model, view } => {
                self.nodes[to as usize].last_active = self.queue.now();
                let act = self.nodes[to as usize].on_train(round, model, &view);
                if let NodeAction::BeginTraining { round, seq } = act {
                    if self.cfg.max_rounds > 0 && round > self.cfg.max_rounds {
                        self.done = true;
                        return;
                    }
                    let batches = self.task.batches_per_epoch(to);
                    let dur = self.compute.train_time(to, batches);
                    self.queue.schedule_in(dur, Event::TrainDone { node: to, seq });
                }
            }
        }
    }

    fn handle_train_done(&mut self, node: NodeId, seq: u64) {
        if !self.is_alive(node) {
            return;
        }
        let Some((round, input)) = self.nodes[node as usize].training_valid(seq) else {
            return; // canceled by a newer round
        };
        let seed = self.local_seed(node, round);
        let (updated, _loss, _batches) = self
            .task
            .local_update(&input, node, seed)
            .expect("local_update");
        self.nodes[node as usize].training = None;
        // Push to the aggregators of round+1 (Alg. 4 lines 33-37).
        self.start_sample(
            node,
            round + 1,
            self.cfg.a,
            Purpose::Aggregators,
            Arc::new(updated),
        );
    }

    fn handle_churn(&mut self, idx: usize) {
        let ev = self.churn.events()[idx];
        match ev.kind {
            ChurnKind::Join | ChurnKind::Recover => {
                let i = ev.node as usize;
                self.status[i] = Status::Alive;
                let node = &mut self.nodes[i];
                node.counter += 1;
                let c = node.counter;
                node.view
                    .registry
                    .update(ev.node, c, MembershipEvent::Joined);
                node.view.activity.update(ev.node, 0);
                // Advertise to s random alive peers (bootstrap set P).
                let peers: Vec<NodeId> = (0..self.nodes.len() as NodeId)
                    .filter(|&j| j != ev.node && self.is_alive(j))
                    .collect();
                let k = self.cfg.s.min(peers.len());
                let picks = self.rng.sample_indices(peers.len(), k);
                for p in picks {
                    self.send(ev.node, peers[p], Msg::Joined { node: ev.node, counter: c });
                }
                self.join_watch.push((ev.node, self.queue.now().as_secs_f64()));
                self.metrics.joins.push(JoinTrace {
                    joiner: ev.node,
                    joined_at_s: self.queue.now().as_secs_f64(),
                    missing: Vec::new(),
                });
            }
            ChurnKind::Leave => {
                let i = ev.node as usize;
                if self.status[i] != Status::Alive {
                    return;
                }
                let node = &mut self.nodes[i];
                node.counter += 1;
                let c = node.counter;
                node.view.registry.update(ev.node, c, MembershipEvent::Left);
                let peers: Vec<NodeId> = (0..self.nodes.len() as NodeId)
                    .filter(|&j| j != ev.node && self.is_alive(j))
                    .collect();
                let k = self.cfg.s.min(peers.len());
                let picks = self.rng.sample_indices(peers.len(), k);
                for p in picks {
                    self.send(ev.node, peers[p], Msg::Left { node: ev.node, counter: c });
                }
                self.status[i] = Status::Dead;
            }
            ChurnKind::Crash => {
                self.status[ev.node as usize] = Status::Dead;
            }
        }
    }

    /// §3.5 auto-rejoin: a reliable node that has not been activated for
    /// more than `Δk * Δt̄` (average round time) re-advertises itself, so a
    /// falsely-suspected node re-enters the candidate set.
    fn auto_rejoin(&mut self) {
        if self.cfg.fedavg_server.is_some() {
            return; // FL emulation has no membership protocol
        }
        let round_time = self.metrics.mean_round_time_s().unwrap_or(10.0).max(1.0);
        let horizon = SimTime::from_secs_f64(self.cfg.dk as f64 * round_time);
        let now = self.queue.now();
        let mut rejoiners = Vec::new();
        for i in 0..self.nodes.len() {
            if self.status[i] != Status::Alive {
                continue;
            }
            let idle = now.saturating_sub(self.nodes[i].last_active);
            if idle > horizon {
                rejoiners.push(i as NodeId);
            }
        }
        for node in rejoiners {
            let (c, peers) = {
                let n = &mut self.nodes[node as usize];
                n.counter += 1;
                let c = n.counter;
                n.view.registry.update(node, c, MembershipEvent::Joined);
                n.last_active = now; // throttle: try again after another horizon
                let peers: Vec<NodeId> = (0..self.nodes.len() as NodeId)
                    .filter(|&j| j != node && self.is_alive(j))
                    .collect();
                (c, peers)
            };
            let k = self.cfg.s.min(peers.len());
            for p in self.rng.sample_indices(peers.len(), k) {
                self.send(node, peers[p], Msg::Joined { node, counter: c });
            }
        }
    }

    fn handle_probe(&mut self) {
        self.auto_rejoin();
        // Join-propagation traces (Fig. 5): count initial-population nodes
        // that still don't know each watched joiner.
        let now_s = self.queue.now().as_secs_f64();
        for w in 0..self.join_watch.len() {
            let (joiner, _) = self.join_watch[w];
            let missing = (0..self.initial_nodes)
                .filter(|&i| {
                    self.status[i] == Status::Alive
                        && !self.nodes[i].view.registry.knows(joiner)
                })
                .count();
            if let Some(trace) = self.metrics.joins.iter_mut().find(|t| t.joiner == joiner) {
                trace.missing.push((now_s, missing));
            }
        }
        // Convergence curve on the freshest global model.
        let eval = self
            .task
            .evaluate(&self.latest_global)
            .expect("evaluate");
        self.metrics.record_eval(
            self.queue.now(),
            self.latest_round,
            eval.metric,
            eval.loss,
            0.0,
        );
        if let Some(target) = self.cfg.target_metric {
            let hit = if self.task.metric_is_accuracy() {
                eval.metric >= target
            } else {
                eval.metric <= target
            };
            if hit {
                self.done = true;
            }
        }
    }

    // ------------------------------------------------------------------ run

    /// Bootstrap round 1 (Alg. 4 lines 6-8): every node in S^1 starts
    /// training the initial model.
    fn bootstrap(&mut self) {
        let init = Arc::new(self.task.init_model());
        // All initial nodes share the same view, so S^1 is consistent.
        let candidates: Vec<NodeId> = (0..self.initial_nodes as NodeId).collect();
        let order = candidate_order(1, &candidates);
        let view = self.nodes[0].view.clone();
        for &i in order.iter().take(self.cfg.s.min(order.len())) {
            self.queue.schedule_in(
                SimTime::ZERO,
                Event::Deliver {
                    to: i,
                    msg: Msg::Train { round: 1, model: init.clone(), view: view.clone() },
                },
            );
        }
        self.metrics.record_round_start(1, SimTime::ZERO);
    }

    /// Run to completion; returns the collected metrics.
    pub fn run(mut self) -> (SessionMetrics, TrafficLedger) {
        // Schedule churn + probes.
        for (i, ev) in self.churn.events().iter().enumerate() {
            self.queue.schedule_at(ev.at, Event::Churn(i));
        }
        let mut t = self.cfg.eval_interval;
        while t <= self.cfg.max_time {
            self.queue.schedule_at(t, Event::Probe);
            t = t + self.cfg.eval_interval;
        }
        self.bootstrap();
        // Baseline evaluation of the initial model at t=0.
        self.handle_probe();

        while let Some((now, ev)) = self.queue.pop() {
            if now > self.cfg.max_time || self.done {
                break;
            }
            match ev {
                Event::Deliver { to, msg } => self.handle_deliver(to, msg),
                Event::SampleTimer { node, op } => {
                    if self.is_alive(node) {
                        self.pump_sample(node, op, false);
                    }
                }
                Event::TrainDone { node, seq } => self.handle_train_done(node, seq),
                Event::Churn(i) => self.handle_churn(i),
                Event::Probe => self.handle_probe(),
            }
        }

        // Always record a terminal evaluation point so short sessions still
        // produce a curve.
        self.handle_probe();
        self.metrics.final_round = self.latest_round;
        self.metrics.duration_s = self.queue.now().as_secs_f64();
        self.metrics.events = self.queue.events_processed();
        self.metrics.traffic = TrafficSummary::from_ledger(&self.traffic, self.nodes.len());
        (self.metrics, self.traffic)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::LatencyParams;

    fn quick_session(n: usize, cfg: ModestConfig) -> ModestSession {
        let mut rng = SimRng::new(cfg.seed);
        let task = MockTask::new(n, 16, 0.5, cfg.seed);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
        let compute = ComputeModel::uniform(n, 0.05);
        ModestSession::new(cfg, n, Box::new(task), compute, latency, ChurnSchedule::empty())
    }

    #[test]
    fn session_makes_rounds_and_converges() {
        let cfg = ModestConfig {
            s: 4,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 60,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = quick_session(16, cfg).run();
        assert!(m.final_round >= 20, "only reached round {}", m.final_round);
        let best = m.best_metric(true).unwrap();
        assert!(best > 0.8, "metric {best}");
        assert!(traffic.is_conserved());
        assert!(traffic.total() > 0);
    }

    #[test]
    fn rounds_advance_monotonically() {
        let cfg = ModestConfig {
            s: 3,
            a: 1,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 30,
            ..Default::default()
        };
        let (m, _) = quick_session(10, cfg).run();
        let rounds: Vec<Round> = m.round_starts.iter().map(|&(r, _)| r).collect();
        let mut sorted = rounds.clone();
        sorted.sort_unstable();
        assert_eq!(rounds, sorted);
        assert!(rounds.len() >= 10);
    }

    #[test]
    fn sample_durations_bounded_when_all_alive() {
        let cfg = ModestConfig {
            s: 4,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (m, _) = quick_session(12, cfg).run();
        assert!(!m.samples.is_empty());
        // With everyone alive, sampling = one parallel ping wave: its
        // duration is bounded by one RTT, far below the 2s timeout.
        for s in &m.samples {
            assert!(s.duration_s < 2.0, "sample took {}s", s.duration_s);
            assert_eq!(s.retries, 0);
        }
    }

    #[test]
    fn fedavg_mode_concentrates_traffic_on_server() {
        let cfg = ModestConfig {
            s: 4,
            a: 1,
            sf: 1.0,
            fedavg_server: Some(0),
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 25,
            ..Default::default()
        };
        let (m, traffic) = quick_session(12, cfg).run();
        assert!(m.final_round >= 10);
        let server = traffic.node_usage(0);
        let max_other = (1..12).map(|i| traffic.node_usage(i)).max().unwrap();
        assert!(server > 2 * max_other, "server {server} vs {max_other}");
    }

    #[test]
    fn crash_resilient_progress() {
        // Crash 4 of 12 nodes mid-run; rounds must continue.
        let churn = ChurnSchedule::mass_crash(
            12,
            8,
            2,
            SimTime::from_secs_f64(30.0),
            SimTime::from_secs_f64(10.0),
        );
        let cfg = ModestConfig {
            s: 4,
            a: 3,
            sf: 0.5,
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 0,
            ..Default::default()
        };
        let mut rng = SimRng::new(7);
        let task = MockTask::new(12, 16, 0.5, 7);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), 12, &mut rng.fork("lat"));
        let compute = ComputeModel::uniform(12, 0.05);
        let session =
            ModestSession::new(cfg, 12, Box::new(task), compute, latency, churn);
        let (m, _) = session.run();
        // Progress after the crash window (crashes end at t=60).
        let late_rounds = m
            .round_starts
            .iter()
            .filter(|&&(_, t)| t > 120.0)
            .count();
        assert!(late_rounds > 5, "no progress after crashes: {late_rounds}");
    }

    #[test]
    fn join_via_churn_eventually_known() {
        let churn = ChurnSchedule::staggered_joins(
            8,
            2,
            SimTime::from_secs_f64(20.0),
            SimTime::from_secs_f64(20.0),
        );
        let cfg = ModestConfig {
            s: 3,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(400.0),
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let mut rng = SimRng::new(9);
        let task = MockTask::new(10, 16, 0.5, 9);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), 10, &mut rng.fork("lat"));
        let compute = ComputeModel::uniform(10, 0.05);
        let session = ModestSession::new(cfg, 8, Box::new(task), compute, latency, churn);
        let (m, _) = session.run();
        assert_eq!(m.joins.len(), 2);
        for t in &m.joins {
            assert!(
                t.full_propagation_s().is_some(),
                "join of {} never fully propagated",
                t.joiner
            );
        }
    }
}
