//! The MoDeST session: Alg. 1–4 as a [`Protocol`] over the shared
//! [`SimHarness`].
//!
//! [`ModestProtocol`] holds only protocol state (the node table, the latest
//! aggregated model, join-propagation watches) and reacts to harness events
//! through [`Ctx`]; the event queue, liveness table, churn application,
//! probe/eval loop, stop conditions, and network fabric all live in the
//! harness. [`ModestSession`] is the assembly facade the builders and tests
//! use.
//!
//! Faithfulness notes:
//! * Sampling (Alg. 1) pings the first `need` candidates in parallel, then
//!   walks the tail one-by-one, each wait bounded by `Δt`; exhausted
//!   candidate lists retry after `Δt` with a freshly recomputed order
//!   ("network may be asynchronous, retry").
//! * Views travel only on `train`/`aggregate` messages (§3.6).
//! * The multi-aggregator fast path falls out of `k_train` dedup: the first
//!   aggregator's `train` starts local training, later copies are ignored.
//! * FedAvg emulation (§4.3) is available via `fedavg_server`: aggregator
//!   fixed to one node, no sampling pings for it, and the *fabric* grants
//!   that node unlimited capacity (a per-node override, not a protocol
//!   special case).

use std::sync::Arc;

use anyhow::Result;

use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::{JoinTrace, SessionMetrics};
use crate::net::{MsgKind, NetworkFabric, SizeModel, TrafficLedger};
use crate::sim::{
    ChurnEvent, ChurnKind, ChurnSchedule, Ctx, EvalPoint, HarnessConfig, NodeTable, Protocol,
    ReliabilityConfig, ReliableOutbox, ResumeOptions, SamplingVersion, SimHarness, SimRng,
    SimTime, SnapshotReader, SnapshotWriter, TimerVerdict,
};
use crate::{NodeId, Round};

use super::node::{ModelRef, ModestNode, Msg, NodeAction, Purpose, SampleOp, ViewRef};
use super::registry::MembershipEvent;
use super::sampler::candidate_order;
use super::view::View;

/// MoDeST parameters (paper Table 2) plus session plumbing. Bandwidth is no
/// longer here: per-node capacities belong to the [`NetworkFabric`].
#[derive(Debug, Clone)]
pub struct ModestConfig {
    /// Sample size `s` (trainers per round).
    pub s: usize,
    /// Aggregators per round `a` (choose z+1 for z expected failures).
    pub a: usize,
    /// Success fraction `sf` of models required to aggregate.
    pub sf: f64,
    /// Ping timeout `Δt`.
    pub dt: SimTime,
    /// Activity window `Δk` in rounds.
    pub dk: Round,
    /// Stop after this much virtual time.
    pub max_time: SimTime,
    /// Stop once this round has been dispatched (0 = unlimited).
    pub max_rounds: Round,
    /// Evaluate the latest global model this often.
    pub eval_interval: SimTime,
    /// Stop early when the metric crosses this target (accuracy >=, mse <=).
    pub target_metric: Option<f64>,
    /// RNG seed for everything in the session.
    pub seed: u64,
    /// Peer-sampling stream version for the uniform-draw sites (bootstrap
    /// advertisement sets, auto-rejoin, the FedAvg participant draw) —
    /// Alg. 1's ping-based candidate walk is deterministic and unaffected.
    pub sampling: SamplingVersion,
    /// FedAvg emulation (§4.3): fix this node as the only aggregator, skip
    /// sampling pings toward it; the session grants it unlimited fabric
    /// capacity.
    pub fedavg_server: Option<NodeId>,
    /// Canonical scenario JSON embedded into snapshots (None = session not
    /// built from a spec; checkpointing disabled).
    pub spec_json: Option<String>,
    /// Write a snapshot and stop once the clock reaches this instant.
    pub checkpoint_at: Option<SimTime>,
    /// Snapshot file path for `checkpoint_at`.
    pub checkpoint_out: Option<String>,
    /// Ack/timeout/retransmit contract for model-bearing messages; `Some`
    /// exactly when the session's network is lossy. Pings, pongs, and
    /// membership advertisements keep their native best-effort semantics
    /// (Alg. 1's candidate walk already retries on its own Δt clock).
    pub reliability: Option<ReliabilityConfig>,
    /// Live JSONL progress stream (None = off).
    pub progress: Option<crate::sim::ProgressConfig>,
    /// Event-queue execution threads (1 = classic single-threaded loop;
    /// T > 1 runs the sharded conservative-window scheduler, bit-identical).
    pub threads: usize,
}

impl Default for ModestConfig {
    fn default() -> Self {
        ModestConfig {
            s: 10,
            a: 3,
            sf: 0.9,
            dt: SimTime::from_secs_f64(2.0),
            dk: 20,
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            target_metric: None,
            seed: 42,
            sampling: SamplingVersion::default(),
            fedavg_server: None,
            spec_json: None,
            checkpoint_at: None,
            checkpoint_out: None,
            reliability: None,
            progress: None,
            threads: 1,
        }
    }
}

impl ModestConfig {
    /// The harness plumbing derived from this config.
    pub fn harness_config(&self) -> HarnessConfig {
        HarnessConfig {
            max_time: self.max_time,
            max_rounds: self.max_rounds,
            eval_interval: self.eval_interval,
            target_metric: self.target_metric,
            seed: self.seed,
            sampling: self.sampling,
            spec_json: self.spec_json.clone(),
            checkpoint_at: self.checkpoint_at,
            checkpoint_out: self.checkpoint_out.clone(),
            progress: self.progress.clone(),
            threads: self.threads,
        }
    }
}

/// Views serialize inline (no interning): a view is two sorted CRDT maps,
/// so equal views produce equal bytes and the write→read→write round trip
/// stays byte-identical even though shared `ViewRef`s are not re-shared on
/// restore (only memory is lost, never determinism).
fn write_view(w: &mut SnapshotWriter, v: &View) {
    w.write_usize(v.registry.len());
    for (node, counter, e) in v.registry.iter() {
        w.write_u32(node);
        w.write_u64(counter);
        w.write_bool(e == MembershipEvent::Joined);
    }
    w.write_usize(v.activity.len());
    for (node, round) in v.activity.iter() {
        w.write_u32(node);
        w.write_u64(round);
    }
}

fn read_view(r: &mut SnapshotReader) -> Result<View> {
    let mut v = View::default();
    let regs = r.read_usize()?;
    for _ in 0..regs {
        let node = r.read_u32()?;
        let counter = r.read_u64()?;
        let e = if r.read_bool()? { MembershipEvent::Joined } else { MembershipEvent::Left };
        v.registry.update(node, counter, e);
    }
    let acts = r.read_usize()?;
    for _ in 0..acts {
        let node = r.read_u32()?;
        let round = r.read_u64()?;
        v.activity.update(node, round);
    }
    Ok(v)
}

/// Timer ids with this bit set are aggregator deadlines: the low bits
/// carry the round. An aggregator stuck with a partial `Θ` (the missing
/// trainers' uploads expired) force-dispatches with what arrived instead
/// of stalling the round. Disjoint from both the sampling-op id space
/// (small sequence counters) and [`crate::sim::RELIABLE_TIMER_BIT`].
const MODEST_AGG_DEADLINE_BIT: u64 = 1 << 62;

/// The MoDeST protocol state machine (drives through [`SimHarness`]).
pub struct ModestProtocol {
    cfg: ModestConfig,
    nodes: Vec<ModestNode>,
    /// Hot flat per-node counters in SoA columns, parallel to `nodes`:
    /// `counters` = the persistent membership counter `c_i` (Alg. 2),
    /// `seqs` = the sampling-op id sequence, `timers` = the virtual time
    /// the node last received a train/aggregate message (drives the §3.5
    /// auto-rejoin when it stops being sampled).
    hot: NodeTable,
    sizes: SizeModel,
    /// Latest aggregated model dispatched by any aggregator (shared with
    /// the train messages that carried it — never deep-copied).
    latest_global: ModelRef,
    latest_round: Round,
    /// Size of the initial population (observers for join traces).
    initial_nodes: usize,
    join_watch: Vec<(NodeId, f64)>,
    /// Retransmit ledger for train/aggregate sends; `Some` exactly in
    /// lossy sessions.
    outbox: Option<ReliableOutbox<Msg>>,
}

impl ModestProtocol {
    fn local_seed(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    /// Compute the wire parts for `msg` and hand it to the fabric via `ctx`
    /// (self-sends are loopback: no traffic, no latency). Parts live on the
    /// stack — the fan-out hot path performs no per-send allocation.
    fn send(&mut self, ctx: &mut Ctx<'_, Msg>, from: NodeId, to: NodeId, msg: Msg) {
        if from == to {
            ctx.deliver_local(to, msg);
            return;
        }
        let (parts, used): ([(MsgKind, u64); 2], usize) = match &msg {
            Msg::Ping { .. } | Msg::Pong { .. } | Msg::Ack { .. } => {
                ([(MsgKind::Control, self.sizes.ping_bytes()), (MsgKind::Control, 0)], 1)
            }
            Msg::Joined { .. } | Msg::Left { .. } => (
                [(MsgKind::Membership, self.sizes.membership_bytes()), (MsgKind::Control, 0)],
                1,
            ),
            Msg::Train { view, .. } | Msg::Aggregate { view, .. } => {
                let model_b = ctx.task.model_bytes();
                let view_b = view.wire_bytes(&self.sizes);
                let total = self.sizes.model_transfer_bytes(model_b, 0) + view_b;
                (
                    [(MsgKind::ModelPayload, model_b), (MsgKind::ViewPayload, total - model_b)],
                    2,
                )
            }
        };
        // Lossy sessions track model-bearing messages through the reliable
        // outbox (the closure embeds the allocated seq so the receiver can
        // ack); everything else — and every lossless send — stays a plain
        // fire-and-forget.
        match (&mut self.outbox, msg) {
            (Some(ob), Msg::Train { round, model, view, .. }) => {
                ob.track(ctx, from, to, &parts[..used], |seq| Msg::Train {
                    seq,
                    from,
                    round,
                    model,
                    view,
                });
            }
            (Some(ob), Msg::Aggregate { round, model, view, .. }) => {
                ob.track(ctx, from, to, &parts[..used], |seq| Msg::Aggregate {
                    seq,
                    from,
                    round,
                    model,
                    view,
                });
            }
            (_, msg) => ctx.send(from, to, &parts[..used], msg),
        }
    }

    // ------------------------------------------------------------- sampling

    /// Start `Sample(round, need)` at `node` with the given continuation.
    fn start_sample(
        &mut self,
        ctx: &mut Ctx<'_, Msg>,
        node: NodeId,
        round: Round,
        need: usize,
        purpose: Purpose,
        payload: ModelRef,
    ) {
        // FedAvg emulation: the sample is fixed — aggregator = the server;
        // participants chosen uniformly by the server without pings.
        if let Some(server) = self.cfg.fedavg_server {
            let targets: Vec<NodeId> = match purpose {
                Purpose::Aggregators => vec![server],
                Purpose::Participants => {
                    let mut rng = SimRng::new(self.local_seed(node, round) ^ 0xfeda);
                    // The harness's Population owns both draw paths: all
                    // alive maps sampled indices straight to node ids, a
                    // churned table maps sampled alive-ranks through the
                    // Fenwick `select` — either way no O(n) candidate
                    // list per round, and the RNG stream is identical to
                    // sampling from the old materialized alive list.
                    ctx.population().sample_alive_excluding(
                        &mut rng,
                        ctx.sampling(),
                        server as usize,
                        need,
                    )
                }
            };
            self.dispatch_payload(ctx, node, round, purpose, payload, &targets);
            return;
        }

        let op_id = self.hot.bump_seq(node as usize);
        {
            let n = &mut self.nodes[node as usize];
            let candidates = n.view.candidates(round, self.cfg.dk);
            let order = candidate_order(round, &candidates);
            n.ops.push(SampleOp {
                id: op_id,
                round,
                need,
                purpose,
                payload,
                order,
                next_tail: 0,
                done: false,
                started: ctx.now(),
                retries: 0,
            });
        }
        self.pump_sample(ctx, node, op_id, true);
    }

    /// Advance a sampling op: initial parallel pings or the sequential tail.
    fn pump_sample(&mut self, ctx: &mut Ctx<'_, Msg>, node: NodeId, op_id: u64, initial: bool) {
        // Completion may already be possible from earlier pongs this round.
        if self.try_complete(ctx, node, op_id) {
            return;
        }
        let mut pings: Vec<NodeId> = Vec::new();
        let round;
        {
            let n = &mut self.nodes[node as usize];
            let Some(pos) = n.ops.iter().position(|o| o.id == op_id && !o.done) else {
                return;
            };
            round = n.ops[pos].round;
            let (need, next_tail, order_len) = {
                let op = &n.ops[pos];
                (op.need, op.next_tail, op.order.len())
            };
            if initial {
                // Alg. 1: ping the first `need` in parallel.
                let op = &mut n.ops[pos];
                let first = need.min(order_len);
                pings.extend_from_slice(&op.order[..first]);
                op.next_tail = first;
            } else if next_tail < order_len {
                // Sequential tail: one more candidate.
                let op = &mut n.ops[pos];
                pings.push(op.order[next_tail]);
                op.next_tail += 1;
            } else {
                // Exhausted: retry with a recomputed order (the view may
                // have changed; the network may have been asynchronous).
                let candidates = n.view.candidates(round, self.cfg.dk);
                let op = &mut n.ops[pos];
                op.retries += 1;
                op.order = candidate_order(round, &candidates);
                let first = need.min(op.order.len());
                pings.extend_from_slice(&op.order[..first]);
                op.next_tail = first;
            }
        }
        for j in pings {
            self.send(ctx, node, j, Msg::Ping { round, from: node });
        }
        ctx.schedule_timer(self.cfg.dt, node, op_id);
    }

    /// If the op has enough pongs, dispatch its continuation. Returns true
    /// if completed.
    fn try_complete(&mut self, ctx: &mut Ctx<'_, Msg>, node: NodeId, op_id: u64) -> bool {
        let (round, purpose, payload, targets, started, retries) = {
            let n = &mut self.nodes[node as usize];
            let Some(idx) = n.ops.iter().position(|o| o.id == op_id && !o.done) else {
                return true; // already done/garbage-collected
            };
            let enough = {
                let op = &n.ops[idx];
                n.pongs.get(&op.round).map_or(0, |l| l.len()) >= op.need
            };
            if !enough {
                return false;
            }
            let live = n.live_for(&n.ops[idx]);
            let op = &mut n.ops[idx];
            op.done = true;
            (op.round, op.purpose, op.payload.clone(), live, op.started, op.retries)
        };
        ctx.record_sample(started, round, retries);
        self.dispatch_payload(ctx, node, round, purpose, payload, &targets);
        self.nodes[node as usize].gc();
        true
    }

    /// Send the continuation messages of a completed sample.
    fn dispatch_payload(
        &mut self,
        ctx: &mut Ctx<'_, Msg>,
        node: NodeId,
        round: Round,
        purpose: Purpose,
        payload: ModelRef,
        targets: &[NodeId],
    ) {
        match purpose {
            Purpose::Aggregators => {
                // Trainer pushes its updated model to A^{round}: one view
                // snapshot, shared by every copy in flight.
                let view: ViewRef = Arc::new(self.nodes[node as usize].view.clone());
                for &j in targets {
                    self.send(
                        ctx,
                        node,
                        j,
                        Msg::Aggregate {
                            seq: 0,
                            from: node,
                            round,
                            model: payload.clone(),
                            view: view.clone(),
                        },
                    );
                }
            }
            Purpose::Participants => {
                // Aggregator averages Θ and pushes to S^{round}.
                let avg = {
                    let n = &self.nodes[node as usize];
                    let models: Vec<&Model> = n.theta.iter().map(|m| m.as_ref()).collect();
                    if models.is_empty() {
                        return;
                    }
                    Arc::new(ctx.task.aggregate(&models).expect("aggregate"))
                };
                self.nodes[node as usize].theta.clear();
                self.nodes[node as usize].theta_from.clear();
                // Track the freshest global model for evaluation (shared,
                // not copied: the Arc already owns the buffer).
                if round > self.latest_round {
                    self.latest_round = round;
                    self.latest_global = avg.clone();
                    ctx.record_round_start(round);
                }
                let view: ViewRef = Arc::new(self.nodes[node as usize].view.clone());
                for &j in targets {
                    self.send(
                        ctx,
                        node,
                        j,
                        Msg::Train {
                            seq: 0,
                            from: node,
                            round,
                            model: avg.clone(),
                            view: view.clone(),
                        },
                    );
                }
                let _ = payload; // participants' payload slot unused (avg built here)
            }
        }
    }

    /// The FedAvg emulation cannot outlive its fixed aggregator: there is
    /// no failure detection and no re-election (§4.3 strips the sampling
    /// machinery), so once the server is down every upload is dropped at
    /// dispatch and no round can ever complete. Finish the session instead
    /// of idling through probe ticks to `max_time` — availability-compiled
    /// churn makes a server crash a routine scenario, not a scripting
    /// error. (MoDeST proper has no such single point of failure.)
    fn finish_if_fedavg_server_died(&self, ctx: &mut Ctx<'_, Msg>, died: NodeId) {
        if self.cfg.fedavg_server == Some(died) {
            ctx.finish();
        }
    }

    /// §3.5 auto-rejoin: a reliable node that has not been activated for
    /// more than `Δk * Δt̄` (average round time) re-advertises itself, so a
    /// falsely-suspected node re-enters the candidate set.
    fn auto_rejoin(&mut self, ctx: &mut Ctx<'_, Msg>) {
        if self.cfg.fedavg_server.is_some() {
            return; // FL emulation has no membership protocol
        }
        let round_time = ctx.metrics.mean_round_time_s().unwrap_or(10.0).max(1.0);
        let horizon = SimTime::from_secs_f64(self.cfg.dk as f64 * round_time);
        let now = ctx.now();
        let mut rejoiners = Vec::new();
        for i in 0..self.nodes.len() {
            if !ctx.is_alive(i as NodeId) {
                continue;
            }
            let idle = now.saturating_sub(self.hot.timer(i));
            if idle > horizon {
                rejoiners.push(i as NodeId);
            }
        }
        for node in rejoiners {
            let c = self.hot.bump_counter(node as usize);
            self.nodes[node as usize]
                .view
                .registry
                .update(node, c, MembershipEvent::Joined);
            // Throttle: try again only after another full horizon.
            self.hot.set_timer(node as usize, now);
            // `Ctx::sample_peers` draws the alive peer set through the
            // Population (all-alive fast path or Fenwick rank/select; no
            // peer-list materialization on either path); RNG-stream
            // identical to the pre-helper code under v1.
            for p in ctx.sample_peers(node, self.cfg.s) {
                self.send(ctx, node, p, Msg::Joined { node, counter: c });
            }
        }
    }
}

impl Protocol for ModestProtocol {
    type Msg = Msg;

    /// Bootstrap round 1 (Alg. 4 lines 6-8): every node in S^1 starts
    /// training the initial model.
    fn bootstrap(&mut self, ctx: &mut Ctx<'_, Msg>) {
        let init = Arc::new(ctx.task.init_model());
        // All initial nodes share the same view, so S^1 is consistent.
        let candidates: Vec<NodeId> = (0..self.initial_nodes as NodeId).collect();
        let order = candidate_order(1, &candidates);
        let view: ViewRef = Arc::new(self.nodes[0].view.clone());
        for &i in order.iter().take(self.cfg.s.min(order.len())) {
            ctx.deliver_local(
                i,
                Msg::Train { seq: 0, from: i, round: 1, model: init.clone(), view: view.clone() },
            );
        }
        ctx.record_round_start(1);
    }

    fn on_deliver(&mut self, ctx: &mut Ctx<'_, Msg>, to: NodeId, msg: Msg) {
        match msg {
            Msg::Ping { round, from } => {
                let act = self.nodes[to as usize].on_ping(round, from);
                if let NodeAction::SendPong { to: peer, round } = act {
                    self.send(ctx, to, peer, Msg::Pong { round, from: to });
                }
            }
            Msg::Pong { round, from } => {
                let completable = self.nodes[to as usize].on_pong(round, from);
                for op in completable {
                    self.try_complete(ctx, to, op);
                }
            }
            Msg::Joined { node, counter } => {
                self.nodes[to as usize].on_membership(node, counter, true);
            }
            Msg::Left { node, counter } => {
                self.nodes[to as usize].on_membership(node, counter, false);
            }
            Msg::Aggregate { seq, from, round, model, view } => {
                self.hot.set_timer(to as usize, ctx.now());
                // Ack before processing: duplicates (the first ack was
                // dropped) are deduplicated inside `on_aggregate` but must
                // still be re-acked to stop the sender's retransmits.
                if seq != 0 {
                    self.send(ctx, to, from, Msg::Ack { seq });
                }
                let first_of_round =
                    self.outbox.is_some() && round > self.nodes[to as usize].k_agg;
                let act = self.nodes[to as usize].on_aggregate(
                    round,
                    from,
                    model,
                    &view,
                    self.cfg.s,
                    self.cfg.sf,
                );
                if let NodeAction::BeginParticipantSample { round } = act {
                    // Virtual cost of the averaging itself.
                    let k = self.nodes[to as usize].theta.len();
                    let _cost = ctx.compute.aggregate_time(to, k, ctx.task.model_bytes());
                    // Aggregator samples the round's participants (Alg. 4 l.19).
                    let dummy = Arc::new(Vec::new());
                    self.start_sample(ctx, to, round, self.cfg.s, Purpose::Participants, dummy);
                } else if first_of_round {
                    // Lossy degradation: the round's first upload arms a
                    // deadline sized past the full retransmit window. If
                    // the remaining trainers' uploads all expire, the
                    // aggregator force-dispatches with what arrived
                    // instead of stalling the round forever.
                    let ob = self.outbox.as_ref().expect("first_of_round implies outbox");
                    let delay = ob.cfg().expiry_window() + ob.cfg().max_timeout;
                    ctx.schedule_timer(delay, to, MODEST_AGG_DEADLINE_BIT | round);
                }
            }
            Msg::Train { seq, from, round, model, view } => {
                self.hot.set_timer(to as usize, ctx.now());
                if seq != 0 {
                    self.send(ctx, to, from, Msg::Ack { seq });
                }
                let act = self.nodes[to as usize].on_train(round, model, &view);
                if let NodeAction::BeginTraining { round, seq } = act {
                    if ctx.round_budget_exceeded(round) {
                        ctx.finish();
                        return;
                    }
                    let batches = ctx.task.batches_per_epoch(to);
                    let dur = ctx.compute.train_time(to, batches);
                    ctx.schedule_train_done(dur, to, seq);
                }
            }
            Msg::Ack { seq } => {
                if let Some(ob) = &mut self.outbox {
                    ob.ack(seq);
                }
            }
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<'_, Msg>, node: NodeId, id: u64) {
        if let Some(ob) = &mut self.outbox {
            match ob.on_timer(ctx, id) {
                // Sender-side expiry needs no action: a lost upload is
                // absorbed by the aggregator deadline, a lost train by the
                // next round's fresh participant sample.
                TimerVerdict::Handled | TimerVerdict::Expired(_) => return,
                TimerVerdict::NotOurs => {}
            }
        }
        if id & MODEST_AGG_DEADLINE_BIT != 0 {
            let round = id & !MODEST_AGG_DEADLINE_BIT;
            let i = node as usize;
            let stuck = {
                let n = &self.nodes[i];
                n.k_agg == round && n.agg_dispatched < round && !n.theta.is_empty()
            };
            if stuck {
                self.nodes[i].agg_dispatched = round;
                let dummy = Arc::new(Vec::new());
                self.start_sample(ctx, node, round, self.cfg.s, Purpose::Participants, dummy);
            }
            return;
        }
        self.pump_sample(ctx, node, id, false);
    }

    fn on_train_done(&mut self, ctx: &mut Ctx<'_, Msg>, node: NodeId, seq: u64) {
        let Some((round, input)) = self.nodes[node as usize].training_valid(seq) else {
            return; // canceled by a newer round
        };
        let seed = self.local_seed(node, round);
        let (updated, _loss, _batches) =
            ctx.task.local_update(&input, node, seed).expect("local_update");
        self.nodes[node as usize].training = None;
        self.nodes[node as usize].k_done = round;
        // Push to the aggregators of round+1 (Alg. 4 lines 33-37).
        self.start_sample(ctx, node, round + 1, self.cfg.a, Purpose::Aggregators, Arc::new(updated));
    }

    fn on_churn(&mut self, ctx: &mut Ctx<'_, Msg>, ev: ChurnEvent) {
        match ev.kind {
            ChurnKind::Join | ChurnKind::Recover => {
                let c = self.hot.bump_counter(ev.node as usize);
                {
                    let node = &mut self.nodes[ev.node as usize];
                    node.view.registry.update(ev.node, c, MembershipEvent::Joined);
                    node.view.activity.update(ev.node, 0);
                }
                // Advertise to s random alive peers (bootstrap set P).
                for p in ctx.sample_peers(ev.node, self.cfg.s) {
                    self.send(ctx, ev.node, p, Msg::Joined { node: ev.node, counter: c });
                }
                // Fig. 5 join-propagation watches track nodes ENTERING the
                // system (ids beyond the initial population), once each.
                // An availability Recover of an initial node is routine
                // churn, not a join experiment — and duplicate watches
                // would both corrupt the traces (only the first per
                // joiner ever accumulates samples) and grow the per-probe
                // scan without bound under periodic availability churn.
                if ev.node as usize >= self.initial_nodes
                    && !ctx.metrics.joins.iter().any(|t| t.joiner == ev.node)
                {
                    let now_s = ctx.now().as_secs_f64();
                    self.join_watch.push((ev.node, now_s));
                    ctx.metrics.joins.push(JoinTrace {
                        joiner: ev.node,
                        joined_at_s: now_s,
                        missing: Vec::new(),
                    });
                }
            }
            ChurnKind::Leave => {
                let c = self.hot.bump_counter(ev.node as usize);
                self.nodes[ev.node as usize]
                    .view
                    .registry
                    .update(ev.node, c, MembershipEvent::Left);
                for p in ctx.sample_peers(ev.node, self.cfg.s) {
                    self.send(ctx, ev.node, p, Msg::Left { node: ev.node, counter: c });
                }
                self.finish_if_fedavg_server_died(ctx, ev.node);
            }
            ChurnKind::Crash => {
                self.finish_if_fedavg_server_died(ctx, ev.node);
            }
        }
    }

    fn on_probe(&mut self, ctx: &mut Ctx<'_, Msg>) {
        self.auto_rejoin(ctx);
        // Join-propagation traces (Fig. 5): count initial-population nodes
        // that still don't know each watched joiner. A fully-propagated
        // watch is retired — `full_propagation_s` reads the FIRST zero
        // sample, so the trace is complete and further O(n) scans for it
        // would be pure waste.
        let now_s = ctx.now().as_secs_f64();
        let mut w = 0;
        while w < self.join_watch.len() {
            let (joiner, _) = self.join_watch[w];
            let missing = (0..self.initial_nodes)
                .filter(|&i| {
                    ctx.is_alive(i as NodeId) && !self.nodes[i].view.registry.knows(joiner)
                })
                .count();
            if let Some(trace) = ctx.metrics.joins.iter_mut().find(|t| t.joiner == joiner) {
                trace.missing.push((now_s, missing));
            }
            if missing == 0 {
                self.join_watch.swap_remove(w);
            } else {
                w += 1;
            }
        }
    }

    fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint> {
        let e = task.evaluate(self.latest_global.as_ref())?;
        Ok(EvalPoint {
            round: self.latest_round,
            metric: e.metric,
            loss: e.loss,
            metric_std: 0.0,
        })
    }

    fn final_round(&self) -> Round {
        self.latest_round
    }

    // Dynamic state only: `cfg`, `sizes` and `initial_nodes` are rebuilt
    // from the embedded spec. Model payloads (`theta`, in-flight training,
    // op payloads, `latest_global`) go through the writer's Arc interning,
    // so the extensive model sharing of the MoDeST fan-out survives a
    // write→read→write round trip byte-identically.
    fn snapshot(&self, w: &mut SnapshotWriter) -> Result<()> {
        w.write_usize(self.nodes.len());
        for n in &self.nodes {
            w.write_u32(n.id);
            write_view(w, &n.view);
            w.write_u64(n.k_agg);
            w.write_usize(n.theta.len());
            for m in &n.theta {
                w.write_model(m);
            }
            for &f in &n.theta_from {
                w.write_u32(f);
            }
            w.write_u64(n.agg_dispatched);
            w.write_u64(n.k_train);
            match &n.training {
                Some((round, seq, model)) => {
                    w.write_bool(true);
                    w.write_u64(*round);
                    w.write_u64(*seq);
                    w.write_model(model);
                }
                None => w.write_bool(false),
            }
            w.write_u64(n.train_seq);
            w.write_u64(n.k_done);
            let mut rounds: Vec<Round> = n.pongs.keys().copied().collect();
            rounds.sort_unstable();
            w.write_usize(rounds.len());
            for k in rounds {
                w.write_u64(k);
                let list = &n.pongs[&k];
                w.write_usize(list.len());
                for &j in list {
                    w.write_u32(j);
                }
            }
            w.write_usize(n.ops.len());
            for op in &n.ops {
                w.write_u64(op.id);
                w.write_u64(op.round);
                w.write_usize(op.need);
                w.write_u8(match op.purpose {
                    Purpose::Aggregators => 0,
                    Purpose::Participants => 1,
                });
                w.write_model(&op.payload);
                w.write_usize(op.order.len());
                for &j in &op.order {
                    w.write_u32(j);
                }
                w.write_usize(op.next_tail);
                w.write_bool(op.done);
                w.write_time(op.started);
                w.write_u32(op.retries);
            }
        }
        self.hot.write_into(w);
        w.write_model(&self.latest_global);
        w.write_u64(self.latest_round);
        w.write_usize(self.join_watch.len());
        for &(node, at_s) in &self.join_watch {
            w.write_u32(node);
            w.write_f64(at_s);
        }
        w.write_bool(self.outbox.is_some());
        if let Some(ob) = &self.outbox {
            ob.write_into(w, |w, m| self.write_msg(w, m))?;
        }
        Ok(())
    }

    fn restore(&mut self, r: &mut SnapshotReader) -> Result<()> {
        let n_nodes = r.read_usize()?;
        let mut nodes = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut node = ModestNode::new(r.read_u32()?);
            node.view = read_view(r)?;
            node.k_agg = r.read_u64()?;
            let t = r.read_usize()?;
            node.theta.reserve(t);
            for _ in 0..t {
                node.theta.push(r.read_model()?);
            }
            node.theta_from.reserve(t);
            for _ in 0..t {
                node.theta_from.push(r.read_u32()?);
            }
            node.agg_dispatched = r.read_u64()?;
            node.k_train = r.read_u64()?;
            node.training = if r.read_bool()? {
                Some((r.read_u64()?, r.read_u64()?, r.read_model()?))
            } else {
                None
            };
            node.train_seq = r.read_u64()?;
            node.k_done = r.read_u64()?;
            let n_rounds = r.read_usize()?;
            for _ in 0..n_rounds {
                let k = r.read_u64()?;
                let len = r.read_usize()?;
                let mut list = Vec::with_capacity(len);
                for _ in 0..len {
                    list.push(r.read_u32()?);
                }
                node.pongs.insert(k, list);
            }
            let n_ops = r.read_usize()?;
            for _ in 0..n_ops {
                let id = r.read_u64()?;
                let round = r.read_u64()?;
                let need = r.read_usize()?;
                let purpose = match r.read_u8()? {
                    0 => Purpose::Aggregators,
                    1 => Purpose::Participants,
                    t => anyhow::bail!("unknown sample-op purpose tag {t}"),
                };
                let payload = r.read_model()?;
                let olen = r.read_usize()?;
                let mut order = Vec::with_capacity(olen);
                for _ in 0..olen {
                    order.push(r.read_u32()?);
                }
                node.ops.push(SampleOp {
                    id,
                    round,
                    need,
                    purpose,
                    payload,
                    order,
                    next_tail: r.read_usize()?,
                    done: r.read_bool()?,
                    started: r.read_time()?,
                    retries: r.read_u32()?,
                });
            }
            nodes.push(node);
        }
        self.nodes = nodes;
        self.hot = NodeTable::read_from(r)?;
        self.latest_global = r.read_model()?;
        self.latest_round = r.read_u64()?;
        let watches = r.read_usize()?;
        let mut join_watch = Vec::with_capacity(watches);
        for _ in 0..watches {
            join_watch.push((r.read_u32()?, r.read_f64()?));
        }
        self.join_watch = join_watch;
        // Tolerate a loss-config overlay flip across the checkpoint: a
        // snapshot taken lossy restores into a lossless session by reading
        // and discarding the ledger; the reverse keeps the fresh outbox.
        if r.read_bool()? {
            let cfg = self.cfg.reliability.unwrap_or(ReliabilityConfig {
                timeout: SimTime::from_secs_f64(1.0),
                backoff: 1.0,
                max_timeout: SimTime::from_secs_f64(1.0),
                retries: 1,
            });
            let ob = ReliableOutbox::read_from(r, cfg, |r| self.read_msg(r))?;
            if self.cfg.reliability.is_some() {
                self.outbox = Some(ob);
            }
        }
        Ok(())
    }

    fn write_msg(&self, w: &mut SnapshotWriter, msg: &Msg) -> Result<()> {
        match msg {
            Msg::Ping { round, from } => {
                w.write_u8(0);
                w.write_u64(*round);
                w.write_u32(*from);
            }
            Msg::Pong { round, from } => {
                w.write_u8(1);
                w.write_u64(*round);
                w.write_u32(*from);
            }
            Msg::Joined { node, counter } => {
                w.write_u8(2);
                w.write_u32(*node);
                w.write_u64(*counter);
            }
            Msg::Left { node, counter } => {
                w.write_u8(3);
                w.write_u32(*node);
                w.write_u64(*counter);
            }
            Msg::Aggregate { seq, from, round, model, view } => {
                w.write_u8(4);
                w.write_u64(*seq);
                w.write_u32(*from);
                w.write_u64(*round);
                w.write_model(model);
                write_view(w, view);
            }
            Msg::Train { seq, from, round, model, view } => {
                w.write_u8(5);
                w.write_u64(*seq);
                w.write_u32(*from);
                w.write_u64(*round);
                w.write_model(model);
                write_view(w, view);
            }
            Msg::Ack { seq } => {
                w.write_u8(6);
                w.write_u64(*seq);
            }
        }
        Ok(())
    }

    fn read_msg(&self, r: &mut SnapshotReader) -> Result<Msg> {
        Ok(match r.read_u8()? {
            0 => Msg::Ping { round: r.read_u64()?, from: r.read_u32()? },
            1 => Msg::Pong { round: r.read_u64()?, from: r.read_u32()? },
            2 => Msg::Joined { node: r.read_u32()?, counter: r.read_u64()? },
            3 => Msg::Left { node: r.read_u32()?, counter: r.read_u64()? },
            4 => Msg::Aggregate {
                seq: r.read_u64()?,
                from: r.read_u32()?,
                round: r.read_u64()?,
                model: r.read_model()?,
                view: Arc::new(read_view(r)?),
            },
            5 => Msg::Train {
                seq: r.read_u64()?,
                from: r.read_u32()?,
                round: r.read_u64()?,
                model: r.read_model()?,
                view: Arc::new(read_view(r)?),
            },
            6 => Msg::Ack { seq: r.read_u64()? },
            t => anyhow::bail!("unknown modest message tag {t}"),
        })
    }
}

/// Assembly facade: builds a [`ModestProtocol`] and its [`SimHarness`].
pub struct ModestSession {
    harness: SimHarness<ModestProtocol>,
}

impl ModestSession {
    /// Build a session over `n_initial` pre-registered nodes (everyone knows
    /// everyone, activity 0) plus whatever the churn script adds later, on
    /// the given fabric.
    pub fn new(
        cfg: ModestConfig,
        n_initial: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        mut fabric: NetworkFabric,
        churn: ChurnSchedule,
    ) -> ModestSession {
        let mut rng = SimRng::new(cfg.seed ^ 0x6d6f6465_73740001);
        let max_node = churn.node_extent().max(n_initial);
        let mut nodes: Vec<ModestNode> = (0..max_node as NodeId).map(ModestNode::new).collect();
        let mut hot = NodeTable::new(max_node).with_seqs().with_counters().with_timers();

        // Initial population: registered with counter 1, activity 0.
        for i in 0..n_initial {
            hot.set_counter(i, 1);
        }
        for i in 0..n_initial {
            for j in 0..n_initial {
                nodes[i]
                    .view
                    .registry
                    .update(j as NodeId, 1, MembershipEvent::Joined);
                nodes[i].view.activity.update(j as NodeId, 0);
            }
        }

        let latest_global = Arc::new(task.init_model());
        let mut compute = compute;
        compute.ensure_nodes(max_node, &mut rng);
        fabric.ensure_nodes(max_node);
        if let Some(server) = cfg.fedavg_server {
            // Paper §4.3: unlimited bandwidth capacity for the aggregator.
            fabric.set_unlimited(server);
        }

        let hcfg = cfg.harness_config();
        let outbox = cfg.reliability.map(ReliableOutbox::new);
        let protocol = ModestProtocol {
            cfg,
            nodes,
            hot,
            sizes: SizeModel::default(),
            latest_global,
            latest_round: 0,
            initial_nodes: n_initial,
            join_watch: Vec::new(),
            outbox,
        };
        ModestSession {
            harness: SimHarness::new(
                hcfg, protocol, max_node, n_initial, task, compute, fabric, churn,
            ),
        }
    }

    /// The freshest aggregated model and its round.
    pub fn latest_global(&self) -> (&Model, Round) {
        let p = self.harness.protocol();
        (p.latest_global.as_ref(), p.latest_round)
    }

    /// Run to completion; returns the collected metrics.
    pub fn run(self) -> (SessionMetrics, TrafficLedger) {
        self.harness.run()
    }

    /// Serialize the complete session state (see [`crate::sim::snapshot`]).
    pub fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        self.harness.snapshot_bytes()
    }

    /// Restore state from a snapshot produced by [`Self::snapshot_bytes`]
    /// onto a freshly spec-built session.
    pub fn resume(&mut self, r: &mut SnapshotReader, opts: &ResumeOptions) -> Result<()> {
        self.harness.restore_from(r, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::{BandwidthConfig, LatencyMatrix, LatencyParams};

    fn quick_fabric(n: usize, seed: u64) -> NetworkFabric {
        let mut rng = SimRng::new(seed);
        let latency = LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
        NetworkFabric::new(latency, &BandwidthConfig::uniform_mbps(50.0), n, &mut rng.fork("bw"))
    }

    fn quick_session(n: usize, cfg: ModestConfig) -> ModestSession {
        let task = MockTask::new(n, 16, 0.5, cfg.seed);
        let compute = ComputeModel::uniform(n, 0.05);
        let fabric = quick_fabric(n, cfg.seed);
        ModestSession::new(cfg, n, Box::new(task), compute, fabric, ChurnSchedule::empty())
    }

    #[test]
    fn session_makes_rounds_and_converges() {
        let cfg = ModestConfig {
            s: 4,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 60,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = quick_session(16, cfg).run();
        assert!(m.final_round >= 20, "only reached round {}", m.final_round);
        let best = m.best_metric(true).unwrap();
        assert!(best > 0.8, "metric {best}");
        assert!(traffic.is_conserved());
        assert!(traffic.total() > 0);
    }

    #[test]
    fn rounds_advance_monotonically() {
        let cfg = ModestConfig {
            s: 3,
            a: 1,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 30,
            ..Default::default()
        };
        let (m, _) = quick_session(10, cfg).run();
        let rounds: Vec<Round> = m.round_starts.iter().map(|(r, _)| r).collect();
        let mut sorted = rounds.clone();
        sorted.sort_unstable();
        assert_eq!(rounds, sorted);
        assert!(rounds.len() >= 10);
    }

    #[test]
    fn sample_durations_bounded_when_all_alive() {
        let cfg = ModestConfig {
            s: 4,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (m, _) = quick_session(12, cfg).run();
        assert!(!m.samples.is_empty());
        // With everyone alive, sampling = one parallel ping wave: its
        // duration is bounded by one RTT, far below the 2s timeout.
        for s in &m.samples {
            assert!(s.duration_s < 2.0, "sample took {}s", s.duration_s);
            assert_eq!(s.retries, 0);
        }
    }

    #[test]
    fn fedavg_mode_concentrates_traffic_on_server() {
        let cfg = ModestConfig {
            s: 4,
            a: 1,
            sf: 1.0,
            fedavg_server: Some(0),
            max_time: SimTime::from_secs_f64(300.0),
            max_rounds: 25,
            ..Default::default()
        };
        let (m, traffic) = quick_session(12, cfg).run();
        assert!(m.final_round >= 10);
        let server = traffic.node_usage(0);
        let max_other = (1..12).map(|i| traffic.node_usage(i)).max().unwrap();
        assert!(server > 2 * max_other, "server {server} vs {max_other}");
    }

    #[test]
    fn lossy_network_degrades_gracefully() {
        use crate::net::LossModel;
        use crate::sim::ReliabilityConfig;
        // 20% uniform loss on every link. Train/aggregate ride the
        // reliable outbox; a stuck aggregator force-dispatches at its
        // deadline. Rounds must keep advancing and the replay must be
        // bit-identical.
        let mk = || {
            let cfg = ModestConfig {
                s: 4,
                a: 2,
                sf: 1.0,
                max_time: SimTime::from_secs_f64(900.0),
                max_rounds: 30,
                eval_interval: SimTime::from_secs_f64(30.0),
                reliability: Some(ReliabilityConfig {
                    timeout: SimTime::from_secs_f64(3.0),
                    backoff: 2.0,
                    max_timeout: SimTime::from_secs_f64(10.0),
                    retries: 4,
                }),
                ..Default::default()
            };
            let n = 12;
            let task = MockTask::new(n, 16, 0.5, cfg.seed);
            let compute = ComputeModel::uniform(n, 0.05);
            let mut fabric = quick_fabric(n, cfg.seed);
            let mut rng = SimRng::new(cfg.seed);
            fabric.set_loss(LossModel::Uniform { p: 0.2 }, rng.fork("loss"));
            ModestSession::new(cfg, n, Box::new(task), compute, fabric, ChurnSchedule::empty())
                .run()
        };
        let (m, traffic) = mk();
        assert!(m.final_round >= 10, "lossy session stalled at round {}", m.final_round);
        assert!(traffic.dropped_bytes() > 0, "20% loss dropped nothing");
        assert!(traffic.retransmitted_bytes() > 0, "no retransmissions under loss");
        assert!(traffic.is_conserved());
        let (b, tb) = mk();
        assert_eq!(m.events, b.events);
        assert_eq!(m.final_round, b.final_round);
        assert_eq!(traffic.total(), tb.total());
    }

    #[test]
    fn crash_resilient_progress() {
        // Crash 4 of 12 nodes mid-run; rounds must continue.
        let churn = ChurnSchedule::mass_crash(
            12,
            8,
            2,
            SimTime::from_secs_f64(30.0),
            SimTime::from_secs_f64(10.0),
        );
        let cfg = ModestConfig {
            s: 4,
            a: 3,
            sf: 0.5,
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 0,
            ..Default::default()
        };
        let task = MockTask::new(12, 16, 0.5, 7);
        let compute = ComputeModel::uniform(12, 0.05);
        let fabric = quick_fabric(12, 7);
        let session = ModestSession::new(cfg, 12, Box::new(task), compute, fabric, churn);
        let (m, _) = session.run();
        // Progress after the crash window (crashes end at t=60).
        let late_rounds = m.round_starts.iter().filter(|&(_, t)| t > 120.0).count();
        assert!(late_rounds > 5, "no progress after crashes: {late_rounds}");
    }

    #[test]
    fn join_via_churn_eventually_known() {
        let churn = ChurnSchedule::staggered_joins(
            8,
            2,
            SimTime::from_secs_f64(20.0),
            SimTime::from_secs_f64(20.0),
        );
        let cfg = ModestConfig {
            s: 3,
            a: 2,
            sf: 1.0,
            max_time: SimTime::from_secs_f64(400.0),
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let task = MockTask::new(10, 16, 0.5, 9);
        let compute = ComputeModel::uniform(10, 0.05);
        let fabric = quick_fabric(10, 9);
        let session = ModestSession::new(cfg, 8, Box::new(task), compute, fabric, churn);
        let (m, _) = session.run();
        assert_eq!(m.joins.len(), 2);
        for t in &m.joins {
            assert!(
                t.full_propagation_s().is_some(),
                "join of {} never fully propagated",
                t.joiner
            );
        }
    }
}
