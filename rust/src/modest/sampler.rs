//! Alg. 1 — the deterministic sampling order.
//!
//! Every node hashes `(candidate_id ++ round)` and sorts; because the hash
//! is keyed by the round, the contact order is re-randomized every round,
//! and because it is a pure function of (id, round), any two nodes with the
//! same candidate set derive the *same* order — the heart of
//! mostly-consistent sampling. The first `a` entries of the order are the
//! round's aggregators (paper §3.6).
//!
//! The ping/pong liveness loop around this order is event-driven and lives
//! in [`super::session`].

use crate::{NodeId, Round};

/// Stable 64-bit hash of `(node, round)` — splitmix64 over the packed pair.
///
/// The paper concatenates the id and round strings and sorts
/// lexicographically; any keyed hash with per-round reshuffling satisfies
/// the algorithm's requirements, and a 64-bit integer hash gives the same
/// mostly-consistent property without string churn.
pub fn sample_hash(node: NodeId, round: Round) -> u64 {
    let mut z = (node as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ round.rotate_left(32);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The hash-sorted candidate contact order for round `k` (Alg. 1 line 6).
/// Ties (astronomically unlikely) break by node id for determinism.
pub fn candidate_order(round: Round, candidates: &[NodeId]) -> Vec<NodeId> {
    let mut keyed: Vec<(u64, NodeId)> = candidates
        .iter()
        .map(|&j| (sample_hash(j, round), j))
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, j)| j).collect()
}

/// The aggregators of round `k` given a candidate set: first `a` of the
/// order (paper §3.6). Used by tests and by the bootstrap (round 1).
pub fn expected_aggregators(round: Round, candidates: &[NodeId], a: usize) -> Vec<NodeId> {
    let mut order = candidate_order(round, candidates);
    order.truncate(a);
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_is_deterministic() {
        let c: Vec<NodeId> = (0..100).collect();
        assert_eq!(candidate_order(5, &c), candidate_order(5, &c));
    }

    #[test]
    fn order_is_a_permutation() {
        let c: Vec<NodeId> = (0..50).collect();
        let mut o = candidate_order(3, &c);
        o.sort_unstable();
        assert_eq!(o, c);
    }

    #[test]
    fn order_changes_every_round() {
        let c: Vec<NodeId> = (0..64).collect();
        let o1 = candidate_order(1, &c);
        let o2 = candidate_order(2, &c);
        assert_ne!(o1, o2);
    }

    #[test]
    fn order_independent_of_input_permutation() {
        // Different nodes may hold their candidate lists in different
        // orders; the derived contact order must not care.
        let mut c: Vec<NodeId> = (0..40).collect();
        let o1 = candidate_order(9, &c);
        c.reverse();
        let o2 = candidate_order(9, &c);
        assert_eq!(o1, o2);
    }

    #[test]
    fn mostly_consistent_under_small_view_divergence() {
        // Two views differing in one node must agree on all other relative
        // positions: the samples overlap in >= s-1 members.
        let full: Vec<NodeId> = (0..100).collect();
        let missing: Vec<NodeId> = (0..100).filter(|&j| j != 42).collect();
        for round in 1..20u64 {
            let s = 10;
            let a: Vec<NodeId> = candidate_order(round, &full).into_iter().take(s).collect();
            let b: Vec<NodeId> = candidate_order(round, &missing).into_iter().take(s).collect();
            let overlap = a.iter().filter(|x| b.contains(x)).count();
            assert!(overlap >= s - 1, "round {round}: overlap {overlap}");
        }
    }

    #[test]
    fn selection_is_roughly_uniform() {
        // Over many rounds, each node should lead the order ~ uniformly.
        let c: Vec<NodeId> = (0..20).collect();
        let mut counts = [0usize; 20];
        for round in 0..4000u64 {
            counts[candidate_order(round, &c)[0] as usize] += 1;
        }
        let expect = 4000 / 20;
        for (j, &n) in counts.iter().enumerate() {
            assert!(
                n > expect / 2 && n < expect * 2,
                "node {j} selected {n} times (expect ~{expect})"
            );
        }
    }

    #[test]
    fn aggregators_prefix_of_order() {
        let c: Vec<NodeId> = (0..30).collect();
        let order = candidate_order(7, &c);
        assert_eq!(expected_aggregators(7, &c, 3), order[..3].to_vec());
    }
}
