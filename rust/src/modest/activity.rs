//! Alg. 3 — latest-activity records (`N_i`).
//!
//! A per-node map `j -> k̂_j` of the highest round each node was known
//! active in, merged by max — a vector-clock-like monotone join. Estimates
//! can lag the true round but never exceed it (the paper's logical-clock
//! argument), which the proptest suite checks against a simulated oracle.

use std::collections::BTreeMap;

use crate::{NodeId, Round};

/// `N_i` of Alg. 3.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ActivityClock {
    records: BTreeMap<NodeId, Round>,
}

impl ActivityClock {
    pub fn new() -> ActivityClock {
        ActivityClock::default()
    }

    /// `UpdateActivity(j, k̂)`: max-merge one record.
    pub fn update(&mut self, node: NodeId, round: Round) {
        let e = self.records.entry(node).or_insert(0);
        *e = (*e).max(round);
    }

    /// `MAX(N_i.VALUES)` — the node's estimate of the current round.
    pub fn estimate(&self) -> Round {
        self.records.values().copied().max().unwrap_or(0)
    }

    pub fn get(&self, node: NodeId) -> Option<Round> {
        self.records.get(&node).copied()
    }

    /// Merge: pointwise max.
    pub fn merge(&mut self, other: &ActivityClock) {
        for (&n, &k) in &other.records {
            self.update(n, k);
        }
    }

    /// Was `node` active within the last `dk` rounds as of round `k`?
    /// (Alg. 3 Candidates: `N_i.get(j) > k - Δk`.)
    pub fn active_within(&self, node: NodeId, k: Round, dk: Round) -> bool {
        match self.records.get(&node) {
            Some(&r) => r + dk > k,
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (NodeId, Round)> + '_ {
        self.records.iter().map(|(&n, &k)| (n, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn update_is_monotone() {
        let mut a = ActivityClock::new();
        a.update(1, 5);
        a.update(1, 3); // stale, ignored
        assert_eq!(a.get(1), Some(5));
        a.update(1, 9);
        assert_eq!(a.get(1), Some(9));
    }

    #[test]
    fn estimate_is_max() {
        let mut a = ActivityClock::new();
        assert_eq!(a.estimate(), 0);
        a.update(1, 3);
        a.update(2, 7);
        a.update(3, 1);
        assert_eq!(a.estimate(), 7);
    }

    #[test]
    fn merge_is_pointwise_max() {
        let mut a = ActivityClock::new();
        a.update(1, 5);
        a.update(2, 2);
        let mut b = ActivityClock::new();
        b.update(1, 3);
        b.update(2, 8);
        b.update(3, 1);
        a.merge(&b);
        assert_eq!(a.get(1), Some(5));
        assert_eq!(a.get(2), Some(8));
        assert_eq!(a.get(3), Some(1));
    }

    #[test]
    fn window_semantics_match_alg3() {
        // Alg. 3 line 19: candidate iff N_i.GET(j) > (k - Δk).
        let mut a = ActivityClock::new();
        a.update(1, 10);
        assert!(a.active_within(1, 20, 20)); // 10 > 0
        assert!(a.active_within(1, 29, 20)); // 10 > 9
        assert!(!a.active_within(1, 30, 20)); // 10 > 10 is false
        assert!(!a.active_within(2, 5, 20)); // unknown node
    }

    #[test]
    fn fresh_joiner_with_round_zero_is_candidate_early() {
        // A node with activity 0 (its own join record) must count as active
        // while k < Δk — otherwise bootstrap would starve.
        let mut a = ActivityClock::new();
        a.update(4, 0);
        assert!(a.active_within(4, 1, 20));
        assert!(!a.active_within(4, 20, 20));
    }
}
