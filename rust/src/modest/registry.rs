//! Alg. 2 — the joined/left registry.
//!
//! Each node orders its own membership events with a persistent counter
//! `c_i`; everyone else keeps only the *most recent* event per node
//! (last-writer-wins by counter). Merging registries is therefore
//! commutative, associative and idempotent — a state-based CRDT — which is
//! what lets MoDeST skip consensus entirely. The proptest suite
//! (`rust/tests/prop_invariants.rs`) checks the CRDT laws.

use std::collections::BTreeMap;

use crate::NodeId;

/// The two membership event kinds of Alg. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipEvent {
    Joined,
    Left,
}

/// Registry: `node -> (counter, latest event)`; `E_i` and `C_i` of Alg. 2
/// fused into one map.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Registry {
    entries: BTreeMap<NodeId, (u64, MembershipEvent)>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// `UpdateRegistry(j, c_j, e)`: keep only strictly newer events.
    ///
    /// Equal counters keep the existing entry — counters are incremented
    /// only by the node itself, so an equal counter implies the same event.
    pub fn update(&mut self, node: NodeId, counter: u64, event: MembershipEvent) -> bool {
        match self.entries.get(&node) {
            Some(&(c, _)) if c >= counter => false,
            _ => {
                self.entries.insert(node, (counter, event));
                true
            }
        }
    }

    /// `MergeRegistry(C_j, E_j)`.
    pub fn merge(&mut self, other: &Registry) {
        for (&node, &(c, e)) in &other.entries {
            self.update(node, c, e);
        }
    }

    /// `Registered()`: nodes whose latest event is `joined`.
    pub fn registered(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.entries
            .iter()
            .filter(|(_, (_, e))| *e == MembershipEvent::Joined)
            .map(|(&n, _)| n)
    }

    pub fn is_registered(&self, node: NodeId) -> bool {
        matches!(self.entries.get(&node), Some((_, MembershipEvent::Joined)))
    }

    pub fn knows(&self, node: NodeId) -> bool {
        self.entries.contains_key(&node)
    }

    pub fn get(&self, node: NodeId) -> Option<(u64, MembershipEvent)> {
        self.entries.get(&node).copied()
    }

    /// Number of entries (drives the serialized view size).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (NodeId, u64, MembershipEvent)> + '_ {
        self.entries.iter().map(|(&n, &(c, e))| (n, c, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use MembershipEvent::*;

    #[test]
    fn newer_counter_wins() {
        let mut r = Registry::new();
        assert!(r.update(1, 1, Joined));
        assert!(r.update(1, 2, Left));
        assert!(!r.is_registered(1));
        // stale joined must not resurrect
        assert!(!r.update(1, 1, Joined));
        assert!(!r.is_registered(1));
    }

    #[test]
    fn equal_counter_is_noop() {
        let mut r = Registry::new();
        r.update(1, 3, Joined);
        assert!(!r.update(1, 3, Left));
        assert!(r.is_registered(1));
    }

    #[test]
    fn registered_filters_left_nodes() {
        let mut r = Registry::new();
        r.update(1, 1, Joined);
        r.update(2, 1, Joined);
        r.update(2, 2, Left);
        r.update(3, 5, Joined);
        let reg: Vec<NodeId> = r.registered().collect();
        assert_eq!(reg, vec![1, 3]);
    }

    #[test]
    fn merge_takes_newest_per_node() {
        let mut a = Registry::new();
        a.update(1, 1, Joined);
        a.update(2, 4, Left);
        let mut b = Registry::new();
        b.update(1, 2, Left);
        b.update(2, 3, Joined);
        b.update(3, 1, Joined);
        a.merge(&b);
        assert_eq!(a.get(1), Some((2, Left)));
        assert_eq!(a.get(2), Some((4, Left)));
        assert_eq!(a.get(3), Some((1, Joined)));
    }

    #[test]
    fn merge_is_idempotent_and_commutative() {
        let mut a = Registry::new();
        a.update(1, 1, Joined);
        a.update(2, 2, Left);
        let mut b = Registry::new();
        b.update(2, 3, Joined);
        b.update(4, 1, Joined);

        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);

        let mut abb = ab.clone();
        abb.merge(&b);
        assert_eq!(ab, abb);
    }

    #[test]
    fn rejoin_after_leave() {
        let mut r = Registry::new();
        r.update(7, 1, Joined);
        r.update(7, 2, Left);
        r.update(7, 3, Joined);
        assert!(r.is_registered(7));
    }
}
