//! Per-node protocol state (Alg. 4) — pure state transitions.
//!
//! `ModestNode` holds the cold per-node state a MoDeST participant keeps
//! between messages: its view, the two task-round cursors (`k_agg`,
//! `k_train`), the accumulating model list `Θ`, the per-round pong lists
//! `L[k]`, and any in-flight sampling operations. The hot flat counters
//! (membership counter, sampling-op sequence, last-activity timestamp)
//! live in the session's `sim::NodeTable` columns instead. Methods here
//! are pure state transitions returning what the caller (the event-driven
//! [`super::session`]) must do next; no I/O happens in this module, which
//! is what makes the protocol unit- and property-testable in isolation.

use std::collections::HashMap;
use std::sync::Arc;

use crate::learning::Model;
use crate::sim::SimTime;
use crate::{NodeId, Round};
// (Membership counters, op sequences, and activity timers are SoA columns
// in the session's `sim::NodeTable`, not fields here.)

use super::view::View;

/// Shared-ownership model payload (messages in flight hold references, not
/// copies — the traffic ledger accounts for the bytes instead).
pub type ModelRef = Arc<Model>;

/// Shared-ownership view payload: a fan-out to `s` peers snapshots the
/// sender's view once and every message holds the same immutable snapshot
/// (the ledger still charges the full serialized view per transfer).
pub type ViewRef = Arc<View>;

/// Wire messages of the MoDeST protocol.
#[derive(Debug, Clone)]
pub enum Msg {
    /// Liveness probe (Alg. 1).
    Ping { round: Round, from: NodeId },
    /// Probe reply.
    Pong { round: Round, from: NodeId },
    /// Membership advertisement (Alg. 2).
    Joined { node: NodeId, counter: u64 },
    /// Graceful-leave advertisement (Alg. 2).
    Left { node: NodeId, counter: u64 },
    /// Participant -> aggregators of the next sample (Alg. 4). `seq != 0`
    /// marks a reliably-tracked copy the receiver must ack to `from`.
    Aggregate { seq: u64, from: NodeId, round: Round, model: ModelRef, view: ViewRef },
    /// Aggregator -> participants of its sample (Alg. 4).
    Train { seq: u64, from: NodeId, round: Round, model: ModelRef, view: ViewRef },
    /// Reliable-delivery ack for a tracked `Train`/`Aggregate` (lossy
    /// sessions only). Sent unreliably: a dropped ack just provokes a
    /// retransmit, which the receiver re-acks.
    Ack { seq: u64 },
}

/// Why a sampling operation is running (continuation on completion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    /// Trainer looking for the `a` aggregators of round `k+1`
    /// (Alg. 4 line 35); payload = its updated model.
    Aggregators,
    /// Aggregator looking for the `s` participants of its round
    /// (Alg. 4 line 19); payload = the aggregated model.
    Participants,
}

/// One in-flight `Sample(k, need)` (Alg. 1) with its continuation payload.
#[derive(Debug)]
pub struct SampleOp {
    pub id: u64,
    pub round: Round,
    pub need: usize,
    pub purpose: Purpose,
    pub payload: ModelRef,
    /// Hash-sorted contact order (recomputed on retry).
    pub order: Vec<NodeId>,
    /// Next tail candidate to contact one-by-one.
    pub next_tail: usize,
    pub done: bool,
    pub started: SimTime,
    pub retries: u32,
}

/// What the session must do after feeding a message to the node.
#[derive(Debug, PartialEq)]
pub enum NodeAction {
    /// Reply with a pong (Alg. 1 line 23).
    SendPong { to: NodeId, round: Round },
    /// `Θ` crossed the `sf·s` threshold for `round`: start sampling the
    /// round's participants (Alg. 4 lines 17-19).
    BeginParticipantSample { round: Round },
    /// A train message was accepted: start the local update
    /// (Alg. 4 lines 29-30). `seq` identifies the training attempt so a
    /// later cancellation invalidates the completion event.
    BeginTraining { round: Round, seq: u64 },
    /// Nothing to do.
    Nothing,
}

/// Per-node protocol state (cold fields only — see module docs).
pub struct ModestNode {
    pub id: NodeId,
    pub view: View,
    /// Last aggregation round `k_agg` (Alg. 4).
    pub k_agg: Round,
    /// Accumulated models `Θ` for round `k_agg`.
    pub theta: Vec<ModelRef>,
    /// Senders of the models in `theta`, parallel to it: a retransmitted
    /// `aggregate` (its ack was dropped) must not count the same trainer's
    /// model twice toward the `sf·s` threshold.
    pub theta_from: Vec<NodeId>,
    /// Last round for which this node dispatched train messages, so a
    /// second threshold crossing in the same round cannot double-send.
    pub agg_dispatched: Round,
    /// Last training round `k_train` (Alg. 4).
    pub k_train: Round,
    /// In-flight local training: (round, seq, received model).
    pub training: Option<(Round, u64, ModelRef)>,
    pub train_seq: u64,
    /// Last round whose local training COMPLETED: a duplicate `train`
    /// (retransmit, or a second aggregator's slow copy) arriving after the
    /// round's update already ran must not restart it.
    pub k_done: Round,
    /// `L[k]`: pong lists per round (Alg. 1), deduplicated, arrival order.
    pub pongs: HashMap<Round, Vec<NodeId>>,
    /// In-flight sampling operations.
    pub ops: Vec<SampleOp>,
}

impl ModestNode {
    pub fn new(id: NodeId) -> ModestNode {
        ModestNode {
            id,
            view: View::default(),
            k_agg: 0,
            theta: Vec::new(),
            theta_from: Vec::new(),
            agg_dispatched: 0,
            k_train: 0,
            training: None,
            train_seq: 0,
            k_done: 0,
            pongs: HashMap::new(),
            ops: Vec::new(),
        }
    }

    /// Alg. 1 line 23: `upon ping(k, j): send pong(k, i)`.
    pub fn on_ping(&mut self, round: Round, from: NodeId) -> NodeAction {
        NodeAction::SendPong { to: from, round }
    }

    /// Alg. 1 line 25: `upon pong(k, j): L[k].add(j)`. Returns ids of ops
    /// that just became completable.
    pub fn on_pong(&mut self, round: Round, from: NodeId) -> Vec<u64> {
        let list = self.pongs.entry(round).or_default();
        if !list.contains(&from) {
            list.push(from);
        }
        let n = list.len();
        self.ops
            .iter()
            .filter(|op| !op.done && op.round == round && n >= op.need)
            .map(|op| op.id)
            .collect()
    }

    /// Alg. 2 `upon joined(j, c_j)` / `upon left(j, c_j)`.
    pub fn on_membership(&mut self, node: NodeId, counter: u64, joined: bool) {
        use super::registry::MembershipEvent::*;
        self.view
            .registry
            .update(node, counter, if joined { Joined } else { Left });
        // Estimate of the current round (Alg. 2 line 25).
        let k_hat = self.view.activity.estimate();
        self.view.activity.update(node, k_hat);
    }

    /// Alg. 4 `upon aggregate(k, θ_j, V_j)`. `s` and `sf` come from config;
    /// `from` is the sending trainer, deduplicated so retransmits cannot
    /// inflate `Θ`.
    pub fn on_aggregate(
        &mut self,
        round: Round,
        from: NodeId,
        model: ModelRef,
        view: &View,
        s: usize,
        sf: f64,
    ) -> NodeAction {
        self.view.merge(view);
        self.view.activity.update(self.id, round);
        if round > self.k_agg {
            self.k_agg = round;
            self.theta.clear();
            self.theta_from.clear();
            self.theta.push(model);
            self.theta_from.push(from);
        } else if round == self.k_agg {
            if self.theta_from.contains(&from) {
                return NodeAction::Nothing; // duplicate delivery of a retransmit
            }
            self.theta.push(model);
            self.theta_from.push(from);
        } else {
            return NodeAction::Nothing; // stale: a later round already ran
        }
        let threshold = ((sf * s as f64).ceil() as usize).max(1);
        if self.theta.len() >= threshold && self.agg_dispatched < round {
            self.agg_dispatched = round;
            return NodeAction::BeginParticipantSample { round };
        }
        NodeAction::Nothing
    }

    /// Alg. 4 `upon train(k, θ_a, V_j)`.
    pub fn on_train(&mut self, round: Round, model: ModelRef, view: &View) -> NodeAction {
        self.view.merge(view);
        self.view.activity.update(self.id, round);
        if round > self.k_train {
            self.k_train = round;
            self.training = None; // CANCEL(θ̄): stale attempt invalidated
        }
        if round == self.k_train && self.training.is_none() && round > self.k_done {
            self.train_seq += 1;
            let seq = self.train_seq;
            self.training = Some((round, seq, model));
            return NodeAction::BeginTraining { round, seq };
        }
        NodeAction::Nothing
    }

    /// Is training attempt `seq` still valid (not canceled)?
    pub fn training_valid(&self, seq: u64) -> Option<(Round, ModelRef)> {
        match &self.training {
            Some((round, s, model)) if *s == seq => Some((*round, model.clone())),
            _ => None,
        }
    }

    /// First `need` live nodes for an op (pong arrival order, Alg. 1
    /// `L[k].HEAD(s)`).
    pub fn live_for(&self, op: &SampleOp) -> Vec<NodeId> {
        self.pongs
            .get(&op.round)
            .map(|l| l.iter().take(op.need).copied().collect())
            .unwrap_or_default()
    }

    /// Drop completed ops and stale pong lists to bound memory.
    pub fn gc(&mut self) {
        self.ops.retain(|op| !op.done);
        let horizon = self.k_train.max(self.k_agg).saturating_sub(4);
        self.pongs.retain(|&k, _| k >= horizon);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelRef {
        Arc::new(vec![1.0f32])
    }

    #[test]
    fn ping_triggers_pong() {
        let mut n = ModestNode::new(3);
        assert_eq!(
            n.on_ping(7, 9),
            NodeAction::SendPong { to: 9, round: 7 }
        );
    }

    #[test]
    fn pong_dedup_and_completion() {
        let mut n = ModestNode::new(0);
        n.ops.push(SampleOp {
            id: 1,
            round: 4,
            need: 2,
            purpose: Purpose::Aggregators,
            payload: model(),
            order: vec![1, 2, 3],
            next_tail: 2,
            done: false,
            started: SimTime::ZERO,
            retries: 0,
        });
        assert!(n.on_pong(4, 1).is_empty()); // 1 < need
        assert!(n.on_pong(4, 1).is_empty()); // duplicate ignored
        assert_eq!(n.on_pong(4, 2), vec![1]); // reaches need
        assert_eq!(n.pongs[&4], vec![1, 2]);
    }

    #[test]
    fn pong_other_round_does_not_complete() {
        let mut n = ModestNode::new(0);
        n.ops.push(SampleOp {
            id: 1,
            round: 4,
            need: 1,
            purpose: Purpose::Aggregators,
            payload: model(),
            order: vec![],
            next_tail: 0,
            done: false,
            started: SimTime::ZERO,
            retries: 0,
        });
        assert!(n.on_pong(5, 1).is_empty());
    }

    #[test]
    fn aggregate_accumulates_and_fires_at_sf_threshold() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        // s=4, sf=0.75 -> threshold 3
        assert_eq!(n.on_aggregate(2, 1, model(), &v, 4, 0.75), NodeAction::Nothing);
        assert_eq!(n.on_aggregate(2, 2, model(), &v, 4, 0.75), NodeAction::Nothing);
        assert_eq!(
            n.on_aggregate(2, 3, model(), &v, 4, 0.75),
            NodeAction::BeginParticipantSample { round: 2 }
        );
        // a 4th model in the same round must NOT double-dispatch
        assert_eq!(n.on_aggregate(2, 4, model(), &v, 4, 0.75), NodeAction::Nothing);
        assert_eq!(n.theta.len(), 4);
    }

    #[test]
    fn duplicate_sender_does_not_inflate_theta() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        // s=2, sf=1.0 -> threshold 2. A retransmitted copy of trainer 1's
        // model (its ack was dropped) must not cross the threshold alone.
        assert_eq!(n.on_aggregate(2, 1, model(), &v, 2, 1.0), NodeAction::Nothing);
        assert_eq!(n.on_aggregate(2, 1, model(), &v, 2, 1.0), NodeAction::Nothing);
        assert_eq!(n.theta.len(), 1);
        assert_eq!(
            n.on_aggregate(2, 7, model(), &v, 2, 1.0),
            NodeAction::BeginParticipantSample { round: 2 }
        );
    }

    #[test]
    fn higher_round_resets_theta() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        n.on_aggregate(2, 1, model(), &v, 10, 1.0);
        n.on_aggregate(2, 2, model(), &v, 10, 1.0);
        assert_eq!(n.theta.len(), 2);
        n.on_aggregate(3, 1, model(), &v, 10, 1.0);
        assert_eq!(n.k_agg, 3);
        assert_eq!(n.theta.len(), 1);
        assert_eq!(n.theta_from, vec![1]);
    }

    #[test]
    fn stale_aggregate_ignored() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        n.on_aggregate(5, 1, model(), &v, 1, 1.0); // dispatches round 5
        assert_eq!(n.on_aggregate(4, 2, model(), &v, 1, 1.0), NodeAction::Nothing);
        assert_eq!(n.theta.len(), 1);
    }

    #[test]
    fn train_starts_once_per_round() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        let a = n.on_train(1, model(), &v);
        assert!(matches!(a, NodeAction::BeginTraining { round: 1, seq: 1 }));
        // second aggregator's copy of the same round: fast path, no restart
        assert_eq!(n.on_train(1, model(), &v), NodeAction::Nothing);
    }

    #[test]
    fn newer_train_cancels_pending() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        n.on_train(1, model(), &v);
        assert!(n.training_valid(1).is_some());
        let a = n.on_train(3, model(), &v);
        assert!(matches!(a, NodeAction::BeginTraining { round: 3, seq: 2 }));
        assert!(n.training_valid(1).is_none(), "seq 1 must be canceled");
        assert!(n.training_valid(2).is_some());
        assert_eq!(n.k_train, 3);
    }

    #[test]
    fn duplicate_train_after_completion_does_not_retrain() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        assert!(matches!(n.on_train(3, model(), &v), NodeAction::BeginTraining { .. }));
        // The session records completion and clears the in-flight slot.
        n.training = None;
        n.k_done = 3;
        // A retransmitted copy of the same round's train must be inert.
        assert_eq!(n.on_train(3, model(), &v), NodeAction::Nothing);
        // The next round still trains normally.
        assert!(matches!(
            n.on_train(4, model(), &v),
            NodeAction::BeginTraining { round: 4, .. }
        ));
    }

    #[test]
    fn stale_train_ignored() {
        let mut n = ModestNode::new(0);
        let v = View::default();
        n.on_train(5, model(), &v);
        assert_eq!(n.on_train(4, model(), &v), NodeAction::Nothing);
    }

    #[test]
    fn train_updates_own_activity() {
        let mut n = ModestNode::new(9);
        let v = View::default();
        n.on_train(12, model(), &v);
        assert_eq!(n.view.activity.get(9), Some(12));
    }

    #[test]
    fn membership_uses_round_estimate() {
        let mut n = ModestNode::new(0);
        n.view.activity.update(0, 42); // we know round 42 happened
        n.on_membership(5, 1, true);
        assert!(n.view.registry.is_registered(5));
        assert_eq!(n.view.activity.get(5), Some(42));
    }

    #[test]
    fn gc_drops_done_ops_and_old_pongs() {
        let mut n = ModestNode::new(0);
        n.k_train = 20;
        n.pongs.insert(3, vec![1]);
        n.pongs.insert(19, vec![1]);
        n.ops.push(SampleOp {
            id: 1,
            round: 20,
            need: 1,
            purpose: Purpose::Aggregators,
            payload: model(),
            order: vec![],
            next_tail: 0,
            done: true,
            started: SimTime::ZERO,
            retries: 0,
        });
        n.gc();
        assert!(n.ops.is_empty());
        assert!(!n.pongs.contains_key(&3));
        assert!(n.pongs.contains_key(&19));
    }
}
