//! MoDeST: Mostly-Consistent Decentralized Sampling Training.
//!
//! The paper's contribution, faithfully implemented as four pieces:
//!
//! * [`registry`] — Alg. 2: last-writer-wins joined/left registry ordered by
//!   per-node persistent counters (a state-based CRDT).
//! * [`activity`] — Alg. 3: latest-activity logical clock with max-merge and
//!   the `Δk` candidate window.
//! * [`view`] — registry + activity bundled, merged and piggybacked on model
//!   transfers.
//! * [`sampler`] — Alg. 1: the deterministic hash-sorted candidate order
//!   (the ping/pong liveness orchestration lives in [`session`]).
//! * [`node`] / [`session`] — Alg. 4: the push-based train/aggregate
//!   protocol with `k_agg`/`k_train` cancellation, `sf` thresholds, and the
//!   multi-aggregator fast path, driven over the discrete-event simulator.

pub mod activity;
pub mod builder;
pub mod node;
pub mod registry;
pub mod sampler;
pub mod session;
pub mod view;

pub use activity::ActivityClock;
pub use builder::{assemble_modest, modest_config, ModestBuilder};
pub use registry::{MembershipEvent, Registry};
pub use sampler::candidate_order;
pub use session::{ModestConfig, ModestProtocol, ModestSession};
pub use view::View;
