//! `obs-check` — the observability gate CI runs against live sessions.
//!
//! ```text
//! obs_check selftest
//! obs_check progress FILE.jsonl
//! ```
//!
//! * `selftest` re-derives the documented error bounds of the streaming
//!   sketches against exact oracles computed in-process: HyperLogLog
//!   distinct counts within 5% of the true cardinality at n ∈ {1k, 100k},
//!   histogram quantiles within 6.25% (1/16) of the exact order statistic,
//!   and merge associativity for both. A bound drifting past its table
//!   entry in `rust/README.md` fails the build here, not in a dashboard
//!   three PRs later.
//! * `progress FILE` validates a JSONL stream written by
//!   `--progress-every/--progress-out` (or `run.progress` in a scenario):
//!   sim-time must be non-strictly monotone, the byte ledger must
//!   reconcile on every line (`bytes_total == goodput + dropped +
//!   retrans`), and the final line must show at least one completed round.
//!   The final line is echoed so CI can upload it as the run's summary
//!   artifact. Any violation exits non-zero with the offending line.

use anyhow::{bail, Context, Result};

use modest_dl::sim::{Hll, StreamHistogram};
use modest_dl::util::Json;

/// splitmix64 finalizer — mirrors `sim::obs::mix64` so the selftest salts
/// match the python oracle in the design notes.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// HLL distinct-count estimates vs the exact cardinality. The keys are a
/// bijective mix of 0..n (odd-constant multiply), so the oracle is n
/// itself; the bound is the documented 5% (σ ≈ 1.6% at 2^12 registers).
fn check_hll_bounds() -> Result<()> {
    for n in [1_000u64, 100_000] {
        for salt_seed in [0u64, 1, 0xCAFE] {
            let mut hll = Hll::with_salt(mix64(salt_seed));
            for i in 0..n {
                hll.insert(i.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(7));
            }
            let est = hll.estimate();
            let err = (est - n as f64).abs() / n as f64;
            println!("hll: n={n} salt_seed={salt_seed:#x} est={est:.1} err={err:.4}");
            if err > 0.05 {
                bail!("hll estimate {est:.1} misses exact {n} by {err:.4} (> 0.05)");
            }
        }
    }
    Ok(())
}

/// Histogram quantiles vs the exact order statistic of the same sample.
/// The estimate is the bucket upper bound, so it may only over-shoot, and
/// by less than one sub-bucket width (1/16 relative).
fn check_hist_bounds() -> Result<()> {
    let mut h = StreamHistogram::new();
    let mut vals = Vec::new();
    let mut x = 0x2545F4914F6CDD1Du64;
    for _ in 0..50_000 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let v = 1 + (x >> 40); // ~[1, 2^24]
        h.record(v);
        vals.push(v);
    }
    vals.sort_unstable();
    for q in [0.01, 0.1, 0.5, 0.9, 0.95, 0.99] {
        let est = h.quantile(q) as f64;
        let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
        let exact = vals[rank - 1] as f64;
        let err = (est - exact).abs() / exact;
        println!("hist: q={q} est={est} exact={exact} err={err:.4}");
        if err > 0.0625 + 1e-9 {
            bail!("histogram q={q} estimate {est} misses exact {exact} by {err:.4}");
        }
    }
    Ok(())
}

/// Merge must be exactly associative for both sketches — the property a
/// future sharded harness leans on to combine per-shard state in any
/// order.
fn check_merge_associativity() -> Result<()> {
    let fill_hist = |seed: u64, n: u64| {
        let mut h = StreamHistogram::new();
        let mut x = seed;
        for _ in 0..n {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            h.record(x >> 44);
        }
        h
    };
    let (a, b, c) = (fill_hist(1, 5_000), fill_hist(2, 8_000), fill_hist(3, 3_000));
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a;
    right.merge(&bc);
    if left != right {
        bail!("histogram merge is not associative");
    }
    println!("hist: merge associative over {} samples", left.total());

    let salt = mix64(9);
    let fill_hll = |lo: u64, hi: u64| {
        let mut s = Hll::with_salt(salt);
        for i in lo..hi {
            s.insert(i);
        }
        s
    };
    let (a, b, c) = (fill_hll(0, 4_000), fill_hll(2_000, 9_000), fill_hll(8_000, 12_000));
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a;
    right.merge(&bc);
    if left != right {
        bail!("hll merge is not associative");
    }
    println!("hll: merge associative, union count {}", left.count());
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    check_hll_bounds()?;
    check_hist_bounds()?;
    check_merge_associativity()?;
    println!("obs-check: selftest OK — all sketches within documented bounds");
    Ok(())
}

/// Validate one progress JSONL stream and echo its final line.
fn cmd_progress(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path).with_context(|| path.to_string())?;
    let mut prev_t = f64::NEG_INFINITY;
    let mut last_line = None;
    let mut count = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = i + 1;
        let v = Json::parse(line)
            .with_context(|| format!("{path}:{lineno}: not valid JSON: {line}"))?;
        let t_s = v.field("t_s")?.as_f64()?;
        if !(t_s >= prev_t) {
            bail!("{path}:{lineno}: sim-time went backwards ({prev_t} -> {t_s})");
        }
        prev_t = t_s;
        let total = v.field("bytes_total")?.as_u64()?;
        let good = v.field("bytes_goodput")?.as_u64()?;
        let dropped = v.field("bytes_dropped")?.as_u64()?;
        let retrans = v.field("bytes_retrans")?.as_u64()?;
        if total != good + dropped + retrans {
            bail!(
                "{path}:{lineno}: byte ledger does not reconcile: \
                 total {total} != goodput {good} + dropped {dropped} + retrans {retrans}"
            );
        }
        // Presence checks for the remaining schema fields, so a renamed
        // field fails here instead of silently vanishing from dashboards.
        for key in ["alive", "rounds", "events", "msgs", "peers_est", "rss_kb"] {
            v.field(key)?.as_u64()?;
        }
        last_line = Some((lineno, line));
        count += 1;
    }
    let Some((lineno, line)) = last_line else {
        bail!("{path}: progress stream has no lines");
    };
    let rounds = Json::parse(line)?.field("rounds")?.as_u64()?;
    if rounds == 0 {
        bail!("{path}:{lineno}: final line shows zero completed rounds");
    }
    println!("obs-check: {path} OK — {count} lines, final:");
    println!("{line}");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("selftest") if args.len() == 1 => cmd_selftest(),
        Some("progress") if args.len() == 2 => cmd_progress(&args[1]),
        _ => bail!("usage: obs_check selftest | obs_check progress FILE.jsonl"),
    }
}
