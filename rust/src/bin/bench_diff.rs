//! `bench-diff` — the CI bench-trend gate.
//!
//! ```text
//! bench-diff BASE.json NEW.json [--threshold 2.0]
//! ```
//!
//! Compares per-bench medians (p50) between two `BENCH_hotpaths.json`
//! snapshots and exits non-zero when a guarded hot path — DES queue
//! push/pop (`des/queue/*`), fan-out (`fanout/*`), or peer sampling
//! (`sample/*`) — regressed by more than the threshold. Rows missing from
//! either snapshot are skipped (benches come and go across PRs), so an
//! empty baseline passes with a warning: CI falls back to the committed
//! `rust/BENCH_baseline.json` seed when the base commit has no artifact.
//!
//! That skip-and-pass fallback used to be *silent* when it made the gate
//! vacuous: a base snapshot that was empty, or simply predated a guarded
//! prefix, let every row under it sail through unchecked with no trace in
//! the log. Both cases now emit GitHub `::warning::` annotations (via
//! [`missing_guarded_coverage`]) so a green gate that checked nothing is
//! visible on the PR.

use anyhow::{bail, Context, Result};

use modest_dl::util::trend::{
    compare_trend, missing_guarded_coverage, parse_snapshot, regressions,
};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths: Vec<&str> = Vec::new();
    let mut threshold = 2.0f64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--threshold" => {
                let v = it.next().context("--threshold needs a value")?;
                threshold = v.parse().with_context(|| format!("--threshold {v:?}"))?;
            }
            other if other.starts_with("--") => bail!("unknown flag {other}"),
            other => paths.push(other),
        }
    }
    if paths.len() != 2 {
        bail!("usage: bench-diff BASE.json NEW.json [--threshold 2.0]");
    }
    let (base_path, new_path) = (paths[0], paths[1]);
    anyhow::ensure!(threshold > 1.0, "threshold must be > 1.0, got {threshold}");

    let base = parse_snapshot(
        &std::fs::read_to_string(base_path).with_context(|| base_path.to_string())?,
    )?;
    let new = parse_snapshot(
        &std::fs::read_to_string(new_path).with_context(|| new_path.to_string())?,
    )?;

    if base.is_empty() {
        println!(
            "::warning::bench-diff: base snapshot {base_path} has no rows — \
             the trend gate is vacuous for this run"
        );
    }
    for prefix in missing_guarded_coverage(&base, &new) {
        println!(
            "::warning::bench-diff: base snapshot {base_path} has no rows under \
             guarded prefix {prefix:?} — regressions there cannot be caught this run"
        );
    }

    let diffs = compare_trend(&base, &new);
    if diffs.is_empty() {
        println!(
            "bench-diff: no comparable benches between {base_path} ({}) and \
             {new_path} ({}) — nothing to gate",
            base.len(),
            new.len()
        );
        return Ok(());
    }

    println!("bench-diff: {base_path} -> {new_path} (fail guarded rows > {threshold}x)");
    for d in &diffs {
        println!(
            "  {:<44} {:>12} -> {:>12} ns  {:>6.2}x  {}",
            d.name,
            d.base_ns,
            d.new_ns,
            d.ratio,
            if d.guarded { "guarded" } else { "info" }
        );
    }

    let bad = regressions(&diffs, threshold);
    if bad.is_empty() {
        println!("bench-diff: OK — no guarded regression above {threshold}x");
        return Ok(());
    }
    eprintln!("bench-diff: FAIL — guarded hot paths regressed >{threshold}x:");
    for d in &bad {
        eprintln!(
            "  {} : {} -> {} ns ({:.2}x)",
            d.name, d.base_ns, d.new_ns, d.ratio
        );
    }
    std::process::exit(1);
}
