//! Gossip-DL: epidemic model averaging over random peers.
//!
//! The ROADMAP's first protocol fan-out target, in the style of gossip
//! learning (Ormándi et al.; also the "gossip" baselines in DecentralizePy):
//! every node repeatedly (1) trains on its local shard, (2) pushes its
//! model to `fanout` uniformly random alive peers, (3) merges every model
//! it receives into its own by pairwise averaging. There is no barrier of
//! any kind — rounds are purely local counters — so convergence rides on
//! the epidemic mixing rate rather than on aggregators (MoDeST) or a fixed
//! topology (D-SGD).
//!
//! This module is also the Scenario API's extensibility proof: it touches
//! nothing outside this file except the module declaration in `lib.rs` and
//! one registration line in `scenario::ProtocolRegistry::builtins` — no
//! enum variant, no launcher match arms, no experiment edits.

use std::sync::Arc;

use anyhow::Result;

use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::SessionMetrics;
use crate::net::{MsgKind, NetworkFabric, SizeModel, TrafficLedger};
use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolMeta, ScenarioSpec, Session, SessionBuilder};
use crate::sim::{
    ChurnSchedule, Ctx, EvalPoint, HarnessConfig, Protocol, SimHarness, SimTime,
};
use crate::{NodeId, Round};

/// Gossip-DL parameters.
#[derive(Debug, Clone)]
pub struct GossipConfig {
    /// Peers each node pushes its model to after every local epoch.
    pub fanout: usize,
    pub max_time: SimTime,
    pub max_rounds: Round,
    pub eval_interval: SimTime,
    /// Node models evaluated for the mean±std curve (like D-SGD).
    pub eval_nodes: usize,
    pub target_metric: Option<f64>,
    pub seed: u64,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig {
            fanout: 2,
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            eval_nodes: 8,
            target_metric: None,
            seed: 42,
        }
    }
}

/// The single wire message: a peer's current model.
pub struct GossipMsg {
    pub model: Arc<Model>,
}

struct GossipNode {
    /// Local epoch counter (the protocol's only notion of a round).
    round: Round,
    /// Shared so pushing to `fanout` peers and keeping the local copy
    /// never duplicate the model buffer.
    model: Arc<Model>,
}

/// The gossip-DL state machine (drives through [`SimHarness`]).
pub struct GossipProtocol {
    cfg: GossipConfig,
    nodes: Vec<GossipNode>,
    sizes: SizeModel,
}

impl GossipProtocol {
    fn seed_for(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0xD1B54A32D192ED03)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    fn start_training(&self, ctx: &mut Ctx<'_, GossipMsg>, node: NodeId) {
        let batches = ctx.task.batches_per_epoch(node);
        let dur = ctx.compute.train_time(node, batches);
        let round = self.nodes[node as usize].round;
        // The local epoch counter doubles as the training sequence id.
        ctx.schedule_train_done(dur, node, round);
    }

    fn push_model(&self, ctx: &mut Ctx<'_, GossipMsg>, from: NodeId, model: Arc<Model>) {
        let peers = ctx.alive_peers(from);
        if peers.is_empty() {
            return;
        }
        let k = self.cfg.fanout.min(peers.len());
        let picks = ctx.rng.sample_indices(peers.len(), k);
        let model_b = ctx.task.model_bytes();
        let total = self.sizes.model_transfer_bytes(model_b, 0);
        for p in picks {
            ctx.send(
                from,
                peers[p],
                &[(MsgKind::ModelPayload, model_b), (MsgKind::Control, total - model_b)],
                GossipMsg { model: model.clone() },
            );
        }
    }
}

impl Protocol for GossipProtocol {
    type Msg = GossipMsg;

    fn bootstrap(&mut self, ctx: &mut Ctx<'_, GossipMsg>) {
        ctx.record_round_start(1);
        for node in 0..self.nodes.len() as NodeId {
            self.start_training(ctx, node);
        }
    }

    fn on_deliver(&mut self, ctx: &mut Ctx<'_, GossipMsg>, to: NodeId, msg: GossipMsg) {
        // Epidemic merge: average the incoming model into the local one.
        let merged = {
            let local = self.nodes[to as usize].model.as_ref();
            ctx.task
                .aggregate(&[local, msg.model.as_ref()])
                .expect("aggregate")
        };
        self.nodes[to as usize].model = Arc::new(merged);
    }

    fn on_train_done(&mut self, ctx: &mut Ctx<'_, GossipMsg>, node: NodeId, seq: u64) {
        if self.nodes[node as usize].round != seq {
            return; // stale
        }
        let round = seq;
        let seed = self.seed_for(node, round);
        let input = self.nodes[node as usize].model.clone();
        let (updated, _loss, _batches) =
            ctx.task.local_update(&input, node, seed).expect("local_update");
        let arc = Arc::new(updated);
        self.nodes[node as usize].model = arc.clone();
        self.push_model(ctx, node, arc);
        self.nodes[node as usize].round = round + 1;
        if node == 0 {
            ctx.record_round_start(round + 1);
        }
        // Rounds are purely local, so the budget is per node: a node that
        // hits it just stops training while slower replicas catch up.
        // Finishing globally on the FIRST node would truncate slow nodes
        // well short of the budget under heterogeneous compute and bias
        // comparisons; the session ends once the LAST node is done.
        if ctx.round_budget_exceeded(round + 1) {
            if self.nodes.iter().all(|x| ctx.round_budget_exceeded(x.round)) {
                ctx.finish();
            }
            return;
        }
        self.start_training(ctx, node);
    }

    fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint> {
        // Mean±std over an even subsample of node models, like D-SGD: the
        // residual variance across replicas is the story.
        let n = self.nodes.len();
        let k = self.cfg.eval_nodes.min(n).max(1);
        let mut metrics = Vec::with_capacity(k);
        let mut losses = Vec::with_capacity(k);
        for j in 0..k {
            let idx = j * n / k;
            let e = task.evaluate(&self.nodes[idx].model)?;
            metrics.push(e.metric);
            losses.push(e.loss);
        }
        let mean = metrics.iter().sum::<f64>() / k as f64;
        let var = metrics.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / k as f64;
        let loss = losses.iter().sum::<f64>() / k as f64;
        Ok(EvalPoint {
            round: self.final_round(),
            metric: mean,
            loss,
            metric_std: var.sqrt(),
        })
    }

    fn final_round(&self) -> Round {
        self.nodes.iter().map(|x| x.round).min().unwrap_or(0)
    }
}

/// Assembly facade: builds a [`GossipProtocol`] and its [`SimHarness`].
pub struct GossipSession {
    harness: SimHarness<GossipProtocol>,
}

impl GossipSession {
    pub fn new(
        cfg: GossipConfig,
        n: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        fabric: NetworkFabric,
    ) -> GossipSession {
        let init = Arc::new(task.init_model());
        let nodes = (0..n).map(|_| GossipNode { round: 1, model: init.clone() }).collect();
        let hcfg = HarnessConfig {
            max_time: cfg.max_time,
            max_rounds: cfg.max_rounds,
            eval_interval: cfg.eval_interval,
            target_metric: cfg.target_metric,
            seed: cfg.seed,
        };
        let protocol = GossipProtocol { cfg, nodes, sizes: SizeModel::default() };
        GossipSession {
            harness: SimHarness::new(
                hcfg,
                protocol,
                n,
                n,
                task,
                compute,
                fabric,
                ChurnSchedule::empty(),
            ),
        }
    }

    pub fn run(self) -> (SessionMetrics, TrafficLedger) {
        self.harness.run()
    }
}

impl Session for GossipSession {
    fn run(self: Box<Self>) -> (SessionMetrics, TrafficLedger) {
        GossipSession::run(*self)
    }
}

/// Registry factory for gossip-DL.
pub struct GossipBuilder;

impl SessionBuilder for GossipBuilder {
    fn meta(&self) -> ProtocolMeta {
        ProtocolMeta {
            name: "gossip",
            label: "Gossip-DL",
            aliases: &["gossip-dl"],
            summary: "epidemic model averaging: train, push to `fanout` random \
                      peers, merge on receipt (no aggregators, no topology)",
            // Every node trains every local epoch, like D-SGD.
            default_round_budget: 120,
            default_params: &[("fanout", 2.0)],
        }
    }

    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        anyhow::ensure!(
            churn.events().is_empty(),
            "gossip-dl does not support churn scripts yet"
        );
        let n = spec.resolved_nodes()?;
        let task = spec.build_task(runtime)?;
        let fabric = spec.build_fabric(n)?;
        let compute = spec.build_compute(n);
        // The fallback comes from this builder's own advertised metadata,
        // so `repro protocols` can never document a different default than
        // the one that actually runs.
        let default_fanout = self
            .meta()
            .default_params
            .iter()
            .find(|(k, _)| *k == "fanout")
            .map(|&(_, v)| v)
            .unwrap_or(2.0);
        let fanout = spec.protocol.param("fanout").unwrap_or(default_fanout);
        anyhow::ensure!(
            fanout >= 1.0 && fanout.fract() == 0.0,
            "gossip fanout must be a positive integer, got {fanout}"
        );
        let fanout = fanout as usize;
        let cfg = GossipConfig {
            fanout,
            max_time: SimTime::from_secs_f64(spec.run.max_time_s),
            max_rounds: spec.run.max_rounds,
            eval_interval: SimTime::from_secs_f64(spec.run.eval_interval_s),
            eval_nodes: 8,
            target_metric: spec.run.target_metric,
            seed: spec.run.seed,
        };
        Ok(Box::new(GossipSession::new(cfg, n, task, compute, fabric)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::{BandwidthConfig, LatencyMatrix, LatencyParams};
    use crate::sim::SimRng;

    fn session(n: usize, cfg: GossipConfig) -> GossipSession {
        let mut rng = SimRng::new(cfg.seed);
        let task = MockTask::new(n, 16, 0.5, cfg.seed);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), n, &mut rng.fork("lat"));
        let fabric = NetworkFabric::new(
            latency,
            &BandwidthConfig::uniform_mbps(50.0),
            n,
            &mut rng.fork("bw"),
        );
        let compute = ComputeModel::uniform(n, 0.05);
        GossipSession::new(cfg, n, Box::new(task), compute, fabric)
    }

    #[test]
    fn gossip_advances_and_learns() {
        let cfg = GossipConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = session(8, cfg).run();
        assert!(m.final_round >= 30, "round {}", m.final_round);
        // Epidemic averaging carries residual cross-replica variance, so
        // the bar matches D-SGD's, not MoDeST's.
        assert!(m.best_metric(true).unwrap() > 0.4, "best {:?}", m.best_metric(true));
        assert!(traffic.is_conserved());
        assert!(traffic.total() > 0);
    }

    #[test]
    fn fanout_scales_traffic() {
        let mk = |fanout| GossipConfig {
            fanout,
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 15,
            ..Default::default()
        };
        let (_, t1) = session(10, mk(1)).run();
        let (_, t3) = session(10, mk(3)).run();
        assert!(
            t3.total() > 2 * t1.total(),
            "fanout 3 sent {} vs fanout 1 {}",
            t3.total(),
            t1.total()
        );
    }

    #[test]
    fn same_seed_replays_identically() {
        let mk = || GossipConfig {
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (a, ta) = session(6, mk()).run();
        let (b, tb) = session(6, mk()).run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
    }
}
