//! Gossip-DL: epidemic model averaging over random peers.
//!
//! The ROADMAP's first protocol fan-out target, in the style of gossip
//! learning (Ormándi et al.; also the "gossip" baselines in DecentralizePy):
//! every node repeatedly (1) trains on its local shard, (2) pushes its
//! model to `fanout` uniformly random alive peers, (3) merges every model
//! it receives into its own by pairwise averaging. There is no barrier of
//! any kind — rounds are purely local counters — so convergence rides on
//! the epidemic mixing rate rather than on aggregators (MoDeST) or a fixed
//! topology (D-SGD).
//!
//! This module is also the Scenario API's extensibility proof: it touches
//! nothing outside this file except the module declaration in `lib.rs` and
//! one registration line in `scenario::ProtocolRegistry::builtins` — no
//! enum variant, no launcher match arms, no experiment edits.

use std::sync::Arc;

use anyhow::Result;

use crate::learning::{ComputeModel, Model, Task};
use crate::metrics::SessionMetrics;
use crate::net::{MsgKind, NetworkFabric, SizeModel, TrafficLedger};
use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolMeta, ScenarioSpec, Session, SessionBuilder};
use crate::sim::{
    ChurnEvent, ChurnKind, ChurnSchedule, Ctx, EvalPoint, HarnessConfig, LivenessMirror,
    NodeTable, Protocol, ReliabilityConfig, ReliableOutbox, ResumeOptions, SamplingVersion,
    SimHarness, SimRng, SimTime, SnapshotReader, SnapshotWriter, TimerVerdict,
};
use crate::{NodeId, Round};

/// Gossip-DL parameters.
#[derive(Debug, Clone)]
pub struct GossipConfig {
    /// Peers each node pushes its model to after every local epoch.
    pub fanout: usize,
    pub max_time: SimTime,
    pub max_rounds: Round,
    pub eval_interval: SimTime,
    /// Node models evaluated for the mean±std curve (like D-SGD).
    pub eval_nodes: usize,
    pub target_metric: Option<f64>,
    pub seed: u64,
    /// Peer-sampling stream version (v1 = frozen full shuffle, v2 = O(k)).
    pub sampling: SamplingVersion,
    /// Canonical scenario JSON embedded into snapshots (None = session not
    /// built from a spec; checkpointing disabled).
    pub spec_json: Option<String>,
    /// Write a snapshot and stop once the clock reaches this instant.
    pub checkpoint_at: Option<SimTime>,
    /// Snapshot file path for `checkpoint_at`.
    pub checkpoint_out: Option<String>,
    /// Ack/retransmit contract; `Some` exactly when the session's fabric
    /// injects loss (lossless sessions run the pre-loss code path).
    pub reliability: Option<ReliabilityConfig>,
    /// Live JSONL progress stream (None = off).
    pub progress: Option<crate::sim::ProgressConfig>,
    /// Event-queue execution threads (1 = classic single-threaded loop;
    /// T > 1 runs the sharded conservative-window scheduler, bit-identical).
    pub threads: usize,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig {
            fanout: 2,
            max_time: SimTime::from_secs_f64(1800.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(20.0),
            eval_nodes: 8,
            target_metric: None,
            seed: 42,
            sampling: SamplingVersion::default(),
            spec_json: None,
            checkpoint_at: None,
            checkpoint_out: None,
            reliability: None,
            progress: None,
            threads: 1,
        }
    }
}

/// Wire messages. `seq` 0 means untracked (lossless session): the receiver
/// merges without acking, exactly the pre-loss behaviour.
#[derive(Clone)]
pub enum GossipMsg {
    /// A peer's current model.
    Push { seq: u64, from: NodeId, model: Arc<Model> },
    /// Reliability ack for a tracked push (unreliable itself).
    Ack { seq: u64 },
}

/// The gossip-DL state machine (drives through [`SimHarness`]).
pub struct GossipProtocol {
    cfg: GossipConfig,
    /// Hot per-node counters in SoA columns: the local epoch (`rounds` —
    /// the protocol's only notion of a round, and the budget the session
    /// stops on) and the training sequence (`seqs` — bumped per dispatched
    /// job and on recovery, so exactly one in-flight completion is valid).
    nodes: NodeTable,
    /// Cold per-node state: each node's current model, Arc-shared so
    /// pushing to `fanout` peers and keeping the local copy never
    /// duplicate the model buffer.
    models: Vec<Arc<Model>>,
    /// Protocol-side liveness mirror (the harness drops events at dead
    /// nodes; this keeps evaluation, the round-start trace, and the round
    /// budget to live replicas). Shared bookkeeping with D-SGD.
    live: LivenessMirror,
    /// Scripted Join/Recover events that have not fired yet: a total
    /// outage with revivals still pending must not finish the session.
    pending_revivals: usize,
    sizes: SizeModel,
    /// Retransmit ledger for lossy sessions; `None` = lossless, zero
    /// bookkeeping, bit-identical pre-loss event stream.
    outbox: Option<ReliableOutbox<GossipMsg>>,
}

impl GossipProtocol {
    fn seed_for(&self, node: NodeId, round: Round) -> u64 {
        self.cfg
            .seed
            .wrapping_mul(0xD1B54A32D192ED03)
            .wrapping_add((node as u64) << 32)
            .wrapping_add(round)
    }

    fn start_training(&mut self, ctx: &mut Ctx<'_, GossipMsg>, node: NodeId) {
        let batches = ctx.task.batches_per_epoch(node);
        let dur = ctx.compute.train_time(node, batches);
        // A fresh per-job sequence id (D-SGD's pattern): only the newest
        // dispatched job's completion is ever accepted, and the budgeted
        // round counter stays out of staleness bookkeeping entirely.
        let seq = self.nodes.bump_seq(node as usize);
        ctx.schedule_train_done(dur, node, seq);
    }

    fn push_model(&mut self, ctx: &mut Ctx<'_, GossipMsg>, from: NodeId, model: Arc<Model>) {
        let model_b = ctx.task.model_bytes();
        let total = self.sizes.model_transfer_bytes(model_b, 0);
        let parts = [(MsgKind::ModelPayload, model_b), (MsgKind::Control, total - model_b)];
        // `Ctx::sample_peers` never materializes a peer list: all-alive
        // tables map sampled indices straight to peer ids, churned tables
        // map sampled alive-ranks through the Population's Fenwick index
        // (O(fanout · log n) under `sampling: v2`). Both draw the
        // identical `sample_indices(m, k)` call, so the RNG stream — and
        // the session fingerprint — are unchanged from the pre-helper
        // code.
        for to in ctx.sample_peers(from, self.cfg.fanout) {
            match &mut self.outbox {
                Some(ob) => {
                    let m = model.clone();
                    ob.track(ctx, from, to, &parts, |seq| GossipMsg::Push {
                        seq,
                        from,
                        model: m,
                    });
                }
                None => ctx.send(from, to, &parts, GossipMsg::Push {
                    seq: 0,
                    from,
                    model: model.clone(),
                }),
            }
        }
    }

    /// True when at least one node is live and every live node has run out
    /// of round budget (with `max_rounds == 0` this is never true).
    fn all_live_done(&self, ctx: &Ctx<'_, GossipMsg>) -> bool {
        let mut any_live = false;
        for i in 0..self.nodes.len() {
            if self.live.is_dead(i) {
                continue;
            }
            any_live = true;
            if !ctx.round_budget_exceeded(self.nodes.round(i)) {
                return false;
            }
        }
        any_live
    }

    /// Record the start of `round` once, from the lowest live node (node 0
    /// unless churn killed it), keeping the trace monotone.
    fn record_round(&mut self, ctx: &mut Ctx<'_, GossipMsg>, node: NodeId, round: Round) {
        if self.live.should_record(node, round) {
            ctx.record_round_start(round);
        }
    }
}

impl Protocol for GossipProtocol {
    type Msg = GossipMsg;

    fn bootstrap(&mut self, ctx: &mut Ctx<'_, GossipMsg>) {
        ctx.record_round_start(1);
        self.live.force_started(1);
        for node in 0..self.nodes.len() as NodeId {
            // Churn-script joiners exist only as NotJoined placeholders at
            // t=0; they start training when their Join event fires.
            if self.live.is_dead(node as usize) {
                continue;
            }
            self.start_training(ctx, node);
        }
    }

    fn on_deliver(&mut self, ctx: &mut Ctx<'_, GossipMsg>, to: NodeId, msg: GossipMsg) {
        match msg {
            GossipMsg::Push { seq, from, model } => {
                // Epidemic merge: average the incoming model into the
                // local one. A duplicate (retransmit whose original
                // arrived) re-merges — averaging is idempotent enough for
                // an epidemic, and the ack must be repeated anyway in case
                // the first ack was the casualty.
                let merged = {
                    let local = self.models[to as usize].as_ref();
                    ctx.task
                        .aggregate(&[local, model.as_ref()])
                        .expect("aggregate")
                };
                self.models[to as usize] = Arc::new(merged);
                if seq != 0 {
                    let parts = [(MsgKind::Control, self.sizes.ping_bytes())];
                    ctx.send(to, from, &parts, GossipMsg::Ack { seq });
                }
            }
            GossipMsg::Ack { seq } => {
                if let Some(ob) = &mut self.outbox {
                    ob.ack(seq); // stale acks fall out silently
                }
            }
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx<'_, GossipMsg>, _node: NodeId, id: u64) {
        if let Some(ob) = &mut self.outbox {
            match ob.on_timer(ctx, id) {
                // Epidemic redundancy is the degradation: a push that
                // exhausted its retries is simply lost fan-out.
                TimerVerdict::Expired(_) | TimerVerdict::Handled => {}
                TimerVerdict::NotOurs => {}
            }
        }
    }

    fn on_train_done(&mut self, ctx: &mut Ctx<'_, GossipMsg>, node: NodeId, seq: u64) {
        if self.nodes.seq(node as usize) != seq {
            return; // stale: a newer dispatch or a recovery superseded it
        }
        let round = self.nodes.round(node as usize);
        let seed = self.seed_for(node, round);
        let input = self.models[node as usize].clone();
        let (updated, _loss, _batches) =
            ctx.task.local_update(&input, node, seed).expect("local_update");
        let arc = Arc::new(updated);
        self.models[node as usize] = arc.clone();
        self.push_model(ctx, node, arc);
        self.nodes.set_round(node as usize, round + 1);
        self.record_round(ctx, node, round + 1);
        // Rounds are purely local, so the budget is per node: a node that
        // hits it just stops training while slower replicas catch up.
        // Finishing globally on the FIRST node would truncate slow nodes
        // well short of the budget under heterogeneous compute and bias
        // comparisons; the session ends once the LAST live node is done
        // (dead replicas can never catch up and must not stall the stop).
        if ctx.round_budget_exceeded(round + 1) {
            if self.all_live_done(ctx) {
                ctx.finish();
            }
            return;
        }
        self.start_training(ctx, node);
    }

    /// Scripted churn (ROADMAP item: gossip used to reject churn scripts),
    /// including availability-compiled crash/recover cycles.
    /// Crashes/leaves only flip the liveness mirror — the harness already
    /// drops the dead node's in-flight deliveries and pending train
    /// completions, and `sample_peers` excludes it from future fan-outs.
    /// Joins/recoveries bump the training sequence (invalidating any stale
    /// pre-crash completion) and restart the round the node was in.
    fn on_churn(&mut self, ctx: &mut Ctx<'_, GossipMsg>, ev: ChurnEvent) {
        let i = ev.node as usize;
        if i >= self.nodes.len() {
            return;
        }
        match ev.kind {
            ChurnKind::Join | ChurnKind::Recover => {
                self.pending_revivals = self.pending_revivals.saturating_sub(1);
                self.live.set_live(i);
                // Staleness is the seq column's job, not the round's:
                // offline/online cycles alone must never consume
                // `max_rounds` (the node resumes the round it was in).
                // The bump matters even when training does not restart —
                // a node over budget can still have a pre-crash
                // completion land inside this alive window.
                self.nodes.bump_seq(i);
                if !ctx.round_budget_exceeded(self.nodes.round(i)) {
                    self.start_training(ctx, ev.node);
                }
            }
            ChurnKind::Leave | ChurnKind::Crash => {
                self.live.set_dead(i);
                // The dead node may have been the last one still under its
                // round budget; without this check the session would idle
                // through probe ticks until max_time. A total outage also
                // ends the session — unless a scripted revival has not
                // fired yet (even one queued at this same instant), in
                // which case the queue must keep running so it can.
                let done = if self.live.any_live() {
                    self.all_live_done(ctx)
                } else {
                    self.pending_revivals == 0
                };
                if done {
                    ctx.finish();
                }
            }
        }
    }

    fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint> {
        // Mean±std over an even subsample of LIVE node models, like D-SGD:
        // the residual variance across replicas is the story. (With no
        // churn every node is live, so this is the original subsample.)
        let live = self.live.live_indices();
        let n = live.len().max(1);
        let k = self.cfg.eval_nodes.min(n).max(1);
        let mut metrics = Vec::with_capacity(k);
        let mut losses = Vec::with_capacity(k);
        for j in 0..k {
            let idx = live.get(j * n / k).copied().unwrap_or(0);
            let e = task.evaluate(&self.models[idx])?;
            metrics.push(e.metric);
            losses.push(e.loss);
        }
        let mean = metrics.iter().sum::<f64>() / k as f64;
        let var = metrics.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / k as f64;
        let loss = losses.iter().sum::<f64>() / k as f64;
        Ok(EvalPoint {
            round: self.final_round(),
            metric: mean,
            loss,
            metric_std: var.sqrt(),
        })
    }

    fn final_round(&self) -> Round {
        self.live.min_live_round(self.nodes.rounds())
    }

    // Dynamic state only: `cfg` and `sizes` are rebuilt from the spec. The
    // model vector goes through the writer's Arc interning, so the shared
    // init model (and every post-merge sharing pattern) survives a
    // write→read→write round trip byte-identically.
    fn snapshot(&self, w: &mut SnapshotWriter) -> Result<()> {
        self.nodes.write_into(w);
        w.write_usize(self.models.len());
        for m in &self.models {
            w.write_model(m);
        }
        self.live.write_into(w);
        w.write_usize(self.pending_revivals);
        w.write_bool(self.outbox.is_some());
        if let Some(ob) = &self.outbox {
            ob.write_into(w, |w, m| self.write_msg(w, m))?;
        }
        Ok(())
    }

    fn restore(&mut self, r: &mut SnapshotReader) -> Result<()> {
        self.nodes = NodeTable::read_from(r)?;
        let n = r.read_usize()?;
        let mut models = Vec::with_capacity(n);
        for _ in 0..n {
            models.push(r.read_model()?);
        }
        self.models = models;
        self.live = LivenessMirror::read_from(r)?;
        self.pending_revivals = r.read_usize()?;
        if r.read_bool()? {
            // Snapshot carries in-flight retransmit state. If a resume
            // overlay turned loss off, the entries are consumed and
            // dropped (the branch is deliberately diverging).
            let cfg = self.cfg.reliability.unwrap_or(ReliabilityConfig {
                timeout: SimTime::from_secs_f64(1.0),
                backoff: 1.0,
                max_timeout: SimTime::from_secs_f64(1.0),
                retries: 1,
            });
            let ob = ReliableOutbox::read_from(r, cfg, |r| self.read_msg(r))?;
            if self.cfg.reliability.is_some() {
                self.outbox = Some(ob);
            }
        }
        Ok(())
    }

    fn write_msg(&self, w: &mut SnapshotWriter, msg: &GossipMsg) -> Result<()> {
        match msg {
            GossipMsg::Push { seq, from, model } => {
                w.write_u8(0);
                w.write_u64(*seq);
                w.write_u32(*from);
                w.write_model(model);
            }
            GossipMsg::Ack { seq } => {
                w.write_u8(1);
                w.write_u64(*seq);
            }
        }
        Ok(())
    }

    fn read_msg(&self, r: &mut SnapshotReader) -> Result<GossipMsg> {
        Ok(match r.read_u8()? {
            0 => GossipMsg::Push {
                seq: r.read_u64()?,
                from: r.read_u32()?,
                model: r.read_model()?,
            },
            1 => GossipMsg::Ack { seq: r.read_u64()? },
            other => anyhow::bail!("unknown gossip message tag {other}"),
        })
    }
}

/// Assembly facade: builds a [`GossipProtocol`] and its [`SimHarness`].
pub struct GossipSession {
    harness: SimHarness<GossipProtocol>,
}

impl GossipSession {
    /// Build a session over `n` initially-alive nodes plus whatever node
    /// ids the churn script introduces later.
    pub fn new(
        cfg: GossipConfig,
        n: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        fabric: NetworkFabric,
        churn: ChurnSchedule,
    ) -> GossipSession {
        let max_node = churn.node_extent().max(n);
        let init = Arc::new(task.init_model());
        let nodes = NodeTable::new(max_node).with_rounds(1).with_seqs();
        let models = (0..max_node).map(|_| init.clone()).collect();
        let live = LivenessMirror::with_live_prefix(max_node, n);
        let pending_revivals = churn
            .events()
            .iter()
            .filter(|e| matches!(e.kind, ChurnKind::Join | ChurnKind::Recover))
            .count();
        let mut compute = compute;
        let mut rng = SimRng::new(cfg.seed ^ 0x676f_7373_6970_0001);
        compute.ensure_nodes(max_node, &mut rng);
        let hcfg = HarnessConfig {
            max_time: cfg.max_time,
            max_rounds: cfg.max_rounds,
            eval_interval: cfg.eval_interval,
            target_metric: cfg.target_metric,
            seed: cfg.seed,
            sampling: cfg.sampling,
            spec_json: cfg.spec_json.clone(),
            checkpoint_at: cfg.checkpoint_at,
            checkpoint_out: cfg.checkpoint_out.clone(),
            progress: cfg.progress.clone(),
            threads: cfg.threads,
        };
        let outbox = cfg.reliability.map(ReliableOutbox::new);
        let protocol = GossipProtocol {
            cfg,
            nodes,
            models,
            live,
            pending_revivals,
            sizes: SizeModel::default(),
            outbox,
        };
        GossipSession {
            harness: SimHarness::new(
                hcfg, protocol, max_node, n, task, compute, fabric, churn,
            ),
        }
    }

    pub fn run(self) -> (SessionMetrics, TrafficLedger) {
        self.harness.run()
    }
}

impl Session for GossipSession {
    fn run(self: Box<Self>) -> (SessionMetrics, TrafficLedger) {
        GossipSession::run(*self)
    }

    fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        self.harness.snapshot_bytes()
    }

    fn resume(&mut self, r: &mut SnapshotReader, opts: &ResumeOptions) -> Result<()> {
        self.harness.restore_from(r, opts)
    }
}

/// Registry factory for gossip-DL.
pub struct GossipBuilder;

impl SessionBuilder for GossipBuilder {
    fn meta(&self) -> ProtocolMeta {
        ProtocolMeta {
            name: "gossip",
            label: "Gossip-DL",
            aliases: &["gossip-dl"],
            summary: "epidemic model averaging: train, push to `fanout` random \
                      peers, merge on receipt (no aggregators, no topology)",
            // Every node trains every local epoch, like D-SGD.
            default_round_budget: 120,
            default_params: &[("fanout", 2.0)],
        }
    }

    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        let n = spec.resolved_nodes()?;
        // Only Join/Recover events may introduce node ids beyond the
        // initial population (the dataset/fabric/compute substrates are
        // sized to cover them); a Crash/Leave of a node that can never
        // exist is a script typo and must fail, not silently inflate the
        // session with phantom dead nodes.
        let max_n = n.max(churn.join_extent());
        for e in churn.events() {
            anyhow::ensure!(
                (e.node as usize) < max_n,
                "gossip churn {:?} names node {} which never joins a population of {max_n}",
                e.kind,
                e.node
            );
        }
        let task = spec.build_task_for(runtime, max_n)?;
        let fabric = spec.build_fabric(max_n)?;
        let compute = spec.build_compute(max_n);
        // The fallback comes from this builder's own advertised metadata,
        // so `repro protocols` can never document a different default than
        // the one that actually runs.
        let default_fanout = self
            .meta()
            .default_params
            .iter()
            .find(|(k, _)| *k == "fanout")
            .map(|&(_, v)| v)
            .unwrap_or(2.0);
        let fanout = spec.protocol.param("fanout").unwrap_or(default_fanout);
        anyhow::ensure!(
            fanout >= 1.0 && fanout.fract() == 0.0,
            "gossip fanout must be a positive integer, got {fanout}"
        );
        let fanout = fanout as usize;
        let cfg = GossipConfig {
            fanout,
            max_time: SimTime::from_secs_f64(spec.run.max_time_s),
            max_rounds: spec.run.max_rounds,
            eval_interval: SimTime::from_secs_f64(spec.run.eval_interval_s),
            eval_nodes: 8,
            target_metric: spec.run.target_metric,
            seed: spec.run.seed,
            sampling: spec.run.sampling,
            spec_json: Some(spec.snapshot_json()),
            checkpoint_at: spec.run.checkpoint_at_s.map(SimTime::from_secs_f64),
            checkpoint_out: spec.run.checkpoint_out.clone(),
            reliability: spec.network.reliability(),
            progress: spec.progress_config()?,
            threads: spec.run.threads,
        };
        Ok(Box::new(GossipSession::new(cfg, n, task, compute, fabric, churn)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::{BandwidthConfig, LatencyMatrix, LatencyParams};
    use crate::sim::SimRng;

    fn session_with_churn(n: usize, cfg: GossipConfig, churn: ChurnSchedule) -> GossipSession {
        let mut rng = SimRng::new(cfg.seed);
        let max_n = n.max(churn.node_extent());
        let task = MockTask::new(max_n, 16, 0.5, cfg.seed);
        let latency =
            LatencyMatrix::synthetic(&LatencyParams::default(), max_n, &mut rng.fork("lat"));
        let fabric = NetworkFabric::new(
            latency,
            &BandwidthConfig::uniform_mbps(50.0),
            max_n,
            &mut rng.fork("bw"),
        );
        let compute = ComputeModel::uniform(max_n, 0.05);
        GossipSession::new(cfg, n, Box::new(task), compute, fabric, churn)
    }

    fn session(n: usize, cfg: GossipConfig) -> GossipSession {
        session_with_churn(n, cfg, ChurnSchedule::empty())
    }

    #[test]
    fn gossip_advances_and_learns() {
        let cfg = GossipConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(5.0),
            ..Default::default()
        };
        let (m, traffic) = session(8, cfg).run();
        assert!(m.final_round >= 30, "round {}", m.final_round);
        // Epidemic averaging carries residual cross-replica variance, so
        // the bar matches D-SGD's, not MoDeST's.
        assert!(m.best_metric(true).unwrap() > 0.4, "best {:?}", m.best_metric(true));
        assert!(traffic.is_conserved());
        assert!(traffic.total() > 0);
    }

    #[test]
    fn fanout_scales_traffic() {
        let mk = |fanout| GossipConfig {
            fanout,
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 15,
            ..Default::default()
        };
        let (_, t1) = session(10, mk(1)).run();
        let (_, t3) = session(10, mk(3)).run();
        assert!(
            t3.total() > 2 * t1.total(),
            "fanout 3 sent {} vs fanout 1 {}",
            t3.total(),
            t1.total()
        );
    }

    #[test]
    fn survives_crashes_and_joins() {
        use crate::sim::{ChurnEvent, ChurnKind};
        // 10 initial nodes; 3 crash mid-run, 2 fresh ids join later. The
        // epidemic must keep mixing among the survivors and fold the
        // joiners in — gossip used to reject churn scripts outright.
        let churn = ChurnSchedule::new(vec![
            ChurnEvent { at: SimTime::from_secs_f64(20.0), node: 7, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_secs_f64(25.0), node: 8, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_secs_f64(30.0), node: 9, kind: ChurnKind::Leave },
            ChurnEvent { at: SimTime::from_secs_f64(40.0), node: 10, kind: ChurnKind::Join },
            ChurnEvent { at: SimTime::from_secs_f64(60.0), node: 11, kind: ChurnKind::Join },
            ChurnEvent { at: SimTime::from_secs_f64(80.0), node: 8, kind: ChurnKind::Recover },
        ]);
        let cfg = GossipConfig {
            max_time: SimTime::from_secs_f64(400.0),
            max_rounds: 40,
            eval_interval: SimTime::from_secs_f64(10.0),
            ..Default::default()
        };
        let (m, traffic) = session_with_churn(10, cfg, churn).run();
        // Live replicas keep making rounds well past the churn window.
        assert!(m.final_round >= 10, "stalled at round {}", m.final_round);
        let late = m.round_starts.iter().filter(|&(_, t)| t > 100.0).count();
        assert!(late > 0, "no round progress after the churn window");
        assert!(traffic.is_conserved());
        assert!(m.best_metric(true).unwrap() > 0.3);
    }

    #[test]
    fn total_outage_finishes_instead_of_idling_to_max_time() {
        // Every node crashes by t=40 and nothing is scripted to revive:
        // the session must end at the outage, not probe a frozen
        // population for the remaining ~14 virtual minutes.
        let churn = ChurnSchedule::mass_crash(
            6,
            0,
            2,
            SimTime::from_secs_f64(20.0),
            SimTime::from_secs_f64(10.0),
        );
        let cfg = GossipConfig {
            max_time: SimTime::from_secs_f64(900.0),
            max_rounds: 0,
            eval_interval: SimTime::from_secs_f64(10.0),
            ..Default::default()
        };
        let (m, _) = session_with_churn(6, cfg, churn).run();
        assert!(m.duration_s < 60.0, "idled to {}s after total outage", m.duration_s);
    }

    #[test]
    fn builder_rejects_crash_of_never_joining_node() {
        use crate::sim::{ChurnEvent, ChurnKind};
        let mut spec = ScenarioSpec::new("mock", "gossip");
        spec.population.nodes = 10;
        let churn = ChurnSchedule::new(vec![ChurnEvent {
            at: SimTime::from_secs_f64(5.0),
            node: 9_999,
            kind: ChurnKind::Crash,
        }]);
        assert!(GossipBuilder.build(&spec, None, churn).is_err());
    }

    #[test]
    fn churn_session_replays_identically() {
        let mk = || {
            let churn = ChurnSchedule::mass_crash(
                8,
                5,
                1,
                SimTime::from_secs_f64(15.0),
                SimTime::from_secs_f64(10.0),
            );
            let cfg = GossipConfig {
                max_time: SimTime::from_secs_f64(200.0),
                max_rounds: 20,
                ..Default::default()
            };
            session_with_churn(8, cfg, churn).run()
        };
        let (a, ta) = mk();
        let (b, tb) = mk();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
        // The full round-start trace too: the LivenessMirror extraction
        // moved the recorder/monotone-guard logic and must not perturb a
        // single (round, time) pair under crash churn.
        let trace =
            |m: &SessionMetrics| -> Vec<(Round, u64)> {
                m.round_starts.iter().map(|(r, t)| (r, t.to_bits())).collect()
            };
        assert_eq!(trace(&a), trace(&b));
        assert!(!a.round_starts.is_empty());
    }

    #[test]
    fn v2_sampling_session_replays_identically() {
        // The O(k) partial-shuffle stream is deterministic per seed, drives
        // the epidemic to the same round budget as V1, and still learns.
        let mk = |sampling| {
            let cfg = GossipConfig {
                max_time: SimTime::from_secs_f64(600.0),
                max_rounds: 20,
                eval_interval: SimTime::from_secs_f64(10.0),
                sampling,
                ..Default::default()
            };
            session(10, cfg).run()
        };
        let (a, ta) = mk(SamplingVersion::V2Partial);
        let (b, tb) = mk(SamplingVersion::V2Partial);
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
        assert!(a.final_round >= 15, "v2 stalled at round {}", a.final_round);
        assert!(a.best_metric(true).unwrap() > 0.3);
        assert!(ta.is_conserved());
        // Same protocol work under either stream: every node trains to the
        // same budget, so the byte totals match even though the recipients
        // differ draw by draw.
        let (v1, tv1) = mk(SamplingVersion::V1Shuffle);
        assert_eq!(v1.final_round, a.final_round);
        assert_eq!(tv1.total(), ta.total());
    }

    #[test]
    fn v2_churn_session_replays_identically() {
        let mk = || {
            let churn = ChurnSchedule::mass_crash(
                8,
                5,
                1,
                SimTime::from_secs_f64(15.0),
                SimTime::from_secs_f64(10.0),
            );
            let cfg = GossipConfig {
                max_time: SimTime::from_secs_f64(200.0),
                max_rounds: 20,
                sampling: SamplingVersion::V2Partial,
                ..Default::default()
            };
            session_with_churn(8, cfg, churn).run()
        };
        let (a, ta) = mk();
        let (b, tb) = mk();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
    }

    #[test]
    fn offline_online_cycles_do_not_exhaust_the_round_budget() {
        use crate::sim::{ChurnEvent, ChurnKind};
        // Node 3 flaps 25 times with 50ms alive windows — far too short to
        // finish a 300ms training round — all before any peer can burn
        // through the 12-round budget (12 × 300ms = 3.6s at uniform
        // compute). The staleness epoch used to ride on the budgeted round
        // counter, so 25 rejoins alone would blow past `max_rounds` and
        // permanently silence the node; with the per-node seq column the
        // node must resume the round it was in and still complete the full
        // budget.
        let mut events = Vec::new();
        for c in 0..25u64 {
            let crash = 500_000 + 100_000 * c;
            events.push(ChurnEvent {
                at: SimTime::from_micros(crash),
                node: 3,
                kind: ChurnKind::Crash,
            });
            events.push(ChurnEvent {
                at: SimTime::from_micros(crash + 50_000),
                node: 3,
                kind: ChurnKind::Recover,
            });
        }
        let churn = ChurnSchedule::new(events);
        let cfg = GossipConfig {
            max_time: SimTime::from_secs_f64(600.0),
            max_rounds: 12,
            eval_interval: SimTime::from_secs_f64(10.0),
            ..Default::default()
        };
        let session = session_with_churn(6, cfg, churn);
        let (m, _traffic, p) = session.harness.run_into_parts();
        // Every node — the flapper included — completes exactly the
        // 12-round budget; rejoins moved the seq column, not the round.
        for i in 0..6 {
            assert_eq!(p.nodes.round(i), 13, "node {i} round");
        }
        assert_eq!(m.final_round, 13);
        // The flapper's staleness seq advanced on every recover and every
        // dispatch (>= 2 per cycle), decoupled from its 13 rounds.
        assert!(p.nodes.seq(3) > 40, "seq {}", p.nodes.seq(3));
        // The session ends when the flapper finishes its budget (~6.3s
        // virtual), not by idling to max_time.
        assert!(m.duration_s < 60.0, "idled to {}s", m.duration_s);
    }

    #[test]
    fn same_seed_replays_identically() {
        let mk = || GossipConfig {
            max_time: SimTime::from_secs_f64(200.0),
            max_rounds: 20,
            ..Default::default()
        };
        let (a, ta) = session(6, mk()).run();
        let (b, tb) = session(6, mk()).run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
    }
}
