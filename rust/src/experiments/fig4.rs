//! Fig. 4: time and rounds until the FEMNIST model reaches the target
//! accuracy, swept over sample size `s` and aggregator count `a`.

use std::io::Write;

use anyhow::Result;

use crate::config::preset;
use crate::scenario::ProtocolRegistry;
use crate::sim::ChurnSchedule;

use super::common::{run_session, ExpOptions};

/// One sweep point result.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub s: usize,
    pub a: usize,
    pub time_to_target_s: Option<f64>,
    pub rounds_to_target: Option<u64>,
    pub best_metric: f64,
}

pub fn run(
    opts: &ExpOptions,
    dataset: &str,
    s_values: &[usize],
    a_values: &[usize],
    target: Option<f64>,
) -> Result<Vec<SweepPoint>> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let registry = ProtocolRegistry::builtins();
    let runtime = opts.load_runtime()?;
    let p = preset(dataset)?;
    let target = target.unwrap_or(p.target);
    let higher = dataset != "movielens";
    let mut points = Vec::new();
    println!("== Fig. 4: time/rounds to target {target} on {dataset} ==");
    println!(
        "{:>3} {:>3} {:>14} {:>16} {:>10}",
        "s", "a", "time-to-target", "rounds-to-target", "best"
    );
    for &s in s_values {
        for &a in a_values {
            let out = run_session(
                opts,
                &registry,
                runtime.as_ref(),
                dataset,
                "modest",
                ChurnSchedule::empty(),
                |spec| {
                    spec.protocol.s = s;
                    spec.protocol.a = a;
                    spec.run.target_metric = Some(target);
                },
            )?;
            let tt = out.metrics.time_to_target(target, higher);
            let point = SweepPoint {
                s,
                a,
                time_to_target_s: tt.map(|(t, _)| t),
                rounds_to_target: tt.map(|(_, r)| r),
                best_metric: out.metrics.best_metric(higher).unwrap_or(f64::NAN),
            };
            println!(
                "{:>3} {:>3} {:>14} {:>16} {:>10.4}",
                s,
                a,
                point
                    .time_to_target_s
                    .map(|t| format!("{t:.0}s"))
                    .unwrap_or_else(|| "-".into()),
                point
                    .rounds_to_target
                    .map(|r| r.to_string())
                    .unwrap_or_else(|| "-".into()),
                point.best_metric
            );
            points.push(point);
        }
    }
    let path = opts.out_dir.join(format!("fig4_{dataset}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "s,a,time_to_target_s,rounds_to_target,best_metric")?;
    for pt in &points {
        writeln!(
            f,
            "{},{},{},{},{}",
            pt.s,
            pt.a,
            pt.time_to_target_s.map(|t| t.to_string()).unwrap_or_default(),
            pt.rounds_to_target.map(|r| r.to_string()).unwrap_or_default(),
            pt.best_metric
        )?;
    }
    println!("sweep written to {}", path.display());
    Ok(points)
}
