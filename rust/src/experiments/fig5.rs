//! Fig. 5: membership propagation. 90 initial nodes; ten more join at
//! one-minute intervals; we trace how many initial nodes still miss each
//! joiner until every view includes it.

use std::io::Write;

use anyhow::Result;

use crate::metrics::JoinTrace;
use crate::scenario::ProtocolRegistry;
use crate::sim::{ChurnSchedule, SimTime};

use super::common::{run_session, ExpOptions};

pub fn run(opts: &ExpOptions, initial: usize, joiners: u32) -> Result<Vec<JoinTrace>> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let registry = ProtocolRegistry::builtins();
    let runtime = opts.load_runtime()?;
    let churn = ChurnSchedule::staggered_joins(
        initial as u32,
        joiners,
        SimTime::from_secs_f64(60.0),
        SimTime::from_secs_f64(60.0),
    );
    // Paper §4.6: CIFAR10 IID, s=10, a=5, sf=0.9, probing every few seconds.
    let out = run_session(opts, &registry, runtime.as_ref(), "cifar10", "modest", churn, |spec| {
        spec.population.nodes = initial;
        spec.protocol.s = 10;
        spec.protocol.a = 5;
        spec.protocol.sf = 0.9;
        spec.run.eval_interval_s = 5.0;
    })?;

    println!("== Fig. 5: membership propagation after staggered joins ==");
    println!("{:>6} {:>10} {:>16}", "joiner", "join@", "full-propagation");
    for t in &out.metrics.joins {
        println!(
            "{:>6} {:>9.0}s {:>16}",
            t.joiner,
            t.joined_at_s,
            t.full_propagation_s()
                .map(|d| format!("{d:.0}s"))
                .unwrap_or_else(|| "(incomplete)".into())
        );
    }
    let path = opts.out_dir.join("fig5_join_propagation.csv");
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "joiner,joined_at_s,time_s,missing")?;
    for t in &out.metrics.joins {
        for &(time_s, missing) in &t.missing {
            writeln!(f, "{},{},{},{}", t.joiner, t.joined_at_s, time_s, missing)?;
        }
    }
    println!("traces written to {}", path.display());
    Ok(out.metrics.joins)
}
