//! Fig. 3 (and Fig. 1): convergence of FedAvg, D-SGD, and MoDeST on the
//! four learning tasks. Writes one curve CSV per (dataset, protocol) and
//! prints the time-to-target + final-metric summary. The protocol set is
//! any slice of registry names, so `--protocols modest,gossip` sweeps a
//! new protocol with zero experiment edits.

use anyhow::Result;

use crate::config::preset;
use crate::scenario::ProtocolRegistry;
use crate::sim::ChurnSchedule;

use super::common::{run_session, ExpOptions, RunOutput};

pub const ALL_DATASETS: [&str; 4] = ["cifar10", "celeba", "femnist", "movielens"];
/// The paper's three-way comparison, in its plotting order.
pub const ALL_PROTOCOLS: [&str; 3] = ["fedavg", "dsgd", "modest"];

/// Run the full grid (or a subset) and return the outputs.
pub fn run(opts: &ExpOptions, datasets: &[&str], protocols: &[&str]) -> Result<Vec<RunOutput>> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let registry = ProtocolRegistry::builtins();
    let runtime = opts.load_runtime()?;
    let mut outputs = Vec::new();
    println!("== Fig. 3: convergence of FL / DL / MoDeST (scale {:.2}) ==", opts.scale);
    println!(
        "{:<10} {:<9} {:>6} {:>8} {:>10} {:>12} {:>12}",
        "dataset", "protocol", "nodes", "rounds", "best", "target", "t-to-target"
    );
    for &dataset in datasets {
        let p = preset(dataset)?;
        for &protocol in protocols {
            // Round budgets when the caller gave none come from registry
            // metadata: protocols that train every node every round (D-SGD,
            // gossip) declare a smaller cap.
            let budget = registry.get(protocol)?.meta().default_round_budget;
            let out = run_session(
                opts,
                &registry,
                runtime.as_ref(),
                dataset,
                protocol,
                ChurnSchedule::empty(),
                |spec| {
                    if spec.run.max_rounds == 0 {
                        spec.run.max_rounds = budget;
                    }
                    spec.run.max_time_s = spec.run.max_time_s.max(7200.0);
                    spec.run.target_metric = Some(preset(dataset).unwrap().target);
                },
            )?;
            let higher = dataset != "movielens";
            let best = out.metrics.best_metric(higher).unwrap_or(f64::NAN);
            let ttt = out
                .metrics
                .time_to_target(p.target, higher)
                .map(|(t, _)| format!("{:.0}s", t))
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<10} {:<9} {:>6} {:>8} {:>10.4} {:>12.3} {:>12}",
                dataset, out.label, out.nodes, out.metrics.final_round, best, p.target, ttt
            );
            let csv = opts
                .out_dir
                .join(format!("fig3_{}_{}.csv", dataset, out.csv_tag));
            out.metrics.write_curve_csv(&csv)?;
            outputs.push(out);
        }
    }
    println!("curves written to {}/fig3_*.csv", opts.out_dir.display());
    Ok(outputs)
}
