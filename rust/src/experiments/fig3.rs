//! Fig. 3 (and Fig. 1): convergence of FedAvg, D-SGD, and MoDeST on the
//! four learning tasks. Writes one curve CSV per (dataset, algo) and prints
//! the time-to-target + final-metric summary.

use anyhow::Result;

use crate::config::{preset, Algo};
use crate::sim::ChurnSchedule;

use super::common::{algo_label, run_session, ExpOptions, RunOutput};

pub const ALL_DATASETS: [&str; 4] = ["cifar10", "celeba", "femnist", "movielens"];
pub const ALL_ALGOS: [Algo; 3] = [Algo::Fedavg, Algo::Dsgd, Algo::Modest];

/// Run the full grid (or a subset) and return the outputs.
pub fn run(opts: &ExpOptions, datasets: &[&str], algos: &[Algo]) -> Result<Vec<RunOutput>> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let runtime = opts.load_runtime()?;
    let mut outputs = Vec::new();
    println!("== Fig. 3: convergence of FL / DL / MoDeST (scale {:.2}) ==", opts.scale);
    println!(
        "{:<10} {:<8} {:>6} {:>8} {:>10} {:>12} {:>12}",
        "dataset", "algo", "nodes", "rounds", "best", "target", "t-to-target"
    );
    for &dataset in datasets {
        let p = preset(dataset)?;
        for &algo in algos {
            let out = run_session(
                opts,
                runtime.as_ref(),
                dataset,
                algo,
                ChurnSchedule::empty(),
                |spec| {
                    // Round budgets when the caller gave none: D-SGD trains
                    // every node every round, so it gets a smaller cap —
                    // its convergence lag is visible well before 120 rounds.
                    if spec.max_rounds == 0 {
                        spec.max_rounds = if algo == Algo::Dsgd { 120 } else { 200 };
                    }
                    spec.max_time_s = spec.max_time_s.max(7200.0);
                    spec.target_metric = Some(preset(dataset).unwrap().target);
                },
            )?;
            let higher = dataset != "movielens";
            let best = out.metrics.best_metric(higher).unwrap_or(f64::NAN);
            let ttt = out
                .metrics
                .time_to_target(p.target, higher)
                .map(|(t, _)| format!("{:.0}s", t))
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<10} {:<8} {:>6} {:>8} {:>10.4} {:>12.3} {:>12}",
                dataset,
                algo_label(algo),
                out.nodes,
                out.metrics.final_round,
                best,
                p.target,
                ttt
            );
            let csv = opts
                .out_dir
                .join(format!("fig3_{}_{}.csv", dataset, algo_label(algo).to_lowercase()));
            out.metrics.write_curve_csv(&csv)?;
            outputs.push(out);
        }
    }
    println!("curves written to {}/fig3_*.csv", opts.out_dir.display());
    Ok(outputs)
}
