//! Experiment drivers — one per figure/table of the paper's evaluation.
//!
//! | id     | paper artifact                                   | driver |
//! |--------|--------------------------------------------------|--------|
//! | fig1   | Fig. 1: FL vs DL on FEMNIST (slice of fig3)      | [`fig3`] with `--datasets femnist` |
//! | fig3   | Fig. 3a-d: convergence of FedAvg/D-SGD/MoDeST    | [`fig3`] |
//! | table4 | Table 4 (+ Table 1): network usage + overhead    | [`table4`] |
//! | fig4   | Fig. 4: time/rounds-to-accuracy vs `s`, `a`      | [`fig4`] |
//! | fig5   | Fig. 5: membership propagation of joins          | [`fig5`] |
//! | fig6   | Fig. 6: accuracy + sample time under 80% crashes | [`fig6`] |
//!
//! Every driver writes CSVs under `results/` and prints a paper-shaped
//! summary to stdout. `--scale` shrinks node counts for CI-speed runs;
//! EXPERIMENTS.md records which scale produced the recorded numbers.

pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table4;

pub use common::{run_session, ExpOptions, RunOutput};
