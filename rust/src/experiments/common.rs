//! Shared experiment plumbing: run a (dataset, algo) session, collect
//! metrics + traffic, write CSVs.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{Algo, SessionSpec};
use crate::metrics::SessionMetrics;
use crate::net::TrafficLedger;
use crate::runtime::XlaRuntime;
use crate::sim::ChurnSchedule;

/// Common experiment options (from the CLI).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Node-count scale vs the paper (1.0 = full size).
    pub scale: f64,
    /// Virtual-time budget per session (seconds).
    pub max_time_s: f64,
    /// Round budget (0 = unlimited).
    pub max_rounds: u64,
    pub seed: u64,
    /// Median per-node capacity in Mbit/s (builds the network fabric).
    pub bandwidth_mbps: f64,
    /// Per-node capacity heterogeneity (lognormal sigma, 0 = uniform).
    pub bandwidth_sigma: f64,
    pub artifacts_dir: String,
    pub out_dir: PathBuf,
    /// Use the mock task instead of XLA (fast smoke runs).
    pub mock: bool,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.25,
            max_time_s: 1200.0,
            max_rounds: 0,
            seed: 42,
            bandwidth_mbps: 50.0,
            bandwidth_sigma: 0.0,
            artifacts_dir: "artifacts".into(),
            out_dir: PathBuf::from("results"),
            mock: false,
        }
    }
}

impl ExpOptions {
    pub fn spec(&self, dataset: &str, algo: Algo) -> SessionSpec {
        SessionSpec {
            dataset: if self.mock { "mock".into() } else { dataset.into() },
            algo,
            scale: self.scale,
            max_time_s: self.max_time_s,
            max_rounds: self.max_rounds,
            seed: self.seed,
            bandwidth_mbps: self.bandwidth_mbps,
            bandwidth_sigma: self.bandwidth_sigma,
            artifacts_dir: self.artifacts_dir.clone(),
            ..Default::default()
        }
    }

    pub fn load_runtime(&self) -> Result<Option<XlaRuntime>> {
        if self.mock {
            Ok(None)
        } else {
            Ok(Some(XlaRuntime::load(&self.artifacts_dir)?))
        }
    }
}

/// The result of one session run.
pub struct RunOutput {
    pub metrics: SessionMetrics,
    pub traffic: TrafficLedger,
    pub nodes: usize,
    pub algo: Algo,
    pub dataset: String,
}

/// Run one session for (dataset, algo) under shared options.
pub fn run_session(
    opts: &ExpOptions,
    runtime: Option<&XlaRuntime>,
    dataset: &str,
    algo: Algo,
    churn: ChurnSchedule,
    tweak: impl FnOnce(&mut SessionSpec),
) -> Result<RunOutput> {
    let mut spec = opts.spec(dataset, algo);
    tweak(&mut spec);
    let nodes = spec.resolved_nodes()?;
    let (metrics, traffic) = match algo {
        Algo::Dsgd => spec.build_dsgd(runtime)?.run(),
        _ => spec.build_modest(runtime, churn)?.run(),
    };
    Ok(RunOutput { metrics, traffic, nodes, algo, dataset: dataset.to_string() })
}

/// `algo` label as the paper prints it.
pub fn algo_label(algo: Algo) -> &'static str {
    match algo {
        Algo::Modest => "MoDeST",
        Algo::Fedavg => "FedAvg",
        Algo::Dsgd => "D-SGD",
    }
}
