//! Shared experiment plumbing: run a (dataset, protocol) session through
//! the scenario registry, collect metrics + traffic, write CSVs.

use std::path::PathBuf;

use anyhow::Result;

use crate::metrics::SessionMetrics;
use crate::net::TrafficLedger;
use crate::runtime::XlaRuntime;
use crate::scenario::{ProtocolRegistry, ScenarioSpec};
use crate::sim::{ChurnSchedule, SamplingVersion};

/// Common experiment options (from the CLI).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Node-count scale vs the paper (1.0 = full size).
    pub scale: f64,
    /// Virtual-time budget per session (seconds).
    pub max_time_s: f64,
    /// Round budget (0 = unlimited).
    pub max_rounds: u64,
    pub seed: u64,
    /// Median per-node capacity in Mbit/s (builds the network fabric).
    pub bandwidth_mbps: f64,
    /// Per-node capacity heterogeneity (lognormal sigma, 0 = uniform).
    pub bandwidth_sigma: f64,
    pub artifacts_dir: String,
    pub out_dir: PathBuf,
    /// Use the mock task instead of XLA (fast smoke runs).
    pub mock: bool,
    /// Peer-sampling stream version for every session of the experiment.
    pub sampling: SamplingVersion,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            scale: 0.25,
            max_time_s: 1200.0,
            max_rounds: 0,
            seed: 42,
            bandwidth_mbps: 50.0,
            bandwidth_sigma: 0.0,
            artifacts_dir: "artifacts".into(),
            out_dir: PathBuf::from("results"),
            mock: false,
            sampling: SamplingVersion::default(),
        }
    }
}

impl ExpOptions {
    /// The scenario these options describe for one (dataset, protocol).
    pub fn scenario(&self, dataset: &str, protocol: &str) -> ScenarioSpec {
        let mut spec =
            ScenarioSpec::new(if self.mock { "mock" } else { dataset }, protocol);
        spec.workload.artifacts_dir = self.artifacts_dir.clone();
        spec.population.scale = self.scale;
        spec.network.bandwidth_mbps = self.bandwidth_mbps;
        spec.network.bandwidth_sigma = self.bandwidth_sigma;
        spec.run.max_time_s = self.max_time_s;
        spec.run.max_rounds = self.max_rounds;
        spec.run.seed = self.seed;
        spec.run.sampling = self.sampling;
        spec
    }

    pub fn load_runtime(&self) -> Result<Option<XlaRuntime>> {
        if self.mock {
            Ok(None)
        } else {
            Ok(Some(XlaRuntime::load(&self.artifacts_dir)?))
        }
    }
}

/// The result of one session run.
pub struct RunOutput {
    pub metrics: SessionMetrics,
    pub traffic: TrafficLedger,
    pub nodes: usize,
    /// Canonical registry name of the protocol that ran.
    pub protocol: String,
    /// Paper-style label from registry metadata (drives table rows — no
    /// hardcoded match anywhere).
    pub label: &'static str,
    /// CSV/file-name tag, from [`crate::scenario::ProtocolMeta::csv_tag`].
    pub csv_tag: String,
    pub dataset: String,
}

/// Run one session for (dataset, protocol) under shared options.
pub fn run_session(
    opts: &ExpOptions,
    registry: &ProtocolRegistry,
    runtime: Option<&XlaRuntime>,
    dataset: &str,
    protocol: &str,
    churn: ChurnSchedule,
    tweak: impl FnOnce(&mut ScenarioSpec),
) -> Result<RunOutput> {
    let meta = registry.get(protocol)?.meta();
    let mut spec = opts.scenario(dataset, meta.name);
    tweak(&mut spec);
    let nodes = spec.resolved_nodes()?;
    let (metrics, traffic) = registry.build(&spec, runtime, churn)?.run();
    Ok(RunOutput {
        metrics,
        traffic,
        nodes,
        protocol: meta.name.to_string(),
        label: meta.label,
        csv_tag: meta.csv_tag(),
        dataset: dataset.to_string(),
    })
}
