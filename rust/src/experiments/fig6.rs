//! Fig. 6: resilience to unresponsive nodes. Two scenarios over CIFAR10:
//! "reliable" (only 20 of 100 nodes ever participate) and "crashing"
//! (crash 5 nodes/minute from minute 5 until 80% are gone). Reports the
//! accuracy curve and the sample-time series.

use anyhow::Result;

use crate::metrics::SessionMetrics;
use crate::scenario::ProtocolRegistry;
use crate::sim::{ChurnSchedule, SimTime};

use super::common::{run_session, ExpOptions};

pub struct Fig6Output {
    pub reliable: SessionMetrics,
    pub crashing: SessionMetrics,
}

pub fn run(opts: &ExpOptions, nodes: usize) -> Result<Fig6Output> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let registry = ProtocolRegistry::builtins();
    let runtime = opts.load_runtime()?;
    let survivors = (nodes / 5).max(4); // 20% survive
    let per_min = (nodes / 20).max(1); // 5/min at n=100

    // Scenario A: only `survivors` nodes exist from the start ("reliable").
    let reliable = run_session(
        opts,
        &registry,
        runtime.as_ref(),
        "cifar10",
        "modest",
        ChurnSchedule::empty(),
        |spec| {
            spec.population.nodes = survivors;
            spec.protocol.s = 10.min(survivors);
            spec.protocol.a = 5.min(survivors);
            spec.protocol.sf = 0.9;
            spec.protocol.dt_s = 2.0;
            spec.protocol.dk = 20;
            spec.run.eval_interval_s = 10.0;
        },
    )?;

    // Scenario B: all `nodes` start, then mass crash (paper §4.7).
    let churn = ChurnSchedule::mass_crash(
        nodes as u32,
        survivors as u32,
        per_min as u32,
        SimTime::from_secs_f64(300.0),
        SimTime::from_secs_f64(60.0),
    );
    let crashing =
        run_session(opts, &registry, runtime.as_ref(), "cifar10", "modest", churn, |spec| {
            spec.population.nodes = nodes;
            spec.protocol.s = 10.min(survivors);
            spec.protocol.a = 5.min(survivors);
            spec.protocol.sf = 0.9;
            spec.protocol.dt_s = 2.0;
            spec.protocol.dk = 20;
            spec.run.eval_interval_s = 10.0;
        })?;

    println!("== Fig. 6: crash resilience (n={nodes}, survivors={survivors}) ==");
    for (name, m) in [("reliable", &reliable.metrics), ("crashing", &crashing.metrics)] {
        let best = m.best_metric(true).unwrap_or(f64::NAN);
        let mean_sample: f64 = if m.samples.is_empty() {
            f64::NAN
        } else {
            m.samples.iter().map(|s| s.duration_s).sum::<f64>() / m.samples.len() as f64
        };
        let max_sample = m
            .samples
            .iter()
            .map(|s| s.duration_s)
            .fold(0.0f64, f64::max);
        println!(
            "{name:<9} rounds={:<5} best-acc={best:.4} mean-sample={mean_sample:.3}s max-sample={max_sample:.3}s",
            m.final_round
        );
        m.write_curve_csv(&opts.out_dir.join(format!("fig6_{name}_curve.csv")))?;
        m.write_samples_csv(&opts.out_dir.join(format!("fig6_{name}_samples.csv")))?;
    }
    println!("curves + sample times written to {}/fig6_*.csv", opts.out_dir.display());
    Ok(Fig6Output { reliable: reliable.metrics, crashing: crashing.metrics })
}
