//! Table 4 (and Table 1 = its FEMNIST rows): total / min / max per-node
//! network usage for D-SGD, FedAvg and MoDeST, plus the MoDeST overhead
//! row. Reuses the Fig. 3 grid runs; labels come from registry metadata.

use anyhow::Result;

use crate::net::traffic::fmt_bytes;

use super::common::{ExpOptions, RunOutput};
use super::fig3;

pub fn run(opts: &ExpOptions, datasets: &[&str]) -> Result<Vec<RunOutput>> {
    let outputs = fig3::run(opts, datasets, &fig3::ALL_PROTOCOLS)?;
    print_from(&outputs);
    Ok(outputs)
}

/// Print the paper-format table from already-run sessions.
pub fn print_from(outputs: &[RunOutput]) {
    println!();
    println!("== Table 4 (top): total / min / max network usage per node ==");
    println!(
        "{:<10} {:<9} {:>12} {:>12} {:>12}",
        "dataset", "method", "total", "min", "max"
    );
    for out in outputs {
        let t = &out.metrics.traffic;
        println!(
            "{:<10} {:<9} {:>12} {:>12} {:>12}",
            out.dataset,
            out.label,
            fmt_bytes(t.total),
            fmt_bytes(t.min_node),
            fmt_bytes(t.max_node)
        );
    }
    println!();
    println!("== Table 4 (bottom): MoDeST overhead beyond model transfers ==");
    println!("{:<10} {:>14} {:>8}", "dataset", "overhead", "frac");
    for out in outputs.iter().filter(|o| o.protocol == "modest") {
        let t = &out.metrics.traffic;
        println!(
            "{:<10} {:>14} {:>7.1}%",
            out.dataset,
            fmt_bytes(t.overhead),
            100.0 * t.overhead_fraction
        );
    }
    // Headline ratios the paper calls out in §4.4.
    println!();
    for dataset in outputs
        .iter()
        .map(|o| o.dataset.clone())
        .collect::<std::collections::BTreeSet<_>>()
    {
        let get = |p: &str| {
            outputs
                .iter()
                .find(|o| o.dataset == dataset && o.protocol == p)
                .map(|o| o.metrics.traffic.total.max(1))
        };
        if let (Some(dl), Some(fl), Some(md)) = (get("dsgd"), get("fedavg"), get("modest")) {
            println!(
                "{dataset}: D-SGD/FedAvg = {:.1}x, D-SGD/MoDeST = {:.1}x, MoDeST/FedAvg = {:.1}x",
                dl as f64 / fl as f64,
                dl as f64 / md as f64,
                md as f64 / fl as f64
            );
        }
    }
}
