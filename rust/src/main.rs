//! `repro` — the MoDeST launcher.
//!
//! ```text
//! repro run --config examples/scenarios/fcc_tiers.json
//! repro run --protocol gossip --mock --max-time 120
//! repro exp fig3 --datasets femnist --scale 0.2
//! repro exp table4 --scale 0.2
//! repro exp fig4 --s 1,2,4 --a 1,3
//! repro exp fig5 --initial 90 --joiners 10
//! repro exp fig6 --nodes 100
//! repro protocols
//! repro info
//! ```
//!
//! Common flags: `--scale`, `--max-time`, `--max-rounds`, `--seed`,
//! `--artifacts`, `--out`, `--mock` (protocol-only runs without artifacts),
//! `--config file.json` (a [`ScenarioSpec`] body — nested sections or the
//! legacy flat keys; explicit CLI flags override the file).

use std::path::PathBuf;

use anyhow::{bail, Result};

use modest_dl::experiments::{self, ExpOptions};
use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::scenario::{ProgressSpec, ProtocolRegistry, ScenarioSpec};
use modest_dl::sim::{ChurnSchedule, SamplingVersion};
use modest_dl::util::cli::Args;

const USAGE: &str = "\
repro — MoDeST: decentralized learning with client sampling

USAGE:
  repro run   [--config scenario.json] [--protocol NAME] [--dataset D]
              [--s N] [--a N] [--sf F] [--nodes N]
              [--checkpoint-at S --checkpoint-out FILE]
              [--progress-every S [--progress-out FILE]] [common flags]
              (`repro train ...` is an alias)
  repro resume --snapshot FILE [--config overlay.json] [--fork LABEL]
              [--out DIR]  (what-if branching: the overlay is a partial
              scenario JSON merged over the spec embedded in the snapshot)
  repro exp fig3   [--datasets cifar10,celeba,femnist,movielens]
                   [--protocols fedavg,dsgd,modest] [common]
  repro exp table4 [--datasets ...] [common]
  repro exp fig4   [--dataset femnist] [--s 1,2,4,7] [--a 1,3,5]
                   [--target F] [common]
  repro exp fig5   [--initial 90] [--joiners 10] [common]
  repro exp fig6   [--nodes 100] [common]
  repro protocols  (list registered protocols + metadata)
  repro info [--artifacts DIR]

COMMON FLAGS:
  --scale F        node-count scale vs the paper (default 0.25)
  --max-time S     virtual-time budget per session (default 1200)
  --max-rounds N   round budget, 0 = unlimited (default 0)
  --seed N         session seed (default 42)
  --bw-mbps F      median per-node capacity in Mbit/s (default 50)
  --bw-sigma F     capacity heterogeneity, lognormal sigma (default 0)
  --sampling V     peer-sampling stream: v1 (frozen full shuffle, default)
                   or v2 (O(k) partial shuffle for 100k-node sessions)
  --threads N      event-queue execution threads (default 1); N > 1 shards
                   the queue across N workers, bit-identical to N = 1
  --artifacts DIR  AOT artifact dir (default artifacts)
  --out DIR        CSV output dir (default results)
  --mock           use the mock task (no artifacts needed)
";

fn common(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        scale: args.get_f64("scale", 0.25)?,
        max_time_s: args.get_f64("max-time", 1200.0)?,
        max_rounds: args.get_u64("max-rounds", 0)?,
        seed: args.get_u64("seed", 42)?,
        bandwidth_mbps: args.get_f64("bw-mbps", 50.0)?,
        bandwidth_sigma: args.get_f64("bw-sigma", 0.0)?,
        artifacts_dir: args.get_str("artifacts", "artifacts"),
        out_dir: PathBuf::from(args.get_str("out", "results")),
        mock: args.get_bool("mock"),
        sampling: match args.get_opt("sampling") {
            Some(v) => SamplingVersion::parse(&v)?,
            None => SamplingVersion::default(),
        },
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let opts = common(args)?;
    let from_config = args.get_opt("config").is_some();
    let mut spec = match args.get_opt("config") {
        Some(path) => {
            let mut s = ScenarioSpec::from_json(&std::fs::read_to_string(&path)?)?;
            // Relative trace paths (bandwidth and availability) resolve
            // against the config file's directory, so scenario presets
            // work from any cwd.
            let resolve = |tf: &str| -> Option<String> {
                let tf_path = std::path::Path::new(tf);
                if tf_path.is_relative() {
                    std::path::Path::new(&path)
                        .parent()
                        .map(|dir| dir.join(tf_path).to_string_lossy().into_owned())
                } else {
                    None
                }
            };
            if let Some(resolved) = s.network.trace_file.as_deref().and_then(resolve) {
                s.network.trace_file = Some(resolved);
            }
            if let Some(av) = &mut s.population.availability {
                if let Some(resolved) = av.trace_file.as_deref().and_then(resolve) {
                    av.trace_file = Some(resolved);
                }
            }
            // `run.progress.out` gets the same treatment: a preset that
            // streams next to itself works from any cwd.
            if let Some(p) = &mut s.run.progress {
                if let Some(resolved) = p.out.as_deref().and_then(resolve) {
                    p.out = Some(resolved);
                }
            }
            s
        }
        None => ScenarioSpec::default(),
    };

    // A config file is authoritative; explicit flags override it. Without
    // one, the common-flag defaults apply as before. Every flag is
    // consumed up front so `reject_unknown` never trips over one that a
    // conditional branch happened to skip (e.g. `--mock --dataset X`).
    let dataset_flag = args.get_opt("dataset");
    let protocol_flag = args.get_opt("protocol");
    let algo_flag = args.get_opt("algo");
    if opts.mock {
        spec.workload.dataset = "mock".into();
    } else if let Some(d) = dataset_flag {
        spec.workload.dataset = d;
    }
    if let Some(p) = protocol_flag.or(algo_flag) {
        spec.protocol.name = p;
    }
    let flag_or_no_config = |key: &str| args.get_opt(key).is_some() || !from_config;
    if flag_or_no_config("scale") {
        spec.population.scale = opts.scale;
    }
    if flag_or_no_config("max-time") {
        spec.run.max_time_s = opts.max_time_s;
    }
    if flag_or_no_config("max-rounds") {
        spec.run.max_rounds = opts.max_rounds;
    }
    if flag_or_no_config("seed") {
        spec.run.seed = opts.seed;
    }
    if flag_or_no_config("artifacts") {
        spec.workload.artifacts_dir = opts.artifacts_dir.clone();
    }
    // Bandwidth flags only when explicit — a config's `network` section
    // (classes/trace) must survive when the flags are absent. When one IS
    // passed, it must actually take effect, so the higher-precedence
    // classes/trace modes are cleared rather than silently winning.
    let bw_flagged =
        args.get_opt("bw-mbps").is_some() || args.get_opt("bw-sigma").is_some();
    if bw_flagged {
        spec.network.classes.clear();
        spec.network.trace_file = None;
    }
    if args.get_opt("bw-mbps").is_some() {
        spec.network.bandwidth_mbps = opts.bandwidth_mbps;
    }
    if args.get_opt("bw-sigma").is_some() {
        spec.network.bandwidth_sigma = opts.bandwidth_sigma;
    }
    let s = args.get_usize("s", 0)?;
    if s > 0 {
        spec.protocol.s = s;
    }
    let a = args.get_usize("a", 0)?;
    if a > 0 {
        spec.protocol.a = a;
    }
    spec.protocol.sf = args.get_f64("sf", spec.protocol.sf)?;
    let nodes = args.get_usize("nodes", 0)?;
    if nodes > 0 {
        spec.population.nodes = nodes;
    }
    if let Some(v) = args.get_opt("sampling") {
        spec.run.sampling = SamplingVersion::parse(&v)?;
    }
    if let Some(t) = args.get_opt("threads") {
        let threads = t
            .parse::<usize>()
            .map_err(|e| anyhow::anyhow!("--threads {t:?}: {e}"))?;
        if threads == 0 {
            bail!("--threads must be >= 1 (got 0)");
        }
        spec.run.threads = threads;
    }
    if let Some(t) = args.get_opt("checkpoint-at") {
        spec.run.checkpoint_at_s = Some(
            t.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("--checkpoint-at {t:?}: {e}"))?,
        );
    }
    if let Some(p) = args.get_opt("checkpoint-out") {
        spec.run.checkpoint_out = Some(p);
    }
    if spec.run.checkpoint_at_s.is_some() != spec.run.checkpoint_out.is_some() {
        bail!("--checkpoint-at and --checkpoint-out must be given together");
    }
    // Live progress stream. `--progress-every` alone streams to stderr;
    // `--progress-out` redirects to a file (cwd-relative, unlike a
    // config's `run.progress.out` which resolves against the config dir).
    let progress_out = args.get_opt("progress-out");
    if let Some(e) = args.get_opt("progress-every") {
        let every_s = e
            .parse::<f64>()
            .map_err(|err| anyhow::anyhow!("--progress-every {e:?}: {err}"))?;
        spec.run.progress = Some(ProgressSpec { every_s, out: progress_out });
    } else if progress_out.is_some() {
        bail!("--progress-out requires --progress-every");
    }
    args.reject_unknown()?;

    let registry = ProtocolRegistry::builtins();
    let meta = registry.get(&spec.protocol.name)?.meta();
    let runtime = if spec.workload.dataset == "mock" {
        None
    } else {
        Some(XlaRuntime::load(&spec.workload.artifacts_dir)?)
    };
    let n = spec.resolved_nodes()?;
    println!(
        "running {} with {} on {} nodes (s={}, a={}, sf={}, sampling={})",
        spec.workload.dataset,
        meta.label,
        n,
        spec.resolved_s()?,
        spec.resolved_a()?,
        spec.protocol.sf,
        spec.run.sampling.as_str()
    );
    let session = registry.build(&spec, runtime.as_ref(), ChurnSchedule::empty())?;
    let (metrics, traffic) = session.run();
    println!(
        "finished: round {} after {:.0}s virtual, {} DES events",
        metrics.final_round, metrics.duration_s, metrics.events
    );
    if let Some(out) = &spec.run.checkpoint_out {
        match std::fs::metadata(out) {
            Ok(meta) => println!("checkpoint written to {out} ({} bytes)", meta.len()),
            Err(e) => bail!("checkpoint was requested but {out} is missing: {e}"),
        }
    }
    let tail: Vec<_> = metrics.curve.iter().rev().take(5).collect();
    for p in tail.iter().rev() {
        println!(
            "  t={:>7.0}s round={:>5} metric={:.4} loss={:.4}",
            p.time_s, p.round, p.metric, p.loss
        );
    }
    let t = &metrics.traffic;
    println!(
        "traffic: total={} min={} max={} overhead={:.1}% conserved={}",
        fmt_bytes(t.total),
        fmt_bytes(t.min_node),
        fmt_bytes(t.max_node),
        100.0 * t.overhead_fraction,
        traffic.is_conserved()
    );
    std::fs::create_dir_all(&opts.out_dir)?;
    let csv = opts
        .out_dir
        .join(format!("run_{}_{}.csv", spec.workload.dataset, meta.csv_tag()));
    metrics.write_curve_csv(&csv)?;
    println!("curve written to {}", csv.display());
    Ok(())
}

fn cmd_resume(args: &Args) -> Result<()> {
    let snap_path = args
        .get_opt("snapshot")
        .ok_or_else(|| anyhow::anyhow!("resume needs --snapshot FILE\n{USAGE}"))?;
    let overlay = match args.get_opt("config") {
        Some(p) => Some(std::fs::read_to_string(&p)?),
        None => None,
    };
    let fork = args.get_opt("fork");
    let out_dir = PathBuf::from(args.get_str("out", "results"));
    args.reject_unknown()?;

    let bytes = std::fs::read(&snap_path)?;
    // The overlay may not change the workload (the snapshot's model state
    // is dataset-shaped), so the embedded spec decides the runtime.
    let preview = modest_dl::scenario::embedded_spec(&bytes)?;
    let runtime = if preview.workload.dataset == "mock" {
        None
    } else {
        Some(XlaRuntime::load(&preview.workload.artifacts_dir)?)
    };
    let (spec, session) =
        modest_dl::scenario::resume_session(&bytes, overlay.as_deref(), fork, runtime.as_ref())?;
    let registry = ProtocolRegistry::builtins();
    let meta = registry.get(&spec.protocol.name)?.meta();
    println!(
        "resuming {} with {} from {snap_path} ({} bytes)",
        spec.workload.dataset,
        meta.label,
        bytes.len()
    );
    let (metrics, traffic) = session.run();
    println!(
        "finished: round {} after {:.0}s virtual, {} DES events",
        metrics.final_round, metrics.duration_s, metrics.events
    );
    let t = &metrics.traffic;
    println!(
        "traffic: total={} min={} max={} overhead={:.1}% conserved={}",
        fmt_bytes(t.total),
        fmt_bytes(t.min_node),
        fmt_bytes(t.max_node),
        100.0 * t.overhead_fraction,
        traffic.is_conserved()
    );
    std::fs::create_dir_all(&out_dir)?;
    let csv =
        out_dir.join(format!("resume_{}_{}.csv", spec.workload.dataset, meta.csv_tag()));
    metrics.write_curve_csv(&csv)?;
    println!("curve written to {}", csv.display());
    Ok(())
}

fn cmd_protocols(args: &Args) -> Result<()> {
    args.reject_unknown()?;
    let registry = ProtocolRegistry::builtins();
    println!("registered protocols:");
    for meta in registry.metas() {
        let aliases = if meta.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", meta.aliases.join(", "))
        };
        let params = if meta.default_params.is_empty() {
            String::new()
        } else {
            let kv: Vec<String> = meta
                .default_params
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            format!(" [params: {}]", kv.join(", "))
        };
        println!("  {:<8} {}{aliases}{params}", meta.name, meta.label);
        println!("           {}", meta.summary);
    }
    Ok(())
}

fn cmd_exp(which: &str, args: &Args) -> Result<()> {
    let opts = common(args)?;
    match which {
        "fig1" | "fig3" => {
            let default = if which == "fig1" {
                "femnist".to_string()
            } else {
                "cifar10,celeba,femnist,movielens".to_string()
            };
            let ds = args.get_list("datasets", &default);
            let ps = args.get_list(
                "protocols",
                &experiments::fig3::ALL_PROTOCOLS.join(","),
            );
            args.reject_unknown()?;
            let dref: Vec<&str> = ds.iter().map(|s| s.as_str()).collect();
            let pref: Vec<&str> = ps.iter().map(|s| s.as_str()).collect();
            experiments::fig3::run(&opts, &dref, &pref)?;
        }
        "table1" | "table4" => {
            let default = if which == "table1" {
                "femnist".to_string()
            } else {
                "cifar10,celeba,femnist,movielens".to_string()
            };
            let ds = args.get_list("datasets", &default);
            args.reject_unknown()?;
            let refs: Vec<&str> = ds.iter().map(|s| s.as_str()).collect();
            experiments::table4::run(&opts, &refs)?;
        }
        "fig4" => {
            let dataset = args.get_str("dataset", "femnist");
            let sv = args.get_usize_list("s", "1,2,4,7")?;
            let av = args.get_usize_list("a", "1,3,5")?;
            let target = match args.get_opt("target") {
                Some(t) => Some(t.parse::<f64>()?),
                None => None,
            };
            args.reject_unknown()?;
            experiments::fig4::run(&opts, &dataset, &sv, &av, target)?;
        }
        "fig5" => {
            let initial = args.get_usize("initial", 90)?;
            let joiners = args.get_u64("joiners", 10)? as u32;
            args.reject_unknown()?;
            experiments::fig5::run(&opts, initial, joiners)?;
        }
        "fig6" => {
            let nodes = args.get_usize("nodes", 100)?;
            args.reject_unknown()?;
            experiments::fig6::run(&opts, nodes)?;
        }
        other => bail!("unknown experiment {other:?} (fig1|fig3|table1|table4|fig4|fig5|fig6)"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.positionals.first().map(|s| s.as_str()) {
        // `train` kept as an alias for the pre-scenario CLI.
        Some("run") | Some("train") => cmd_run(&args),
        Some("resume") => cmd_resume(&args),
        Some("exp") => {
            let which = args
                .positionals
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs a figure/table id\n{USAGE}"))?
                .clone();
            cmd_exp(&which, &args)
        }
        Some("protocols") => cmd_protocols(&args),
        Some("info") => {
            let dir = args.get_str("artifacts", "artifacts");
            args.reject_unknown()?;
            let rt = XlaRuntime::load(&dir)?;
            let m = rt.manifest();
            println!("artifact manifest (seed {}):", m.seed);
            for (name, v) in &m.variants {
                println!(
                    "  {name:<12} kind={:<10} params={:>9} ({:>8} bytes) smax={} lr={} mu={} paper-nodes={}",
                    v.kind, v.param_count, v.model_bytes, v.smax, v.lr, v.momentum, v.nodes
                );
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
