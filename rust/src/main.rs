//! `repro` — the MoDeST launcher.
//!
//! ```text
//! repro train --dataset cifar10 --algo modest --scale 0.25
//! repro exp fig3 --datasets femnist --scale 0.2
//! repro exp table4 --scale 0.2
//! repro exp fig4 --s 1,2,4 --a 1,3
//! repro exp fig5 --initial 90 --joiners 10
//! repro exp fig6 --nodes 100
//! repro info
//! ```
//!
//! Common flags: `--scale`, `--max-time`, `--max-rounds`, `--seed`,
//! `--artifacts`, `--out`, `--mock` (protocol-only runs without artifacts),
//! `--config file.json` (a [`SessionSpec`] JSON body; CLI flags override).

use std::path::PathBuf;

use anyhow::{bail, Result};

use modest_dl::config::{Algo, SessionSpec};
use modest_dl::experiments::{self, ExpOptions};
use modest_dl::net::traffic::fmt_bytes;
use modest_dl::runtime::XlaRuntime;
use modest_dl::sim::ChurnSchedule;
use modest_dl::util::cli::Args;

const USAGE: &str = "\
repro — MoDeST: decentralized learning with client sampling

USAGE:
  repro train [--dataset D] [--algo modest|fedavg|dsgd] [--s N] [--a N]
              [--sf F] [--nodes N] [--config spec.json] [common flags]
  repro exp fig3   [--datasets cifar10,celeba,femnist,movielens] [common]
  repro exp table4 [--datasets ...] [common]
  repro exp fig4   [--dataset femnist] [--s 1,2,4,7] [--a 1,3,5]
                   [--target F] [common]
  repro exp fig5   [--initial 90] [--joiners 10] [common]
  repro exp fig6   [--nodes 100] [common]
  repro info [--artifacts DIR]

COMMON FLAGS:
  --scale F        node-count scale vs the paper (default 0.25)
  --max-time S     virtual-time budget per session (default 1200)
  --max-rounds N   round budget, 0 = unlimited (default 0)
  --seed N         session seed (default 42)
  --bw-mbps F      median per-node capacity in Mbit/s (default 50)
  --bw-sigma F     capacity heterogeneity, lognormal sigma (default 0)
  --artifacts DIR  AOT artifact dir (default artifacts)
  --out DIR        CSV output dir (default results)
  --mock           use the mock task (no artifacts needed)
";

fn common(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        scale: args.get_f64("scale", 0.25)?,
        max_time_s: args.get_f64("max-time", 1200.0)?,
        max_rounds: args.get_u64("max-rounds", 0)?,
        seed: args.get_u64("seed", 42)?,
        bandwidth_mbps: args.get_f64("bw-mbps", 50.0)?,
        bandwidth_sigma: args.get_f64("bw-sigma", 0.0)?,
        artifacts_dir: args.get_str("artifacts", "artifacts"),
        out_dir: PathBuf::from(args.get_str("out", "results")),
        mock: args.get_bool("mock"),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let opts = common(args)?;
    let mut spec = match args.get_opt("config") {
        Some(path) => SessionSpec::from_json(&std::fs::read_to_string(path)?)?,
        None => SessionSpec::default(),
    };
    spec.dataset = if opts.mock {
        "mock".into()
    } else {
        args.get_str("dataset", &spec.dataset.clone())
    };
    spec.algo = args.get_str("algo", "modest").parse()?;
    spec.scale = opts.scale;
    spec.max_time_s = opts.max_time_s;
    spec.max_rounds = opts.max_rounds;
    spec.seed = opts.seed;
    // Only explicit flags override bandwidth — a --config file's
    // bandwidth_mbps/bandwidth_sigma must survive when the flags are absent.
    if args.get_opt("bw-mbps").is_some() {
        spec.bandwidth_mbps = opts.bandwidth_mbps;
    }
    if args.get_opt("bw-sigma").is_some() {
        spec.bandwidth_sigma = opts.bandwidth_sigma;
    }
    spec.artifacts_dir = opts.artifacts_dir.clone();
    let s = args.get_usize("s", 0)?;
    if s > 0 {
        spec.s = s;
    }
    let a = args.get_usize("a", 0)?;
    if a > 0 {
        spec.a = a;
    }
    spec.sf = args.get_f64("sf", spec.sf)?;
    let nodes = args.get_usize("nodes", 0)?;
    if nodes > 0 {
        spec.nodes = nodes;
    }
    args.reject_unknown()?;

    let runtime =
        if opts.mock { None } else { Some(XlaRuntime::load(&opts.artifacts_dir)?) };
    let n = spec.resolved_nodes()?;
    println!(
        "training {} with {:?} on {} nodes (s={}, a={}, sf={})",
        spec.dataset,
        spec.algo,
        n,
        spec.resolved_s()?,
        spec.resolved_a()?,
        spec.sf
    );
    let (metrics, traffic) = match spec.algo {
        Algo::Dsgd => spec.build_dsgd(runtime.as_ref())?.run(),
        _ => spec.build_modest(runtime.as_ref(), ChurnSchedule::empty())?.run(),
    };
    println!(
        "finished: round {} after {:.0}s virtual, {} DES events",
        metrics.final_round, metrics.duration_s, metrics.events
    );
    let tail: Vec<_> = metrics.curve.iter().rev().take(5).collect();
    for p in tail.iter().rev() {
        println!(
            "  t={:>7.0}s round={:>5} metric={:.4} loss={:.4}",
            p.time_s, p.round, p.metric, p.loss
        );
    }
    let t = &metrics.traffic;
    println!(
        "traffic: total={} min={} max={} overhead={:.1}% conserved={}",
        fmt_bytes(t.total),
        fmt_bytes(t.min_node),
        fmt_bytes(t.max_node),
        100.0 * t.overhead_fraction,
        traffic.is_conserved()
    );
    std::fs::create_dir_all(&opts.out_dir)?;
    let csv = opts.out_dir.join(format!("train_{}_{:?}.csv", spec.dataset, spec.algo));
    metrics.write_curve_csv(&csv)?;
    println!("curve written to {}", csv.display());
    Ok(())
}

fn cmd_exp(which: &str, args: &Args) -> Result<()> {
    let opts = common(args)?;
    match which {
        "fig1" | "fig3" => {
            let default = if which == "fig1" {
                "femnist".to_string()
            } else {
                "cifar10,celeba,femnist,movielens".to_string()
            };
            let ds = args.get_list("datasets", &default);
            args.reject_unknown()?;
            let refs: Vec<&str> = ds.iter().map(|s| s.as_str()).collect();
            experiments::fig3::run(&opts, &refs, &experiments::fig3::ALL_ALGOS)?;
        }
        "table1" | "table4" => {
            let default = if which == "table1" {
                "femnist".to_string()
            } else {
                "cifar10,celeba,femnist,movielens".to_string()
            };
            let ds = args.get_list("datasets", &default);
            args.reject_unknown()?;
            let refs: Vec<&str> = ds.iter().map(|s| s.as_str()).collect();
            experiments::table4::run(&opts, &refs)?;
        }
        "fig4" => {
            let dataset = args.get_str("dataset", "femnist");
            let sv = args.get_usize_list("s", "1,2,4,7")?;
            let av = args.get_usize_list("a", "1,3,5")?;
            let target = match args.get_opt("target") {
                Some(t) => Some(t.parse::<f64>()?),
                None => None,
            };
            args.reject_unknown()?;
            experiments::fig4::run(&opts, &dataset, &sv, &av, target)?;
        }
        "fig5" => {
            let initial = args.get_usize("initial", 90)?;
            let joiners = args.get_u64("joiners", 10)? as u32;
            args.reject_unknown()?;
            experiments::fig5::run(&opts, initial, joiners)?;
        }
        "fig6" => {
            let nodes = args.get_usize("nodes", 100)?;
            args.reject_unknown()?;
            experiments::fig6::run(&opts, nodes)?;
        }
        other => bail!("unknown experiment {other:?} (fig1|fig3|table1|table4|fig4|fig5|fig6)"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.positionals.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("exp") => {
            let which = args
                .positionals
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs a figure/table id\n{USAGE}"))?
                .clone();
            cmd_exp(&which, &args)
        }
        Some("info") => {
            let dir = args.get_str("artifacts", "artifacts");
            args.reject_unknown()?;
            let rt = XlaRuntime::load(&dir)?;
            let m = rt.manifest();
            println!("artifact manifest (seed {}):", m.seed);
            for (name, v) in &m.variants {
                println!(
                    "  {name:<12} kind={:<10} params={:>9} ({:>8} bytes) smax={} lr={} mu={} paper-nodes={}",
                    v.kind, v.param_count, v.model_bytes, v.smax, v.lr, v.momentum, v.nodes
                );
            }
            Ok(())
        }
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
