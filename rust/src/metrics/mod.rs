//! Session metrics: convergence curves, round/sample times, traffic
//! summaries, and membership-propagation traces — everything the paper's
//! figures and tables are built from.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::net::TrafficLedger;
use crate::sim::{ObsState, RoundWindow, SimTime};
use crate::{NodeId, Round};

/// One point on a convergence curve (Fig. 1/3/6 top).
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub time_s: f64,
    pub round: Round,
    /// Accuracy in [0,1] or MSE depending on the task.
    pub metric: f64,
    pub loss: f64,
    /// Std-dev across node models when evaluating D-SGD-style (else 0).
    pub metric_std: f64,
}

/// One completed sampling operation (Fig. 6 bottom).
#[derive(Debug, Clone, Copy)]
pub struct SampleTiming {
    pub completed_at_s: f64,
    pub duration_s: f64,
    pub round: Round,
    pub retries: u32,
}

/// Membership propagation trace of one join event (Fig. 5): how many of the
/// observer nodes still miss the joiner, sampled over time.
#[derive(Debug, Clone)]
pub struct JoinTrace {
    pub joiner: NodeId,
    pub joined_at_s: f64,
    /// (time_s, number of observers that do not yet know the joiner)
    pub missing: Vec<(f64, usize)>,
}

impl JoinTrace {
    /// Time from join until every observer knew the node (None if never).
    pub fn full_propagation_s(&self) -> Option<f64> {
        self.missing
            .iter()
            .find(|&&(_, m)| m == 0)
            .map(|&(t, _)| t - self.joined_at_s)
    }
}

/// Network usage summary in the shape of the paper's Tables 1 and 4.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrafficSummary {
    /// True wire cost: every delivery attempt, including drops/retransmits.
    pub total: u64,
    pub min_node: u64,
    pub max_node: u64,
    pub overhead: u64,
    pub overhead_fraction: f64,
    pub messages: u64,
    /// Useful first-delivery bytes (the Fig. 3 communication volume).
    pub goodput: u64,
    /// Bytes lost in flight to fault injection.
    pub dropped: u64,
    /// Bytes of delivered retransmissions.
    pub retransmitted: u64,
    /// Distinct (sender, receiver) pairs contacted — an HLL estimate from
    /// the ledger's streaming sketch (≈1.6% standard error).
    pub distinct_peers: u64,
}

impl TrafficSummary {
    pub fn from_ledger(ledger: &TrafficLedger, nodes: usize) -> TrafficSummary {
        let (min_node, max_node) = ledger.min_max_usage(nodes);
        TrafficSummary {
            total: ledger.total(),
            min_node,
            max_node,
            overhead: ledger.overhead(),
            overhead_fraction: ledger.overhead_fraction(),
            messages: ledger.messages(),
            goodput: ledger.goodput(),
            dropped: ledger.dropped_bytes(),
            retransmitted: ledger.retransmitted_bytes(),
            distinct_peers: ledger.distinct_peers(),
        }
    }
}

/// Everything a session records.
#[derive(Debug, Clone, Default)]
pub struct SessionMetrics {
    pub curve: Vec<CurvePoint>,
    pub samples: Vec<SampleTiming>,
    /// First dispatch time of each round: a bounded ring of the last
    /// [`crate::sim::obs::ROUND_WINDOW`] `(round, time_s)` pairs (the
    /// first entry and total count survive eviction, so whole-session
    /// aggregates stay exact). O(1) in rounds — million-round sessions no
    /// longer materialize their round trace.
    pub round_starts: RoundWindow,
    /// Streaming observability sketches (round-duration / message-latency
    /// histograms, distinct-trainers HLL). Serialized as the snapshot's
    /// own `"obs"` section by the harness.
    pub obs: ObsState,
    pub joins: Vec<JoinTrace>,
    pub traffic: TrafficSummary,
    /// Final round reached.
    pub final_round: Round,
    /// Virtual session duration.
    pub duration_s: f64,
    /// DES events processed (simulator throughput accounting).
    pub events: u64,
    /// Reservoir decimation stride for `samples` (0/1 = keep everything
    /// until the cap is first hit).
    sample_stride: u64,
    /// Sampling operations offered to `record_sample`, retained or not.
    sample_seen: u64,
}

impl SessionMetrics {
    /// Hard cap on retained [`SampleTiming`] entries: a million-node run
    /// offers hundreds of millions of sampling ops, and an unbounded
    /// `samples` vector would dwarf the rest of the session state.
    pub const MAX_SAMPLES: usize = 16_384;

    /// A metrics sink with its per-round vectors sized from the session
    /// budget, so long runs never reallocate them mid-session. `probes` is
    /// the number of evaluation ticks the harness will schedule.
    pub fn with_budget(max_rounds: Round, probes: usize) -> SessionMetrics {
        // An unlimited budget (0) or an absurd one still gets a sane
        // allocation: growth past this point falls back to doubling.
        const MAX_PREALLOC: usize = 1 << 16;
        let rounds = if max_rounds > 0 {
            (max_rounds as usize).saturating_add(2).min(MAX_PREALLOC)
        } else {
            0
        };
        let mut m = SessionMetrics::default();
        m.curve.reserve_exact(probes.min(MAX_PREALLOC));
        m.samples.reserve_exact(rounds.min(Self::MAX_SAMPLES));
        m
    }

    pub fn record_eval(
        &mut self,
        now: SimTime,
        round: Round,
        metric: f64,
        loss: f64,
        metric_std: f64,
    ) {
        self.curve.push(CurvePoint {
            time_s: now.as_secs_f64(),
            round,
            metric,
            loss,
            metric_std,
        });
    }

    pub fn record_sample(&mut self, now: SimTime, started: SimTime, round: Round, retries: u32) {
        // Deterministic bounded reservoir: keep every `stride`-th offered
        // sample; when the cap fills, drop every other retained entry and
        // double the stride. No RNG is touched, so same-seed sessions
        // retain the identical subset, and memory is O(MAX_SAMPLES) no
        // matter how long the session runs.
        self.sample_seen += 1;
        let stride = self.sample_stride.max(1);
        if (self.sample_seen - 1) % stride != 0 {
            return;
        }
        if self.samples.len() == Self::MAX_SAMPLES {
            let mut i = 0usize;
            self.samples.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.sample_stride = stride * 2;
            if (self.sample_seen - 1) % (stride * 2) != 0 {
                return;
            }
        }
        self.samples.push(SampleTiming {
            completed_at_s: now.as_secs_f64(),
            duration_s: (now.saturating_sub(started)).as_secs_f64(),
            round,
            retries,
        });
    }

    pub fn record_round_start(&mut self, round: Round, now: SimTime) {
        if self.round_starts.last().map(|(r, _)| r) == Some(round) {
            return;
        }
        let t = now.as_secs_f64();
        if let Some((_, prev_t)) = self.round_starts.last() {
            // Feed the round-duration histogram (µs) from consecutive
            // round-start gaps — the streaming form of the old full trace.
            let dt_us = ((t - prev_t) * 1e6).round();
            if dt_us >= 0.0 {
                self.obs.round_hist.record(dt_us as u64);
            }
        }
        self.round_starts.record(round, t);
    }

    /// Serialize everything recorded so far, including the reservoir's
    /// stride/seen counters — a resumed session must decimate future
    /// samples exactly where the checkpointed one would have, or the
    /// retained subset (and the session fingerprint) drifts.
    pub fn write_into(&self, w: &mut crate::sim::SnapshotWriter) {
        w.write_usize(self.curve.len());
        for p in &self.curve {
            w.write_f64(p.time_s);
            w.write_u64(p.round);
            w.write_f64(p.metric);
            w.write_f64(p.loss);
            w.write_f64(p.metric_std);
        }
        w.write_usize(self.samples.len());
        for s in &self.samples {
            w.write_f64(s.completed_at_s);
            w.write_f64(s.duration_s);
            w.write_u64(s.round);
            w.write_u32(s.retries);
        }
        self.round_starts.write_into(w);
        w.write_usize(self.joins.len());
        for j in &self.joins {
            w.write_u32(j.joiner);
            w.write_f64(j.joined_at_s);
            w.write_usize(j.missing.len());
            for &(t, m) in &j.missing {
                w.write_f64(t);
                w.write_usize(m);
            }
        }
        w.write_u64(self.traffic.total);
        w.write_u64(self.traffic.min_node);
        w.write_u64(self.traffic.max_node);
        w.write_u64(self.traffic.overhead);
        w.write_f64(self.traffic.overhead_fraction);
        w.write_u64(self.traffic.messages);
        w.write_u64(self.traffic.goodput);
        w.write_u64(self.traffic.dropped);
        w.write_u64(self.traffic.retransmitted);
        w.write_u64(self.traffic.distinct_peers);
        w.write_u64(self.final_round);
        w.write_f64(self.duration_s);
        w.write_u64(self.events);
        w.write_u64(self.sample_stride);
        w.write_u64(self.sample_seen);
    }

    pub fn read_from(r: &mut crate::sim::SnapshotReader) -> Result<SessionMetrics> {
        let mut m = SessionMetrics::default();
        for _ in 0..r.read_usize()? {
            m.curve.push(CurvePoint {
                time_s: r.read_f64()?,
                round: r.read_u64()?,
                metric: r.read_f64()?,
                loss: r.read_f64()?,
                metric_std: r.read_f64()?,
            });
        }
        for _ in 0..r.read_usize()? {
            m.samples.push(SampleTiming {
                completed_at_s: r.read_f64()?,
                duration_s: r.read_f64()?,
                round: r.read_u64()?,
                retries: r.read_u32()?,
            });
        }
        m.round_starts = RoundWindow::read_from(r)?;
        for _ in 0..r.read_usize()? {
            let joiner = r.read_u32()?;
            let joined_at_s = r.read_f64()?;
            let mut missing = Vec::new();
            for _ in 0..r.read_usize()? {
                let t = r.read_f64()?;
                let n = r.read_usize()?;
                missing.push((t, n));
            }
            m.joins.push(JoinTrace { joiner, joined_at_s, missing });
        }
        m.traffic = TrafficSummary {
            total: r.read_u64()?,
            min_node: r.read_u64()?,
            max_node: r.read_u64()?,
            overhead: r.read_u64()?,
            overhead_fraction: r.read_f64()?,
            messages: r.read_u64()?,
            goodput: r.read_u64()?,
            dropped: r.read_u64()?,
            retransmitted: r.read_u64()?,
            distinct_peers: r.read_u64()?,
        };
        m.final_round = r.read_u64()?;
        m.duration_s = r.read_f64()?;
        m.events = r.read_u64()?;
        m.sample_stride = r.read_u64()?;
        m.sample_seen = r.read_u64()?;
        Ok(m)
    }

    /// First virtual time at which `metric` crossed `target` (accuracy) or
    /// dropped below it (MSE), with the round it happened in.
    pub fn time_to_target(&self, target: f64, higher_is_better: bool) -> Option<(f64, Round)> {
        self.curve
            .iter()
            .find(|p| {
                if higher_is_better {
                    p.metric >= target
                } else {
                    p.metric <= target
                }
            })
            .map(|p| (p.time_s, p.round))
    }

    /// Best metric reached.
    pub fn best_metric(&self, higher_is_better: bool) -> Option<f64> {
        let it = self.curve.iter().map(|p| p.metric);
        if higher_is_better {
            it.fold(None, |a: Option<f64>, x| Some(a.map_or(x, |a| a.max(x))))
        } else {
            it.fold(None, |a: Option<f64>, x| Some(a.map_or(x, |a| a.min(x))))
        }
    }

    /// Mean round duration over the whole session (Fig. 6 annotation).
    /// Exact despite the windowing: the window retains the first entry and
    /// the total count across evictions.
    pub fn mean_round_time_s(&self) -> Option<f64> {
        if self.round_starts.seen() < 2 {
            return None;
        }
        let first = self.round_starts.first()?;
        let last = self.round_starts.last()?;
        Some((last.1 - first.1) / (self.round_starts.seen() - 1) as f64)
    }

    /// Dump the convergence curve as CSV.
    pub fn write_curve_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "time_s,round,metric,loss,metric_std")?;
        for p in &self.curve {
            writeln!(
                f,
                "{:.3},{},{:.6},{:.6},{:.6}",
                p.time_s, p.round, p.metric, p.loss, p.metric_std
            )?;
        }
        Ok(())
    }

    /// Dump sample timings as CSV (Fig. 6 bottom).
    pub fn write_samples_csv(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "completed_at_s,duration_s,round,retries")?;
        for s in &self.samples {
            writeln!(
                f,
                "{:.3},{:.4},{},{}",
                s.completed_at_s, s.duration_s, s.round, s.retries
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_to_target_accuracy() {
        let mut m = SessionMetrics::default();
        m.record_eval(SimTime::from_secs_f64(10.0), 1, 0.5, 1.0, 0.0);
        m.record_eval(SimTime::from_secs_f64(20.0), 2, 0.85, 0.5, 0.0);
        assert_eq!(m.time_to_target(0.8, true), Some((20.0, 2)));
        assert_eq!(m.time_to_target(0.9, true), None);
    }

    #[test]
    fn time_to_target_mse() {
        let mut m = SessionMetrics::default();
        m.record_eval(SimTime::from_secs_f64(5.0), 1, 2.0, 2.0, 0.0);
        m.record_eval(SimTime::from_secs_f64(9.0), 2, 0.9, 0.9, 0.0);
        assert_eq!(m.time_to_target(1.0, false), Some((9.0, 2)));
    }

    #[test]
    fn round_start_dedup() {
        let mut m = SessionMetrics::default();
        m.record_round_start(1, SimTime::from_secs_f64(1.0));
        m.record_round_start(1, SimTime::from_secs_f64(1.5));
        m.record_round_start(2, SimTime::from_secs_f64(2.0));
        assert_eq!(m.round_starts.len(), 2);
        assert!((m.mean_round_time_s().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn join_trace_propagation() {
        let t = JoinTrace {
            joiner: 90,
            joined_at_s: 60.0,
            missing: vec![(60.0, 90), (120.0, 40), (300.0, 0)],
        };
        assert_eq!(t.full_propagation_s(), Some(240.0));
    }

    #[test]
    fn sample_reservoir_caps_memory_deterministically() {
        let run = |total: usize| {
            let mut m = SessionMetrics::default();
            for i in 0..total {
                m.record_sample(SimTime::from_micros(i as u64 + 1), SimTime::ZERO, 1, 0);
            }
            m
        };
        let total = SessionMetrics::MAX_SAMPLES * 4 + 123;
        let m = run(total);
        assert!(m.samples.len() <= SessionMetrics::MAX_SAMPLES);
        assert!(m.samples.len() > SessionMetrics::MAX_SAMPLES / 4, "{}", m.samples.len());
        // Decimation keeps the earliest sample and preserves time order.
        assert_eq!(m.samples[0].completed_at_s, 1e-6);
        assert!(m
            .samples
            .windows(2)
            .all(|w| w[0].completed_at_s < w[1].completed_at_s));
        // Same offer stream, same retained subset: the reservoir draws no
        // randomness.
        let b = run(total);
        assert_eq!(m.samples.len(), b.samples.len());
        assert_eq!(
            m.samples.last().unwrap().completed_at_s.to_bits(),
            b.samples.last().unwrap().completed_at_s.to_bits()
        );
    }

    #[test]
    fn small_sessions_keep_every_sample() {
        let mut m = SessionMetrics::default();
        for i in 0..100u64 {
            m.record_sample(SimTime::from_micros(i + 1), SimTime::ZERO, 1, 0);
        }
        assert_eq!(m.samples.len(), 100);
    }

    #[test]
    fn with_budget_preallocates_from_the_round_budget() {
        let m = SessionMetrics::with_budget(100, 32);
        assert!(m.curve.capacity() >= 32);
        assert!(m.samples.capacity() >= 100);
        assert!(m.curve.is_empty() && m.samples.is_empty());
        // Unlimited budgets must not preallocate the per-round vectors.
        let u = SessionMetrics::with_budget(0, 8);
        assert_eq!(u.samples.capacity(), 0);
        assert!(u.round_starts.is_empty());
    }

    #[test]
    fn round_durations_feed_the_streaming_histogram() {
        let mut m = SessionMetrics::default();
        for r in 1..=50u64 {
            m.record_round_start(r, SimTime::from_secs_f64(r as f64 * 2.0));
        }
        // 49 gaps of exactly 2s = 2_000_000 µs each.
        assert_eq!(m.obs.round_hist.total(), 49);
        let p50 = m.obs.round_hist.quantile(0.5) as f64;
        assert!((p50 / 2e6 - 1.0).abs() <= 0.0625, "p50 {p50} vs 2e6");
        assert!((m.mean_round_time_s().unwrap() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mean_round_time_stays_exact_after_window_eviction() {
        use crate::sim::obs::ROUND_WINDOW;
        let mut m = SessionMetrics::default();
        let total = ROUND_WINDOW as u64 + 500;
        for r in 0..total {
            m.record_round_start(r, SimTime::from_secs_f64(r as f64 * 3.0));
        }
        assert_eq!(m.round_starts.len(), ROUND_WINDOW);
        assert_eq!(m.round_starts.seen(), total);
        // (last - first) / (seen - 1) = 3.0 exactly, eviction or not.
        assert!((m.mean_round_time_s().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_roundtrip_resumes_the_reservoir_mid_decimation() {
        use crate::sim::{SnapshotReader, SnapshotWriter};
        // Fill past the cap so stride-doubling has happened, snapshot,
        // then keep offering to both the original and the restored sink:
        // the retained subsets must stay identical (reservoir continuity
        // is part of the fingerprint contract).
        let mut m = SessionMetrics::default();
        for i in 0..(SessionMetrics::MAX_SAMPLES as u64 * 2 + 7) {
            m.record_sample(SimTime::from_micros(i + 1), SimTime::ZERO, 1, 0);
        }
        m.record_eval(SimTime::from_secs_f64(3.0), 2, 0.5, 1.25, 0.0);
        m.record_round_start(2, SimTime::from_secs_f64(2.5));
        m.joins.push(JoinTrace {
            joiner: 9,
            joined_at_s: 1.0,
            missing: vec![(1.0, 4), (2.0, 0)],
        });
        m.events = 12345;
        let mut w = SnapshotWriter::new();
        w.begin_section("metrics");
        m.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("metrics").unwrap();
        let mut back = SessionMetrics::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(back.curve.len(), 1);
        assert_eq!(back.curve[0].loss.to_bits(), 1.25f64.to_bits());
        assert_eq!(back.round_starts, m.round_starts);
        assert_eq!(back.joins.len(), 1);
        assert_eq!(back.joins[0].missing, m.joins[0].missing);
        assert_eq!(back.events, 12345);
        assert_eq!(back.samples.len(), m.samples.len());
        for i in 0..(SessionMetrics::MAX_SAMPLES as u64 * 3) {
            let t = SimTime::from_micros(1_000_000 + i);
            m.record_sample(t, SimTime::ZERO, 3, 1);
            back.record_sample(t, SimTime::ZERO, 3, 1);
        }
        assert_eq!(m.samples.len(), back.samples.len(), "reservoir desynced after restore");
        for (a, b) in m.samples.iter().zip(&back.samples) {
            assert_eq!(a.completed_at_s.to_bits(), b.completed_at_s.to_bits());
            assert_eq!(a.round, b.round);
        }
    }

    #[test]
    fn best_metric_directions() {
        let mut m = SessionMetrics::default();
        m.record_eval(SimTime::ZERO, 1, 0.3, 3.0, 0.0);
        m.record_eval(SimTime::ZERO, 2, 0.7, 1.0, 0.0);
        m.record_eval(SimTime::ZERO, 3, 0.6, 1.5, 0.0);
        assert_eq!(m.best_metric(true), Some(0.7));
        assert_eq!(m.best_metric(false), Some(0.3));
    }
}
