//! The Scenario API: declarative session composition.
//!
//! This layer replaces the old closed surface (an `Algo` enum, a flat
//! 19-field `SessionSpec`, and per-algorithm `build_*` calls at every
//! launch site) with two orthogonal pieces:
//!
//! * [`ScenarioSpec`] — a layered description of one session, nested as
//!   `{workload, population, network, protocol, run}` and parseable from
//!   JSON (legacy flat keys keep working through a compatibility shim).
//!   The `network` section speaks the full fabric vocabulary: uniform,
//!   lognormal, weighted asymmetric capacity tiers, per-node traces.
//! * [`ProtocolRegistry`] — protocol name → [`SessionBuilder`] factory
//!   returning a type-erased [`Session`] with uniform
//!   `run() -> (SessionMetrics, TrafficLedger)`, plus [`ProtocolMeta`]
//!   (label, aliases, default params) that drives CLI help, experiment
//!   labels, and CSV naming.
//!
//! Every launcher (`main.rs`, `experiments::*`, the examples, tests and
//! benches) goes through this module; protocols never appear by name
//! outside their own module and one registration line in
//! [`ProtocolRegistry::builtins`].

pub mod availability;
pub mod network;
pub mod registry;
pub mod resume;
pub mod spec;

pub use availability::{AvailabilityModel, AvailabilitySpec};
pub use network::{LatencySpec, NetworkSpec, TierSpec};
pub use registry::{
    run_scenario, ProtocolMeta, ProtocolRegistry, Session, SessionBuilder,
};
pub use resume::{embedded_spec, resume_session};
pub use spec::{
    PopulationSpec, ProgressSpec, ProtocolSpec, RunSpec, ScenarioSpec, WorkloadSpec,
};
