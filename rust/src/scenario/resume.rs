//! Resume a session from snapshot bytes, with optional what-if branching.
//!
//! A snapshot embeds the canonical scenario JSON it was taken under (its
//! `spec` section), so resuming needs nothing but the file: the spec
//! rebuilds every static — task data, latency geography, bandwidth config,
//! calendar-queue geometry — and the snapshot replays the dynamic state on
//! top. What-if branching layers a partial scenario JSON *overlay* over the
//! embedded spec (overlay wins per key, recursively), e.g. a different
//! `population.availability` future; the branch diverges only after the
//! checkpoint instant because the harness RNG is the sole runtime stream
//! and its state resumes exactly. An overlay that extends `run.max_time_s`
//! does not add probe/eval ticks before the restored horizon — queued
//! `Probe` events are restored as-is.

use anyhow::{Context, Result};

use crate::runtime::XlaRuntime;
use crate::sim::{ChurnSchedule, ResumeOptions, SnapshotReader};
use crate::util::Json;

use super::registry::{ProtocolRegistry, Session};
use super::spec::ScenarioSpec;

/// Recursive object merge: `overlay` wins on leaves and non-object values;
/// keys absent from `base` are appended in overlay order.
fn merge_json(base: &Json, overlay: &Json) -> Json {
    match (base, overlay) {
        (Json::Obj(b), Json::Obj(o)) => {
            let mut out = b.clone();
            for (k, v) in o {
                match out.iter_mut().find(|(ek, _)| ek == k) {
                    Some((_, ev)) => *ev = merge_json(ev, v),
                    None => out.push((k.clone(), v.clone())),
                }
            }
            Json::Obj(out)
        }
        (_, o) => o.clone(),
    }
}

/// Peek at the scenario spec a snapshot embeds without building anything —
/// launchers use this to decide whether the dataset needs an XLA runtime
/// before committing to session assembly.
pub fn embedded_spec(bytes: &[u8]) -> Result<ScenarioSpec> {
    let mut r = SnapshotReader::new(bytes)?;
    r.begin_section("spec")?;
    let embedded = r.read_str()?;
    r.end_section()?;
    ScenarioSpec::from_json(&embedded)
        .context("parsing the scenario spec embedded in the snapshot")
}

/// Rebuild a session from snapshot bytes and restore its state, ready to
/// `run()`. `overlay_json` is an optional partial scenario JSON for what-if
/// branching; `fork` relabels the RNG stream at the resume point so two
/// branches of the same snapshot diverge even under an identical future.
/// Returns the effective (merged) spec alongside the session, for labels
/// and output naming.
pub fn resume_session(
    bytes: &[u8],
    overlay_json: Option<&str>,
    fork: Option<String>,
    runtime: Option<&XlaRuntime>,
) -> Result<(ScenarioSpec, Box<dyn Session>)> {
    let mut r = SnapshotReader::new(bytes)?;
    r.begin_section("spec")?;
    let embedded = r.read_str()?;
    r.end_section()?;
    let base = ScenarioSpec::from_json(&embedded)
        .context("parsing the scenario spec embedded in the snapshot")?;
    let spec = match overlay_json {
        Some(text) => {
            let overlay = Json::parse(text).context("parsing the what-if overlay")?;
            let merged = merge_json(&base.to_json(), &overlay);
            ScenarioSpec::from_json(&merged.to_string())
                .context("applying the what-if overlay to the embedded spec")?
        }
        None => base.clone(),
    };
    // A changed availability future invalidates the snapshot's queued churn
    // (it indexes the old script); the harness drops it and schedules the
    // freshly compiled script instead. An unchanged future replays the
    // snapshot's own schedule verbatim for bit-identical resumption.
    let reschedule_churn = spec.population.availability != base.population.availability;
    let mut session =
        ProtocolRegistry::builtins().build(&spec, runtime, ChurnSchedule::empty())?;
    session.resume(&mut r, &ResumeOptions { fork, reschedule_churn })?;
    r.finish()?;
    Ok((spec, session))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_overlay_wins_recursively() {
        let base = Json::parse(r#"{"a": {"x": 1, "y": 2}, "b": 3}"#).unwrap();
        let over = Json::parse(r#"{"a": {"y": 9, "z": 8}, "c": 4}"#).unwrap();
        let m = merge_json(&base, &over);
        assert_eq!(m.to_string(), r#"{"a":{"x":1,"y":9,"z":8},"b":3,"c":4}"#);
    }

    #[test]
    fn merge_replaces_non_objects_wholesale() {
        let base = Json::parse(r#"{"a": {"x": 1}}"#).unwrap();
        let over = Json::parse(r#"{"a": null}"#).unwrap();
        assert_eq!(merge_json(&base, &over).to_string(), r#"{"a":null}"#);
    }

    #[test]
    fn garbage_bytes_fail_loudly() {
        assert!(resume_session(b"not a snapshot", None, None, None).is_err());
    }
}
