//! The layered scenario specification.
//!
//! A [`ScenarioSpec`] fully describes one runnable session as five nested
//! sections — replacing the old flat 19-field `SessionSpec`:
//!
//! * [`WorkloadSpec`] — which learning task (dataset preset, artifact dir).
//! * [`PopulationSpec`] — how many nodes and how fast they compute.
//! * [`NetworkSpec`] — latency + per-node capacity shaping (see
//!   [`super::network`]).
//! * [`ProtocolSpec`] — which registered protocol runs, with its knobs.
//! * [`RunSpec`] — budgets, eval cadence, stop target, seed.
//!
//! JSON configs may use the nested sections, the old flat keys (accepted
//! via a compatibility shim so every pre-existing config file keeps
//! parsing, with identical same-seed behaviour), or a mix of both; flat
//! keys are applied after sections so an explicit flat override wins.

use anyhow::{bail, Result};

use crate::config::preset;
use crate::learning::{ComputeModel, MockTask, Task};
use crate::net::{LatencyMatrix, LatencyParams, NetworkFabric};
use crate::runtime::XlaRuntime;
use crate::sim::{ChurnKind, ChurnSchedule, ProgressConfig, SamplingVersion, SimRng, SimTime};
use crate::util::Json;

use super::availability::AvailabilitySpec;
use super::network::NetworkSpec;

/// The `workload` section: which learning task the session trains.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Dataset preset name (`cifar10`, `celeba`, `femnist`, `movielens`,
    /// `transformer`, `mock`).
    pub dataset: String,
    /// AOT artifact directory for the XLA path.
    pub artifacts_dir: String,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { dataset: "cifar10".into(), artifacts_dir: "artifacts".into() }
    }
}

/// The `population` section: node count, compute heterogeneity, and
/// (optionally) trace-driven or synthetic node availability.
#[derive(Debug, Clone, PartialEq)]
pub struct PopulationSpec {
    /// Explicit node count; 0 = paper preset count (times `scale`).
    pub nodes: usize,
    /// Scale factor on the preset node count for CI-speed runs.
    pub scale: f64,
    /// Base per-batch train time (s) on a speed-1 node.
    pub base_batch_s: f64,
    /// Compute heterogeneity (lognormal sigma; 0 = uniform).
    pub hetero_sigma: f64,
    /// Node availability over time (diurnal sine / step / CSV trace),
    /// compiled into a churn schedule at session build time; absent =
    /// everyone stays up unless a programmatic churn script says
    /// otherwise.
    pub availability: Option<AvailabilitySpec>,
}

impl Default for PopulationSpec {
    fn default() -> Self {
        PopulationSpec {
            nodes: 0,
            scale: 1.0,
            base_batch_s: 0.05,
            hetero_sigma: 0.35,
            availability: None,
        }
    }
}

/// The `protocol` section: which registered protocol runs the session.
#[derive(Debug, Clone, PartialEq)]
pub struct ProtocolSpec {
    /// Registry name or alias (`modest`, `fedavg`/`fl`, `dsgd`/`d-sgd`/`dl`,
    /// `gossip`, ...).
    pub name: String,
    /// Sample size `s` (trainers per round); 0 = dataset preset.
    pub s: usize,
    /// Aggregators per round `a`; 0 = dataset preset.
    pub a: usize,
    /// Success fraction `sf` of models required to aggregate.
    pub sf: f64,
    /// Ping timeout `Δt` in seconds.
    pub dt_s: f64,
    /// Activity window `Δk` in rounds.
    pub dk: u64,
    /// Protocol-specific extras (e.g. gossip `fanout`), free-form numeric
    /// key/value pairs a builder may read via [`ProtocolSpec::param`].
    pub params: Vec<(String, f64)>,
}

impl Default for ProtocolSpec {
    fn default() -> Self {
        ProtocolSpec {
            name: "modest".into(),
            s: 0,
            a: 0,
            sf: 1.0,
            dt_s: 2.0,
            dk: 20,
            params: Vec::new(),
        }
    }
}

impl ProtocolSpec {
    /// Look up a protocol-specific extra parameter.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

/// The `run.progress` section: live JSONL progress snapshots.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgressSpec {
    /// Emit one snapshot line every this many virtual seconds.
    pub every_s: f64,
    /// Output file path (`None` = stderr). Relative paths are resolved
    /// against the config file's directory, like availability traces.
    pub out: Option<String>,
}

/// The `run` section: budgets, eval cadence, stop target, seed.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// Stop after this much virtual time (seconds).
    pub max_time_s: f64,
    /// Round budget (0 = unlimited).
    pub max_rounds: u64,
    /// Evaluate the model(s) this often (virtual seconds).
    pub eval_interval_s: f64,
    /// Stop early when the metric crosses this target (accuracy >=, mse <=).
    pub target_metric: Option<f64>,
    /// Seed for everything in the session.
    pub seed: u64,
    /// Peer-sampling stream version (JSON `"sampling": "v1" | "v2"`).
    /// `v1` — the default — keeps every pre-existing same-seed session
    /// fingerprint bit-identical; `v2` draws the same set distribution in
    /// O(k) per fan-out for large populations.
    pub sampling: SamplingVersion,
    /// Write a snapshot and stop once the virtual clock reaches this
    /// instant (seconds); requires `checkpoint_out`.
    pub checkpoint_at_s: Option<f64>,
    /// Snapshot file path for `checkpoint_at_s`.
    pub checkpoint_out: Option<String>,
    /// Live progress stream (`None` = off: zero extra events or RNG
    /// draws, so recorded same-seed fingerprints stay bit-identical).
    pub progress: Option<ProgressSpec>,
    /// Event-queue execution threads (must be ≥ 1). 1 — the default — is
    /// the classic single-threaded loop; T > 1 shards the queue across T
    /// worker threads under the conservative-window scheduler, bit-identical
    /// to T = 1 (fingerprints, ledgers, progress streams, snapshots).
    pub threads: usize,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            max_time_s: 1800.0,
            max_rounds: 0,
            eval_interval_s: 20.0,
            target_metric: None,
            seed: 42,
            sampling: SamplingVersion::default(),
            checkpoint_at_s: None,
            checkpoint_out: None,
            progress: None,
            threads: 1,
        }
    }
}

/// Full layered session description; see the module docs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScenarioSpec {
    pub workload: WorkloadSpec,
    pub population: PopulationSpec,
    pub network: NetworkSpec,
    pub protocol: ProtocolSpec,
    pub run: RunSpec,
}

impl ScenarioSpec {
    /// Convenience constructor for the common case.
    pub fn new(dataset: &str, protocol: &str) -> ScenarioSpec {
        ScenarioSpec {
            workload: WorkloadSpec { dataset: dataset.into(), ..Default::default() },
            protocol: ProtocolSpec { name: protocol.into(), ..Default::default() },
            ..Default::default()
        }
    }

    // ------------------------------------------------------------- parsing

    /// Load from a JSON config body. Accepts the nested five-section form,
    /// the legacy flat keys, or a mix (flat keys applied last, so they
    /// override sections). Unknown keys are rejected at every level.
    pub fn from_json(text: &str) -> Result<ScenarioSpec> {
        let v = Json::parse(text)?;
        let mut spec = ScenarioSpec::default();
        let mut flat: Vec<(&str, &Json)> = Vec::new();
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                // -------- nested sections
                "workload" => {
                    for (k, val) in val.as_obj()? {
                        match k.as_str() {
                            "dataset" => spec.workload.dataset = val.as_str()?.to_string(),
                            "artifacts_dir" => {
                                spec.workload.artifacts_dir = val.as_str()?.to_string()
                            }
                            other => bail!("unknown workload key {other:?}"),
                        }
                    }
                }
                "population" => {
                    for (k, val) in val.as_obj()? {
                        match k.as_str() {
                            "nodes" => spec.population.nodes = val.as_usize()?,
                            "scale" => spec.population.scale = val.as_f64()?,
                            "base_batch_s" => spec.population.base_batch_s = val.as_f64()?,
                            "hetero_sigma" => spec.population.hetero_sigma = val.as_f64()?,
                            "availability" => {
                                spec.population.availability = if *val == Json::Null {
                                    None
                                } else {
                                    Some(AvailabilitySpec::from_json(val)?)
                                }
                            }
                            other => bail!("unknown population key {other:?}"),
                        }
                    }
                }
                "network" => spec.network = NetworkSpec::from_json(val)?,
                "protocol" => {
                    for (k, val) in val.as_obj()? {
                        match k.as_str() {
                            "name" => spec.protocol.name = val.as_str()?.to_string(),
                            "s" => spec.protocol.s = val.as_usize()?,
                            "a" => spec.protocol.a = val.as_usize()?,
                            "sf" => spec.protocol.sf = val.as_f64()?,
                            "dt_s" => spec.protocol.dt_s = val.as_f64()?,
                            "dk" => spec.protocol.dk = val.as_u64()?,
                            "params" => {
                                spec.protocol.params = val
                                    .as_obj()?
                                    .iter()
                                    .map(|(k, v)| Ok((k.clone(), v.as_f64()?)))
                                    .collect::<Result<Vec<_>>>()?;
                            }
                            other => bail!("unknown protocol key {other:?}"),
                        }
                    }
                }
                "run" => {
                    for (k, val) in val.as_obj()? {
                        match k.as_str() {
                            "max_time_s" => spec.run.max_time_s = val.as_f64()?,
                            "max_rounds" => spec.run.max_rounds = val.as_u64()?,
                            "eval_interval_s" => spec.run.eval_interval_s = val.as_f64()?,
                            "target_metric" => {
                                spec.run.target_metric = if *val == Json::Null {
                                    None
                                } else {
                                    Some(val.as_f64()?)
                                }
                            }
                            "seed" => spec.run.seed = val.as_u64()?,
                            "sampling" => {
                                spec.run.sampling = SamplingVersion::parse(val.as_str()?)?
                            }
                            "checkpoint_at_s" => {
                                spec.run.checkpoint_at_s = if *val == Json::Null {
                                    None
                                } else {
                                    Some(val.as_f64()?)
                                }
                            }
                            "checkpoint_out" => {
                                spec.run.checkpoint_out = if *val == Json::Null {
                                    None
                                } else {
                                    Some(val.as_str()?.to_string())
                                }
                            }
                            "progress" => {
                                spec.run.progress = if *val == Json::Null {
                                    None
                                } else {
                                    let mut p = ProgressSpec { every_s: 0.0, out: None };
                                    let mut saw_every = false;
                                    for (pk, pv) in val.as_obj()? {
                                        match pk.as_str() {
                                            "every_s" => {
                                                p.every_s = pv.as_f64()?;
                                                saw_every = true;
                                            }
                                            "out" => {
                                                p.out = if *pv == Json::Null {
                                                    None
                                                } else {
                                                    Some(pv.as_str()?.to_string())
                                                }
                                            }
                                            other => {
                                                bail!("unknown run.progress key {other:?}")
                                            }
                                        }
                                    }
                                    if !saw_every {
                                        bail!("run.progress requires \"every_s\"");
                                    }
                                    Some(p)
                                }
                            }
                            "threads" => {
                                let t = val.as_usize()?;
                                if t == 0 {
                                    bail!("run.threads must be >= 1 (got 0)");
                                }
                                let avail = std::thread::available_parallelism()
                                    .map(|n| n.get())
                                    .unwrap_or(1);
                                if t > avail {
                                    eprintln!(
                                        "warning: run.threads = {t} exceeds available \
                                         parallelism ({avail}); the run stays \
                                         deterministic but threads will contend"
                                    );
                                }
                                spec.run.threads = t;
                            }
                            other => bail!("unknown run key {other:?}"),
                        }
                    }
                }
                // -------- legacy flat keys (deferred so they win over
                // sections regardless of key order)
                _ => flat.push((key.as_str(), val)),
            }
        }
        for (key, val) in flat {
            spec.apply_flat_key(key, val)?;
        }
        Ok(spec)
    }

    /// Legacy flat-key compatibility shim: the full old `SessionSpec`
    /// vocabulary routed into the nested sections.
    fn apply_flat_key(&mut self, key: &str, val: &Json) -> Result<()> {
        match key {
            "dataset" => self.workload.dataset = val.as_str()?.to_string(),
            "artifacts_dir" => self.workload.artifacts_dir = val.as_str()?.to_string(),
            // `algo` was the enum-backed protocol selector.
            "algo" => self.protocol.name = val.as_str()?.to_string(),
            "nodes" => self.population.nodes = val.as_usize()?,
            "scale" => self.population.scale = val.as_f64()?,
            "base_batch_s" => self.population.base_batch_s = val.as_f64()?,
            "hetero_sigma" => self.population.hetero_sigma = val.as_f64()?,
            "s" => self.protocol.s = val.as_usize()?,
            "a" => self.protocol.a = val.as_usize()?,
            "sf" => self.protocol.sf = val.as_f64()?,
            "dt_s" => self.protocol.dt_s = val.as_f64()?,
            "dk" => self.protocol.dk = val.as_u64()?,
            "max_time_s" => self.run.max_time_s = val.as_f64()?,
            "max_rounds" => self.run.max_rounds = val.as_u64()?,
            "eval_interval_s" => self.run.eval_interval_s = val.as_f64()?,
            "target_metric" => {
                self.run.target_metric =
                    if *val == Json::Null { None } else { Some(val.as_f64()?) }
            }
            "seed" => self.run.seed = val.as_u64()?,
            "sampling" => self.run.sampling = SamplingVersion::parse(val.as_str()?)?,
            "bandwidth_mbps" => self.network.bandwidth_mbps = val.as_f64()?,
            "bandwidth_sigma" => self.network.bandwidth_sigma = val.as_f64()?,
            other => bail!(
                "unknown config key {other:?} (not a section or a legacy flat key)"
            ),
        }
        Ok(())
    }

    /// Serialize as the nested five-section JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "workload",
                Json::obj(vec![
                    ("dataset", Json::Str(self.workload.dataset.clone())),
                    ("artifacts_dir", Json::Str(self.workload.artifacts_dir.clone())),
                ]),
            ),
            (
                "population",
                Json::obj(vec![
                    ("nodes", Json::Num(self.population.nodes as f64)),
                    ("scale", Json::Num(self.population.scale)),
                    ("base_batch_s", Json::Num(self.population.base_batch_s)),
                    ("hetero_sigma", Json::Num(self.population.hetero_sigma)),
                    (
                        "availability",
                        match &self.population.availability {
                            Some(a) => a.to_json(),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            ("network", self.network.to_json()),
            (
                "protocol",
                Json::obj(vec![
                    ("name", Json::Str(self.protocol.name.clone())),
                    ("s", Json::Num(self.protocol.s as f64)),
                    ("a", Json::Num(self.protocol.a as f64)),
                    ("sf", Json::Num(self.protocol.sf)),
                    ("dt_s", Json::Num(self.protocol.dt_s)),
                    ("dk", Json::Num(self.protocol.dk as f64)),
                    (
                        "params",
                        Json::Obj(
                            self.protocol
                                .params
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "run",
                Json::obj(vec![
                    ("max_time_s", Json::Num(self.run.max_time_s)),
                    ("max_rounds", Json::Num(self.run.max_rounds as f64)),
                    ("eval_interval_s", Json::Num(self.run.eval_interval_s)),
                    (
                        "target_metric",
                        match self.run.target_metric {
                            Some(t) => Json::Num(t),
                            None => Json::Null,
                        },
                    ),
                    ("seed", Json::Num(self.run.seed as f64)),
                    ("sampling", Json::Str(self.run.sampling.as_str().to_string())),
                    (
                        "checkpoint_at_s",
                        match self.run.checkpoint_at_s {
                            Some(t) => Json::Num(t),
                            None => Json::Null,
                        },
                    ),
                    (
                        "checkpoint_out",
                        match &self.run.checkpoint_out {
                            Some(p) => Json::Str(p.clone()),
                            None => Json::Null,
                        },
                    ),
                    (
                        "progress",
                        match &self.run.progress {
                            Some(p) => Json::obj(vec![
                                ("every_s", Json::Num(p.every_s)),
                                (
                                    "out",
                                    match &p.out {
                                        Some(o) => Json::Str(o.clone()),
                                        None => Json::Null,
                                    },
                                ),
                            ]),
                            None => Json::Null,
                        },
                    ),
                    ("threads", Json::Num(self.run.threads as f64)),
                ]),
            ),
        ])
    }

    /// The canonical JSON a snapshot embeds: this spec with the checkpoint
    /// trigger cleared, so a resumed session re-runs to its budget instead
    /// of immediately re-checkpointing over its own input file. Lossless
    /// for everything else — `from_json(snapshot_json(spec))` rebuilds the
    /// identical substrate (same seeds, fabric, churn compilation).
    pub fn snapshot_json(&self) -> String {
        let mut clean = self.clone();
        clean.run.checkpoint_at_s = None;
        clean.run.checkpoint_out = None;
        clean.to_json().to_string()
    }

    // ----------------------------------------------------------- resolvers

    pub fn resolved_nodes(&self) -> Result<usize> {
        let p = preset(&self.workload.dataset)?;
        let n = if self.population.nodes > 0 {
            self.population.nodes
        } else {
            ((p.nodes as f64 * self.population.scale).round() as usize).max(8)
        };
        Ok(n)
    }

    pub fn resolved_s(&self) -> Result<usize> {
        Ok(if self.protocol.s > 0 { self.protocol.s } else { preset(&self.workload.dataset)?.s })
    }

    pub fn resolved_a(&self) -> Result<usize> {
        Ok(if self.protocol.a > 0 { self.protocol.a } else { preset(&self.workload.dataset)?.a })
    }

    /// Validate `run.progress` into the harness-level [`ProgressConfig`].
    ///
    /// Loud at build time: a non-positive or non-finite `every_s` and an
    /// unopenable `out` path are rejected here, not hours into a
    /// million-node run. The writability probe opens append+create (never
    /// truncating), so probing a resumed session's existing stream is
    /// harmless.
    pub fn progress_config(&self) -> Result<Option<ProgressConfig>> {
        let Some(p) = self.run.progress.as_ref() else {
            return Ok(None);
        };
        if !(p.every_s.is_finite() && p.every_s > 0.0) {
            bail!(
                "run.progress.every_s must be a positive finite number of seconds \
                 (got {})",
                p.every_s
            );
        }
        if let Some(out) = p.out.as_deref() {
            std::fs::OpenOptions::new()
                .append(true)
                .create(true)
                .open(out)
                .map_err(|e| {
                    anyhow::anyhow!("run.progress.out {out:?} is not writable: {e}")
                })?;
        }
        Ok(Some(ProgressConfig {
            every: SimTime::from_secs_f64(p.every_s),
            out: p.out.clone(),
        }))
    }

    // -------------------------------------------------------- churn wiring

    /// Compile the `population.availability` section (if any) into a churn
    /// schedule over this scenario's resolved population and time budget.
    /// Deterministic; uses its own labelled seed stream, so adding an
    /// availability section never perturbs the session RNG.
    pub fn availability_churn(&self) -> Result<ChurnSchedule> {
        match &self.population.availability {
            Some(av) => av.compile(self.resolved_nodes()?, self.run.seed, self.run.max_time_s),
            None => Ok(ChurnSchedule::empty()),
        }
    }

    /// Reject churn scripts that crash/leave a node id that never joins
    /// this scenario's population — at spec level, with a pointed message,
    /// instead of surfacing as a runtime protocol error (or silent phantom
    /// dead node) deep inside the session. Ids beyond the initial
    /// population are legitimate only when the same script also
    /// joins/recovers them at some point.
    pub fn validate_churn(&self, churn: &ChurnSchedule) -> Result<()> {
        let n = self.resolved_nodes()?;
        // One pass to collect the ids the script legitimately introduces,
        // so join-heavy scale scripts validate in O(E) instead of
        // rescanning the whole event list per out-of-population event.
        let joiners: std::collections::HashSet<crate::NodeId> = churn
            .events()
            .iter()
            .filter(|e| matches!(e.kind, ChurnKind::Join | ChurnKind::Recover))
            .map(|e| e.node)
            .collect();
        for e in churn.events() {
            if matches!(e.kind, ChurnKind::Crash | ChurnKind::Leave) && (e.node as usize) >= n {
                anyhow::ensure!(
                    joiners.contains(&e.node),
                    "churn script applies {:?} to node {} which never joins (initial \
                     population {n}, and the script has no Join/Recover event for it)",
                    e.kind,
                    e.node
                );
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ builders

    /// Build the learning task for this scenario. `runtime` may be `None`
    /// only for the mock dataset.
    pub fn build_task(&self, runtime: Option<&XlaRuntime>) -> Result<Box<dyn Task>> {
        self.build_task_for(runtime, self.resolved_nodes()?)
    }

    /// Build the task sized for `n` nodes (>= resolved_nodes when a churn
    /// script adds joiners whose shards must exist).
    pub fn build_task_for(
        &self,
        runtime: Option<&XlaRuntime>,
        n: usize,
    ) -> Result<Box<dyn Task>> {
        if self.workload.dataset == "mock" {
            return Ok(Box::new(MockTask::new(n.max(64), 32, 0.8, self.run.seed)));
        }
        self.build_artifact_task(runtime, n)
    }

    /// Artifact-backed datasets need the PJRT engine: without the `xla`
    /// feature this is a clear runtime error instead of a build break.
    #[cfg(not(feature = "xla"))]
    fn build_artifact_task(
        &self,
        _runtime: Option<&XlaRuntime>,
        _n: usize,
    ) -> Result<Box<dyn Task>> {
        anyhow::bail!(
            "dataset {:?} needs AOT artifacts; uncomment the `xla` dependency \
             in rust/Cargo.toml and rebuild with `--features xla`, or run with \
             the mock dataset",
            self.workload.dataset
        )
    }

    #[cfg(feature = "xla")]
    fn build_artifact_task(
        &self,
        runtime: Option<&XlaRuntime>,
        n: usize,
    ) -> Result<Box<dyn Task>> {
        use crate::data::{
            classif::ClassifParams, ratings::RatingsParams, tokens::TokensParams, ClassifData,
            RatingsData, TokensData,
        };
        use crate::learning::{TaskData, XlaTask};

        let p = preset(&self.workload.dataset)?;
        let mut rng = SimRng::new(self.run.seed).fork("data");
        let runtime = runtime.ok_or_else(|| {
            anyhow::anyhow!("dataset {} needs artifacts", self.workload.dataset)
        })?;
        let manifest = runtime.manifest().variant(p.variant)?.clone();
        let data = match manifest.kind.as_str() {
            "classifier" => {
                let classes = manifest.meta_usize("classes").unwrap_or(10);
                let input_dim = manifest.meta_usize("input_dim").unwrap_or(128);
                TaskData::Classif(ClassifData::generate(
                    &ClassifParams {
                        dim: input_dim,
                        classes,
                        nodes: n,
                        samples_per_node: p.samples_per_node,
                        test_samples: 2048,
                        partition: p.partition,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            "matfact" => {
                let users = manifest.meta_usize("users").unwrap_or(610);
                let items = manifest.meta_usize("items").unwrap_or(9724);
                TaskData::Ratings(RatingsData::generate(
                    &RatingsParams {
                        users,
                        items,
                        nodes: n,
                        ratings_per_user: p.samples_per_node,
                        test_per_user: 25,
                        sampling: self.run.sampling,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            "lm" => {
                let vocab = manifest.meta_usize("vocab").unwrap_or(64);
                let max_t = manifest.meta_usize("max_t").unwrap_or(64);
                TaskData::Tokens(TokensData::generate(
                    &TokensParams {
                        vocab,
                        seq_len: max_t,
                        nodes: n,
                        seqs_per_node: p.samples_per_node,
                        test_seqs: 128,
                        ..Default::default()
                    },
                    &mut rng,
                ))
            }
            other => anyhow::bail!("unknown variant kind {other}"),
        };
        Ok(Box::new(XlaTask::new(runtime, p.variant, data)?))
    }

    /// Build the latency geography. With no `network.latency` section this
    /// is exactly the pre-section default (same params, same seed stream),
    /// so existing configs replay bit-identically.
    pub fn build_latency(&self, n: usize) -> LatencyMatrix {
        let (params, geo_seed) = match &self.network.latency {
            Some(l) => (l.params(), l.seed.unwrap_or(self.run.seed)),
            None => (LatencyParams::default(), self.run.seed),
        };
        let mut rng = SimRng::new(geo_seed).fork("latency");
        LatencyMatrix::synthetic(&params, n, &mut rng)
    }

    /// Assemble the network fabric: synthetic geography + per-node
    /// capacities from the `network` section, both seeded from the session
    /// seed.
    pub fn build_fabric(&self, n: usize) -> Result<NetworkFabric> {
        let latency = self.build_latency(n);
        let bw = self.network.bandwidth_config()?;
        let mut rng = SimRng::new(self.run.seed).fork("bandwidth");
        let mut fabric = NetworkFabric::new(latency, &bw, n, &mut rng);
        if let Some(model) = self.network.loss_model() {
            // A dedicated stream: lossless sessions never fork it, so
            // their draw sequences — and fingerprints — are unchanged.
            fabric.set_loss(model, SimRng::new(self.run.seed).fork("loss"));
        }
        Ok(fabric)
    }

    pub fn build_compute(&self, n: usize) -> ComputeModel {
        let mut rng = SimRng::new(self.run.seed).fork("compute");
        if self.population.hetero_sigma > 0.0 {
            ComputeModel::heterogeneous(
                n,
                self.population.base_batch_s,
                self.population.hetero_sigma,
                &mut rng,
            )
        } else {
            ComputeModel::uniform(n, self.population.base_batch_s)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_shrinks_node_count() {
        let mut spec = ScenarioSpec::new("celeba", "modest");
        spec.population.scale = 0.1;
        assert_eq!(spec.resolved_nodes().unwrap(), 50);
    }

    #[test]
    fn explicit_nodes_override_scale() {
        let mut spec = ScenarioSpec::new("cifar10", "modest");
        spec.population.nodes = 24;
        spec.population.scale = 0.1;
        assert_eq!(spec.resolved_nodes().unwrap(), 24);
    }

    #[test]
    fn nested_sections_parse() {
        let spec = ScenarioSpec::from_json(
            r#"{
                "workload": {"dataset": "femnist"},
                "protocol": {"name": "dsgd", "s": 4},
                "population": {"scale": 0.2},
                "run": {"seed": 7, "max_rounds": 30},
                "network": {"bandwidth_mbps": 25.0}
            }"#,
        )
        .unwrap();
        assert_eq!(spec.workload.dataset, "femnist");
        assert_eq!(spec.protocol.name, "dsgd");
        assert_eq!(spec.protocol.s, 4);
        assert_eq!(spec.run.seed, 7);
        assert_eq!(spec.run.max_rounds, 30);
        assert!((spec.population.scale - 0.2).abs() < 1e-12);
        assert!((spec.network.bandwidth_mbps - 25.0).abs() < 1e-12);
        // defaults retained
        assert_eq!(spec.protocol.dk, 20);
    }

    #[test]
    fn flat_keys_still_parse() {
        let spec = ScenarioSpec::from_json(
            r#"{"dataset": "femnist", "algo": "dsgd", "scale": 0.2, "seed": 7,
                "bandwidth_mbps": 25.0, "bandwidth_sigma": 0.4}"#,
        )
        .unwrap();
        assert_eq!(spec.workload.dataset, "femnist");
        assert_eq!(spec.protocol.name, "dsgd");
        assert_eq!(spec.run.seed, 7);
        assert!((spec.network.bandwidth_sigma - 0.4).abs() < 1e-12);
    }

    #[test]
    fn flat_key_overrides_section() {
        // Mixed configs: flat keys are a compatibility override layer.
        let spec = ScenarioSpec::from_json(
            r#"{"seed": 9, "run": {"seed": 7, "max_rounds": 30}}"#,
        )
        .unwrap();
        assert_eq!(spec.run.seed, 9);
        assert_eq!(spec.run.max_rounds, 30);
    }

    #[test]
    fn unknown_keys_rejected_everywhere() {
        assert!(ScenarioSpec::from_json(r#"{"datset": "x"}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"run": {"sede": 1}}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"protocol": {"nmae": "x"}}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"network": {"bw": 1}}"#).is_err());
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let mut spec = ScenarioSpec::new("femnist", "gossip");
        spec.population.nodes = 32;
        spec.protocol.sf = 0.75;
        spec.protocol.params = vec![("fanout".into(), 3.0)];
        spec.run.target_metric = Some(0.8);
        spec.run.sampling = SamplingVersion::V2Partial;
        spec.run.progress =
            Some(ProgressSpec { every_s: 5.0, out: Some("/tmp/p.jsonl".into()) });
        spec.run.threads = 4;
        spec.network.bandwidth_sigma = 0.6;
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&text).unwrap();
        assert_eq!(spec, back);
    }

    #[test]
    fn run_threads_parses_defaults_and_rejects_zero() {
        // Absent = 1: every pre-existing config stays single-threaded.
        let spec = ScenarioSpec::from_json(r#"{"run": {"seed": 3}}"#).unwrap();
        assert_eq!(spec.run.threads, 1);
        let spec = ScenarioSpec::from_json(r#"{"run": {"threads": 4}}"#).unwrap();
        assert_eq!(spec.run.threads, 4);
        // Zero threads cannot execute anything: loud error, not a warning.
        let err = ScenarioSpec::from_json(r#"{"run": {"threads": 0}}"#)
            .expect_err("threads = 0 must be rejected");
        assert!(err.to_string().contains("threads"), "{err}");
        // The flat-key compat shim predates `threads` and stays frozen:
        // a flat `threads` key is unknown vocabulary.
        assert!(ScenarioSpec::from_json(r#"{"threads": 2}"#).is_err());
    }

    #[test]
    fn sampling_version_parses_nested_flat_and_defaults() {
        // Nested form.
        let spec =
            ScenarioSpec::from_json(r#"{"run": {"sampling": "v2"}}"#).unwrap();
        assert_eq!(spec.run.sampling, SamplingVersion::V2Partial);
        // Legacy flat key (overrides the section, like every flat key).
        let spec = ScenarioSpec::from_json(
            r#"{"sampling": "v2", "run": {"sampling": "v1"}}"#,
        )
        .unwrap();
        assert_eq!(spec.run.sampling, SamplingVersion::V2Partial);
        // Absent = v1, so every pre-existing config keeps its fingerprint.
        let spec = ScenarioSpec::from_json(r#"{"run": {"seed": 3}}"#).unwrap();
        assert_eq!(spec.run.sampling, SamplingVersion::V1Shuffle);
        // Unknown spellings fail loudly.
        assert!(ScenarioSpec::from_json(r#"{"run": {"sampling": "v9"}}"#).is_err());
        assert!(ScenarioSpec::from_json(r#"{"run": {"sampling": 2}}"#).is_err());
    }

    #[test]
    fn protocol_params_parse_and_lookup() {
        let spec = ScenarioSpec::from_json(
            r#"{"protocol": {"name": "gossip", "params": {"fanout": 3}}}"#,
        )
        .unwrap();
        assert_eq!(spec.protocol.param("fanout"), Some(3.0));
        assert_eq!(spec.protocol.param("absent"), None);
    }

    #[test]
    fn hetero_bandwidth_builds_spread_fabric() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.population.nodes = 16;
        spec.network.bandwidth_mbps = 10.0;
        spec.network.bandwidth_sigma = 0.6;
        let fabric = spec.build_fabric(16).unwrap();
        let min = (0..16u32).map(|n| fabric.up_bps(n)).fold(f64::MAX, f64::min);
        let max = (0..16u32).map(|n| fabric.up_bps(n)).fold(0.0f64, f64::max);
        assert!(max > min, "no heterogeneity: {min}..{max}");
        // sigma = 0 gives a flat fabric
        let flat = ScenarioSpec::new("mock", "modest").build_fabric(16).unwrap();
        for n in 0..16u32 {
            assert_eq!(flat.up_bps(n), 50e6);
            assert_eq!(flat.down_bps(n), 50e6);
        }
    }

    #[test]
    fn latency_section_shapes_the_geography() {
        use crate::sim::SimTime;
        // A one-city world with a 30ms last mile: every pair sits at
        // exactly the base cost (no propagation, jitter scales the base).
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.population.nodes = 8;
        spec.network.latency = Some(crate::scenario::LatencySpec {
            cities: 1,
            base_ms: 30.0,
            jitter: 0.0,
            ..Default::default()
        });
        let m = spec.build_latency(8);
        for a in 0..8u32 {
            for b in 0..8u32 {
                assert_eq!(m.one_way(a, b), SimTime::from_millis(30));
            }
        }
    }

    #[test]
    fn latency_seed_decouples_geography_from_run_seed() {
        let mk = |run_seed: u64, geo_seed: Option<u64>| {
            let mut spec = ScenarioSpec::new("mock", "modest");
            spec.run.seed = run_seed;
            spec.network.latency = Some(crate::scenario::LatencySpec {
                seed: geo_seed,
                ..Default::default()
            });
            spec.build_latency(16)
        };
        // Pinned geography seed: different run seeds, same geography.
        let a = mk(1, Some(99));
        let b = mk(2, Some(99));
        for i in 0..16u32 {
            assert_eq!(a.one_way(0, i), b.one_way(0, i));
        }
        // No geography seed: the run seed drives it, exactly as before.
        let c = mk(1, None);
        let d = mk(2, None);
        assert!((0..16u32).any(|i| c.one_way(0, i) != d.one_way(0, i)));
    }

    #[test]
    fn availability_parses_nested_and_compiles() {
        let spec = ScenarioSpec::from_json(
            r#"{
                "workload": {"dataset": "mock"},
                "population": {"nodes": 40, "availability": {
                    "model": "step", "amplitude": 0.5, "period_s": 60.0, "seed": 2}},
                "run": {"max_time_s": 100.0}
            }"#,
        )
        .unwrap();
        let av = spec.population.availability.as_ref().expect("availability parsed");
        assert_eq!(av.period_s, 60.0);
        let churn = spec.availability_churn().unwrap();
        // One down-step at t = 30 for 20 of 40 nodes; the up-step at 60
        // and the next down-step at 90 are also inside the horizon.
        assert!(!churn.is_empty());
        assert!(churn.events().iter().all(|e| (e.node as usize) < 40));
        // Explicit null and absence both mean "no availability".
        let spec =
            ScenarioSpec::from_json(r#"{"population": {"availability": null}}"#).unwrap();
        assert!(spec.population.availability.is_none());
        assert!(spec.availability_churn().unwrap().is_empty());
        // Bad sections fail at parse.
        assert!(ScenarioSpec::from_json(
            r#"{"population": {"availability": {"model": "nope"}}}"#
        )
        .is_err());
    }

    #[test]
    fn validate_churn_rejects_never_joining_targets() {
        use crate::sim::{ChurnEvent, ChurnKind, SimTime};
        let mut spec = ScenarioSpec::new("mock", "gossip");
        spec.population.nodes = 10;
        let orphan = ChurnSchedule::new(vec![ChurnEvent {
            at: SimTime::from_secs_f64(1.0),
            node: 42,
            kind: ChurnKind::Leave,
        }]);
        let err = spec.validate_churn(&orphan).unwrap_err();
        assert!(err.to_string().contains("never joins"), "{err:#}");
        // In-population targets and joined-then-crashed ids are fine.
        let ok = ChurnSchedule::new(vec![
            ChurnEvent { at: SimTime::from_secs_f64(1.0), node: 3, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_secs_f64(2.0), node: 42, kind: ChurnKind::Join },
            ChurnEvent { at: SimTime::from_secs_f64(3.0), node: 42, kind: ChurnKind::Crash },
        ]);
        assert!(spec.validate_churn(&ok).is_ok());
    }

    #[test]
    fn mock_task_builds_without_artifacts() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.population.nodes = 12;
        assert!(spec.build_task(None).is_ok());
    }

    #[test]
    fn progress_parses_nested_null_and_rejects_unknown_keys() {
        let spec = ScenarioSpec::from_json(
            r#"{"run": {"progress": {"every_s": 5.0, "out": "p.jsonl"}}}"#,
        )
        .unwrap();
        let p = spec.run.progress.as_ref().expect("progress parsed");
        assert_eq!(p.every_s, 5.0);
        assert_eq!(p.out.as_deref(), Some("p.jsonl"));
        // `out` is optional (stderr) and `null` disables the section.
        let spec =
            ScenarioSpec::from_json(r#"{"run": {"progress": {"every_s": 2.0}}}"#).unwrap();
        assert_eq!(spec.run.progress.as_ref().unwrap().out, None);
        let spec = ScenarioSpec::from_json(r#"{"run": {"progress": null}}"#).unwrap();
        assert!(spec.run.progress.is_none());
        // every_s is mandatory; unknown keys fail loudly.
        assert!(ScenarioSpec::from_json(r#"{"run": {"progress": {}}}"#).is_err());
        assert!(ScenarioSpec::from_json(
            r#"{"run": {"progress": {"every_s": 5.0, "evry": 1}}}"#
        )
        .is_err());
    }

    #[test]
    fn progress_config_rejects_nonpositive_every() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.run.progress = Some(ProgressSpec { every_s: 0.0, out: None });
        let err = spec.progress_config().unwrap_err();
        assert!(err.to_string().contains("positive finite"), "{err:#}");
        spec.run.progress = Some(ProgressSpec { every_s: -3.0, out: None });
        assert!(spec.progress_config().is_err());
    }

    #[test]
    fn progress_config_rejects_non_finite_every() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.run.progress = Some(ProgressSpec { every_s: f64::NAN, out: None });
        assert!(spec.progress_config().is_err());
        spec.run.progress = Some(ProgressSpec { every_s: f64::INFINITY, out: None });
        assert!(spec.progress_config().is_err());
    }

    #[test]
    fn progress_config_rejects_unwritable_out() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.run.progress = Some(ProgressSpec {
            every_s: 5.0,
            out: Some("/nonexistent_dir_modest_obs/x.jsonl".into()),
        });
        let err = spec.progress_config().unwrap_err();
        assert!(err.to_string().contains("not writable"), "{err:#}");
    }

    #[test]
    fn progress_config_accepts_writable_out_without_truncating() {
        let path = std::env::temp_dir().join("modest_spec_progress_probe.jsonl");
        std::fs::write(&path, "existing line\n").unwrap();
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.run.progress = Some(ProgressSpec {
            every_s: 5.0,
            out: Some(path.to_str().unwrap().to_string()),
        });
        let cfg = spec.progress_config().unwrap().expect("config built");
        assert_eq!(cfg.every, SimTime::from_secs_f64(5.0));
        // The writability probe must not clobber an existing stream.
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "existing line\n");
        std::fs::remove_file(&path).ok();
        // Absent progress builds to None with no side effects.
        assert!(ScenarioSpec::new("mock", "modest").progress_config().unwrap().is_none());
    }

    #[test]
    fn snapshot_json_keeps_progress_but_clears_checkpoint() {
        let mut spec = ScenarioSpec::new("mock", "modest");
        spec.run.checkpoint_at_s = Some(10.0);
        spec.run.checkpoint_out = Some("snap.bin".into());
        spec.run.progress = Some(ProgressSpec { every_s: 5.0, out: Some("p.jsonl".into()) });
        let back = ScenarioSpec::from_json(&spec.snapshot_json()).unwrap();
        assert!(back.run.checkpoint_at_s.is_none());
        assert!(back.run.checkpoint_out.is_none());
        // The resumed session must keep streaming to the same file.
        assert_eq!(back.run.progress, spec.run.progress);
    }
}
