//! The `network` section of a scenario: first-class shaping of per-node
//! capacities, compiled down to [`net::BandwidthConfig`](crate::net::BandwidthConfig).
//!
//! The old flat `SessionSpec` could only express symmetric capacities
//! (`bandwidth_mbps` + lognormal `bandwidth_sigma`); the full fabric
//! vocabulary — weighted **asymmetric up/down tiers** (FCC/speedtest-style
//! cable / DSL / fiber classes) and explicit per-node trace playback — was
//! reachable only programmatically. [`NetworkSpec`] exposes all four modes
//! declaratively:
//!
//! ```json
//! "network": {
//!   "bandwidth_mbps": 50.0,
//!   "bandwidth_sigma": 0.0,
//!   "classes": [
//!     {"name": "fiber", "weight": 0.2, "up_mbps": 100.0, "down_mbps": 300.0},
//!     {"name": "cable", "weight": 0.5, "up_mbps": 10.0,  "down_mbps": 100.0},
//!     {"name": "dsl",   "weight": 0.3, "up_mbps": 1.5,   "down_mbps": 12.0}
//!   ],
//!   "trace_file": null
//! }
//! ```
//!
//! Precedence: `trace_file` > `classes` > `bandwidth_sigma` (lognormal) >
//! `bandwidth_mbps` (uniform). Trace files are CSV, one node per line,
//! `up_mbps,down_mbps` (a single column means symmetric); `#` comments and
//! an alphabetic header line are skipped.

use anyhow::{anyhow, bail, Context, Result};

use crate::net::{BandwidthClass, BandwidthConfig, LatencyParams, LossModel};
use crate::sim::{ReliabilityConfig, SimTime};
use crate::util::Json;

/// The `network.latency` section: knobs of the synthetic WAN geography
/// (ROADMAP item — latency shaping used to be reachable only
/// programmatically while bandwidth was already declarative).
///
/// ```json
/// "latency": {"cities": 64, "base_ms": 2.0, "inflation": 1.6,
///             "jitter": 0.15, "seed": 9}
/// ```
///
/// Every field is optional; defaults mirror [`LatencyParams::default`].
/// `seed` decouples the geography from the run seed (absent = derive from
/// `run.seed` exactly as before, so existing configs replay identically).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySpec {
    /// Number of distinct cities nodes are assigned to round-robin.
    pub cities: usize,
    /// Fixed last-mile cost added to every one-way latency, in ms.
    pub base_ms: f64,
    /// Route inflation over great-circle distance.
    pub inflation: f64,
    /// Relative jitter amplitude per city pair (0.1 = ±10%).
    pub jitter: f64,
    /// Independent geography seed; `null`/absent = derive from `run.seed`.
    pub seed: Option<u64>,
}

impl Default for LatencySpec {
    fn default() -> Self {
        let p = LatencyParams::default();
        LatencySpec {
            cities: p.cities,
            base_ms: p.base_s * 1e3,
            inflation: p.inflation,
            jitter: p.jitter,
            seed: None,
        }
    }
}

impl LatencySpec {
    pub fn from_json(v: &Json) -> Result<LatencySpec> {
        let mut out = LatencySpec::default();
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "cities" => out.cities = val.as_usize()?,
                "base_ms" => out.base_ms = val.as_f64()?,
                "inflation" => out.inflation = val.as_f64()?,
                "jitter" => out.jitter = val.as_f64()?,
                "seed" => {
                    out.seed = if *val == Json::Null { None } else { Some(val.as_u64()?) }
                }
                other => bail!("unknown latency key {other:?}"),
            }
        }
        anyhow::ensure!(out.cities > 0, "latency.cities must be > 0");
        anyhow::ensure!(
            out.base_ms.is_finite() && out.base_ms >= 0.0,
            "latency.base_ms must be a finite non-negative number, got {}",
            out.base_ms
        );
        anyhow::ensure!(
            out.inflation.is_finite() && out.inflation > 0.0,
            "latency.inflation must be a finite positive number, got {}",
            out.inflation
        );
        anyhow::ensure!(
            out.jitter.is_finite() && (0.0..1.0).contains(&out.jitter),
            "latency.jitter must be in [0, 1), got {}",
            out.jitter
        );
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cities", Json::Num(self.cities as f64)),
            ("base_ms", Json::Num(self.base_ms)),
            ("inflation", Json::Num(self.inflation)),
            ("jitter", Json::Num(self.jitter)),
            (
                "seed",
                match self.seed {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// The geography parameters this section describes.
    pub fn params(&self) -> LatencyParams {
        LatencyParams {
            cities: self.cities,
            base_s: self.base_ms / 1e3,
            inflation: self.inflation,
            jitter: self.jitter,
        }
    }
}

/// One capacity tier of `network.classes`: asymmetric up/down rates with a
/// relative sampling weight (weights need not sum to 1).
#[derive(Debug, Clone, PartialEq)]
pub struct TierSpec {
    /// Display name ("fiber", "cable", ...) — documentation only.
    pub name: String,
    pub weight: f64,
    pub up_mbps: f64,
    pub down_mbps: f64,
}

impl TierSpec {
    pub fn from_json(v: &Json) -> Result<TierSpec> {
        let mut name = String::new();
        let mut weight = 1.0;
        let mut up_mbps = None;
        let mut down_mbps = None;
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "name" => name = val.as_str()?.to_string(),
                "weight" => weight = val.as_f64()?,
                "up_mbps" => up_mbps = Some(val.as_f64()?),
                "down_mbps" => down_mbps = Some(val.as_f64()?),
                other => bail!("unknown bandwidth-class key {other:?}"),
            }
        }
        let up = up_mbps.ok_or_else(|| anyhow!("bandwidth class missing up_mbps"))?;
        // A tier with only `up_mbps` is symmetric.
        let down = down_mbps.unwrap_or(up);
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "bandwidth class weight must be a finite number > 0, got {weight}"
        );
        anyhow::ensure!(up >= 0.0 && down >= 0.0, "negative capacity in class {name:?}");
        Ok(TierSpec { name, weight, up_mbps: up, down_mbps: down })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("weight", Json::Num(self.weight)),
            ("up_mbps", Json::Num(self.up_mbps)),
            ("down_mbps", Json::Num(self.down_mbps)),
        ])
    }
}

/// Which fault-injection model the `network.loss` section describes.
#[derive(Debug, Clone, PartialEq)]
pub enum LossModelSpec {
    /// Flat per-message drop probability on every link.
    Uniform { p: f64 },
    /// Per-tier drop probabilities riding the `network.classes` bandwidth
    /// tiers (entry `i` applies to nodes assigned tier `i`); a transfer is
    /// dropped when either endpoint's tier loses it.
    Classes { tiers: Vec<f64> },
    /// Two-state Gilbert–Elliott channel per receiver: exponential dwell
    /// times in a good and a bad state, each with its own drop probability.
    Burst { p_good: f64, p_bad: f64, good_s: f64, bad_s: f64 },
}

/// The `network.loss` section: deterministic per-message fault injection
/// plus the timeout/retransmit/backoff contract every protocol's
/// reliability layer runs under.
///
/// ```json
/// "loss": {"model": "burst", "p_good": 0.01, "p_bad": 0.5,
///          "good_s": 10.0, "bad_s": 2.0,
///          "timeout_s": 2.0, "backoff": 2.0, "max_timeout_s": 30.0,
///          "retries": 3}
/// ```
///
/// A lossless section (`p = 0` everywhere) compiles to *no* loss layer and
/// *no* reliability layer, so such sessions replay pre-loss same-seed
/// fingerprints bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct LossSpec {
    pub model: LossModelSpec,
    /// Ack timeout (seconds) before the first retransmit.
    pub timeout_s: f64,
    /// Multiplicative backoff factor applied per retransmit (>= 1).
    pub backoff: f64,
    /// Ceiling on the backed-off retransmit timeout (seconds).
    pub max_timeout_s: f64,
    /// Retransmit cap: after this many retries the message expires and the
    /// protocol's degradation path runs.
    pub retries: u32,
}

impl LossSpec {
    pub fn from_json(v: &Json) -> Result<LossSpec> {
        let mut model = String::from("uniform");
        let mut p: Option<f64> = None;
        let mut tiers: Option<Vec<f64>> = None;
        let mut p_good: Option<f64> = None;
        let mut p_bad: Option<f64> = None;
        let mut good_s: Option<f64> = None;
        let mut bad_s: Option<f64> = None;
        let mut timeout_s = 2.0;
        let mut backoff = 2.0;
        let mut max_timeout_s = 30.0;
        let mut retries = 3u64;
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "model" => model = val.as_str()?.to_string(),
                "p" => p = Some(val.as_f64()?),
                "tiers" => {
                    tiers = Some(
                        val.as_arr()?
                            .iter()
                            .map(Json::as_f64)
                            .collect::<Result<Vec<_>>>()?,
                    )
                }
                "p_good" => p_good = Some(val.as_f64()?),
                "p_bad" => p_bad = Some(val.as_f64()?),
                "good_s" => good_s = Some(val.as_f64()?),
                "bad_s" => bad_s = Some(val.as_f64()?),
                "timeout_s" => timeout_s = val.as_f64()?,
                "backoff" => backoff = val.as_f64()?,
                "max_timeout_s" => max_timeout_s = val.as_f64()?,
                "retries" => retries = val.as_u64()?,
                other => bail!("unknown loss key {other:?}"),
            }
        }
        let check_p = |name: &str, v: f64| -> Result<()> {
            anyhow::ensure!(
                v.is_finite() && (0.0..=1.0).contains(&v),
                "loss.{name} must be a drop probability in [0, 1], got {v}"
            );
            Ok(())
        };
        let check_dwell = |name: &str, v: f64| -> Result<()> {
            anyhow::ensure!(
                v.is_finite() && v > 0.0,
                "loss.{name} must be a finite positive dwell time in seconds, got {v}"
            );
            Ok(())
        };
        let model = match model.as_str() {
            "uniform" => {
                anyhow::ensure!(
                    tiers.is_none()
                        && p_good.is_none()
                        && p_bad.is_none()
                        && good_s.is_none()
                        && bad_s.is_none(),
                    "loss model \"uniform\" takes only \"p\" (classes/burst keys present)"
                );
                let p = p.unwrap_or(0.0);
                check_p("p", p)?;
                LossModelSpec::Uniform { p }
            }
            "classes" => {
                anyhow::ensure!(
                    p.is_none()
                        && p_good.is_none()
                        && p_bad.is_none()
                        && good_s.is_none()
                        && bad_s.is_none(),
                    "loss model \"classes\" takes only \"tiers\" (uniform/burst keys present)"
                );
                let tiers = tiers.ok_or_else(|| {
                    anyhow!(
                        "loss model \"classes\" needs a \"tiers\" array of per-tier drop \
                         probabilities"
                    )
                })?;
                anyhow::ensure!(!tiers.is_empty(), "loss.tiers must not be empty");
                for (i, &t) in tiers.iter().enumerate() {
                    check_p(&format!("tiers[{i}]"), t)?;
                }
                LossModelSpec::Classes { tiers }
            }
            "burst" => {
                anyhow::ensure!(
                    p.is_none() && tiers.is_none(),
                    "loss model \"burst\" takes p_good/p_bad/good_s/bad_s \
                     (uniform/classes keys present)"
                );
                let p_good = p_good.unwrap_or(0.0);
                let p_bad = p_bad.unwrap_or(0.0);
                let good_s = good_s.unwrap_or(10.0);
                let bad_s = bad_s.unwrap_or(1.0);
                check_p("p_good", p_good)?;
                check_p("p_bad", p_bad)?;
                check_dwell("good_s", good_s)?;
                check_dwell("bad_s", bad_s)?;
                LossModelSpec::Burst { p_good, p_bad, good_s, bad_s }
            }
            other => bail!(
                "unknown loss model {other:?} (expected \"uniform\", \"classes\", or \"burst\")"
            ),
        };
        anyhow::ensure!(
            timeout_s.is_finite() && timeout_s > 0.0,
            "loss.timeout_s must be a finite positive number of seconds, got {timeout_s}"
        );
        anyhow::ensure!(
            backoff.is_finite() && backoff >= 1.0,
            "loss.backoff must be a finite factor >= 1, got {backoff}"
        );
        anyhow::ensure!(
            max_timeout_s.is_finite() && max_timeout_s >= timeout_s,
            "loss.max_timeout_s must be >= timeout_s ({timeout_s}), got {max_timeout_s}"
        );
        anyhow::ensure!(
            (1..=u32::MAX as u64).contains(&retries),
            "loss.retries must be in [1, {}], got {retries} (remove the loss section to \
             disable retransmits entirely)",
            u32::MAX
        );
        Ok(LossSpec {
            model,
            timeout_s,
            backoff,
            max_timeout_s,
            retries: retries as u32,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = match &self.model {
            LossModelSpec::Uniform { p } => vec![
                ("model", Json::Str("uniform".into())),
                ("p", Json::Num(*p)),
            ],
            LossModelSpec::Classes { tiers } => vec![
                ("model", Json::Str("classes".into())),
                ("tiers", Json::Arr(tiers.iter().map(|&t| Json::Num(t)).collect())),
            ],
            LossModelSpec::Burst { p_good, p_bad, good_s, bad_s } => vec![
                ("model", Json::Str("burst".into())),
                ("p_good", Json::Num(*p_good)),
                ("p_bad", Json::Num(*p_bad)),
                ("good_s", Json::Num(*good_s)),
                ("bad_s", Json::Num(*bad_s)),
            ],
        };
        kv.push(("timeout_s", Json::Num(self.timeout_s)));
        kv.push(("backoff", Json::Num(self.backoff)));
        kv.push(("max_timeout_s", Json::Num(self.max_timeout_s)));
        kv.push(("retries", Json::Num(self.retries as f64)));
        Json::obj(kv)
    }

    /// `true` when every drop probability is exactly zero — the section is
    /// then compiled away entirely (no loss layer, no reliability layer, no
    /// extra RNG stream), preserving pre-loss fingerprints bit-for-bit.
    pub fn is_lossless(&self) -> bool {
        match &self.model {
            LossModelSpec::Uniform { p } => *p == 0.0,
            LossModelSpec::Classes { tiers } => tiers.iter().all(|&t| t == 0.0),
            LossModelSpec::Burst { p_good, p_bad, .. } => *p_good == 0.0 && *p_bad == 0.0,
        }
    }
}

/// The `network` section of a [`super::ScenarioSpec`].
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    /// Median symmetric per-node capacity in Mbit/s (uniform / lognormal
    /// modes; ignored when `classes` or `trace_file` is set).
    pub bandwidth_mbps: f64,
    /// Capacity heterogeneity: lognormal sigma around `bandwidth_mbps`
    /// (0 = every node identical).
    pub bandwidth_sigma: f64,
    /// Weighted asymmetric capacity tiers; non-empty wins over the scalar
    /// knobs.
    pub classes: Vec<TierSpec>,
    /// Per-node capacity trace (CSV `up_mbps,down_mbps` per node); wins
    /// over everything else.
    pub trace_file: Option<String>,
    /// Synthetic WAN geography shaping; absent = the built-in defaults
    /// seeded from `run.seed` (bit-identical to pre-section behaviour).
    pub latency: Option<LatencySpec>,
    /// Per-message fault injection + reliability contract; absent (or
    /// all-zero) = today's exactly-once delivery, bit-identical.
    pub loss: Option<LossSpec>,
}

impl Default for NetworkSpec {
    fn default() -> Self {
        NetworkSpec {
            bandwidth_mbps: 50.0,
            bandwidth_sigma: 0.0,
            classes: Vec::new(),
            trace_file: None,
            latency: None,
            loss: None,
        }
    }
}

impl NetworkSpec {
    pub fn from_json(v: &Json) -> Result<NetworkSpec> {
        let mut out = NetworkSpec::default();
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "bandwidth_mbps" => out.bandwidth_mbps = val.as_f64()?,
                "bandwidth_sigma" => out.bandwidth_sigma = val.as_f64()?,
                "classes" => {
                    out.classes = val
                        .as_arr()?
                        .iter()
                        .map(TierSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                }
                "trace_file" => {
                    out.trace_file = if *val == Json::Null {
                        None
                    } else {
                        Some(val.as_str()?.to_string())
                    }
                }
                "latency" => {
                    out.latency = if *val == Json::Null {
                        None
                    } else {
                        Some(LatencySpec::from_json(val)?)
                    }
                }
                "loss" => {
                    out.loss = if *val == Json::Null {
                        None
                    } else {
                        Some(LossSpec::from_json(val)?)
                    }
                }
                other => bail!("unknown network key {other:?}"),
            }
        }
        if let Some(LossSpec { model: LossModelSpec::Classes { tiers }, .. }) = &out.loss {
            anyhow::ensure!(
                !out.classes.is_empty(),
                "loss model \"classes\" needs network.classes bandwidth tiers to ride on, \
                 but none are configured"
            );
            anyhow::ensure!(
                tiers.len() == out.classes.len(),
                "loss.tiers has {} entries but network.classes has {} tiers — they must \
                 match one-to-one",
                tiers.len(),
                out.classes.len()
            );
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bandwidth_mbps", Json::Num(self.bandwidth_mbps)),
            ("bandwidth_sigma", Json::Num(self.bandwidth_sigma)),
            (
                "classes",
                Json::Arr(self.classes.iter().map(TierSpec::to_json).collect()),
            ),
            (
                "trace_file",
                match &self.trace_file {
                    Some(p) => Json::Str(p.clone()),
                    None => Json::Null,
                },
            ),
            (
                "latency",
                match &self.latency {
                    Some(l) => l.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "loss",
                match &self.loss {
                    Some(l) => l.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Compile the loss section into the fabric's runtime drop model.
    /// `None` when the section is absent *or* lossless — those sessions run
    /// with no loss layer at all and replay pre-loss fingerprints.
    pub fn loss_model(&self) -> Option<LossModel> {
        let spec = self.loss.as_ref()?;
        if spec.is_lossless() {
            return None;
        }
        Some(match &spec.model {
            LossModelSpec::Uniform { p } => LossModel::Uniform { p: *p },
            LossModelSpec::Classes { tiers } => LossModel::Classes { tier_p: tiers.clone() },
            LossModelSpec::Burst { p_good, p_bad, good_s, bad_s } => LossModel::Burst {
                p_good: *p_good,
                p_bad: *p_bad,
                good_mean_s: *good_s,
                bad_mean_s: *bad_s,
            },
        })
    }

    /// The ack/timeout/retransmit contract protocols run under, present
    /// exactly when [`Self::loss_model`] is.
    pub fn reliability(&self) -> Option<ReliabilityConfig> {
        self.loss_model()?;
        let spec = self.loss.as_ref().expect("loss_model implies loss spec");
        Some(ReliabilityConfig {
            timeout: SimTime::from_secs_f64(spec.timeout_s),
            backoff: spec.backoff,
            max_timeout: SimTime::from_secs_f64(spec.max_timeout_s),
            retries: spec.retries,
        })
    }

    /// Compile this section into the per-node capacity distribution the
    /// fabric samples from. Fails only on an unreadable/malformed trace.
    pub fn bandwidth_config(&self) -> Result<BandwidthConfig> {
        if let Some(path) = &self.trace_file {
            return load_trace(path);
        }
        if !self.classes.is_empty() {
            return Ok(BandwidthConfig::Classes(
                self.classes
                    .iter()
                    .map(|t| BandwidthClass {
                        weight: t.weight,
                        up_bps: t.up_mbps * 1e6,
                        down_bps: t.down_mbps * 1e6,
                    })
                    .collect(),
            ));
        }
        anyhow::ensure!(
            self.bandwidth_mbps > 0.0,
            "bandwidth_mbps must be > 0, got {}",
            self.bandwidth_mbps
        );
        if self.bandwidth_sigma > 0.0 {
            Ok(BandwidthConfig::LogNormal {
                median_bps: self.bandwidth_mbps * 1e6,
                sigma: self.bandwidth_sigma,
            })
        } else {
            Ok(BandwidthConfig::Uniform { bps: self.bandwidth_mbps * 1e6 })
        }
    }
}

/// Parse a capacity trace file into [`BandwidthConfig::PerNode`].
fn load_trace(path: &str) -> Result<BandwidthConfig> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bandwidth trace {path:?}"))?;
    parse_trace(&text).with_context(|| format!("parsing bandwidth trace {path:?}"))
}

/// CSV body: `up_mbps[,down_mbps]` per node, with the shared trace
/// envelope (`#` comments, optional alphabetic header tolerated only
/// before the first data row — so a typoed first data row errors instead
/// of silently shifting every node's capacities by one; line-numbered
/// errors): [`crate::util::parse_trace_rows`].
fn parse_trace(text: &str) -> Result<BandwidthConfig> {
    let mut up_bps = Vec::new();
    let mut down_bps = Vec::new();
    crate::util::parse_trace_rows(text, parse_trace_row, |lineno, (up, down)| {
        anyhow::ensure!(
            up >= 0.0 && down >= 0.0,
            "negative capacity on trace line {lineno}"
        );
        up_bps.push(up * 1e6);
        down_bps.push(down * 1e6);
        Ok(())
    })?;
    anyhow::ensure!(!up_bps.is_empty(), "trace holds no capacity rows");
    Ok(BandwidthConfig::PerNode { up_bps, down_bps })
}

/// One `up[,down]` row; a single column means symmetric.
fn parse_trace_row(line: &str) -> Result<(f64, f64)> {
    let mut cols = line.split(',').map(str::trim);
    let up: f64 = cols
        .next()
        .filter(|s| !s.is_empty())
        .ok_or_else(|| anyhow!("empty row"))?
        .parse()
        .map_err(|e| anyhow!("bad up_mbps: {e}"))?;
    let down: f64 = match cols.next().filter(|s| !s.is_empty()) {
        Some(s) => s.parse().map_err(|e| anyhow!("bad down_mbps: {e}"))?,
        None => up,
    };
    Ok((up, down))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_flat_50mbps() {
        let cfg = NetworkSpec::default().bandwidth_config().unwrap();
        match cfg {
            BandwidthConfig::Uniform { bps } => assert_eq!(bps, 50e6),
            other => panic!("expected Uniform, got {other:?}"),
        }
    }

    #[test]
    fn sigma_selects_lognormal() {
        let spec = NetworkSpec { bandwidth_sigma: 0.5, ..Default::default() };
        match spec.bandwidth_config().unwrap() {
            BandwidthConfig::LogNormal { median_bps, sigma } => {
                assert_eq!(median_bps, 50e6);
                assert_eq!(sigma, 0.5);
            }
            other => panic!("expected LogNormal, got {other:?}"),
        }
    }

    #[test]
    fn classes_parse_with_asymmetric_tiers() {
        let v = Json::parse(
            r#"{"classes": [
                {"name": "cable", "weight": 2.0, "up_mbps": 10.0, "down_mbps": 100.0},
                {"name": "dsl", "weight": 1.0, "up_mbps": 1.5, "down_mbps": 12.0}
            ]}"#,
        )
        .unwrap();
        let spec = NetworkSpec::from_json(&v).unwrap();
        assert_eq!(spec.classes.len(), 2);
        match spec.bandwidth_config().unwrap() {
            BandwidthConfig::Classes(cs) => {
                assert_eq!(cs[0].up_bps, 10e6);
                assert_eq!(cs[0].down_bps, 100e6);
                assert_eq!(cs[1].weight, 1.0);
            }
            other => panic!("expected Classes, got {other:?}"),
        }
    }

    #[test]
    fn tier_with_only_up_is_symmetric() {
        let v = Json::parse(r#"{"weight": 1.0, "up_mbps": 25.0}"#).unwrap();
        let t = TierSpec::from_json(&v).unwrap();
        assert_eq!(t.up_mbps, 25.0);
        assert_eq!(t.down_mbps, 25.0);
    }

    #[test]
    fn unknown_keys_rejected() {
        let v = Json::parse(r#"{"bandwidht_mbps": 1.0}"#).unwrap();
        assert!(NetworkSpec::from_json(&v).is_err());
        let t = Json::parse(r#"{"up_mbps": 1.0, "wieght": 2.0}"#).unwrap();
        assert!(TierSpec::from_json(&t).is_err());
    }

    #[test]
    fn trace_parses_csv_with_header_and_comments() {
        let cfg = parse_trace(
            "# FCC sample\nup_mbps,down_mbps\n10.0,100.0\n1.5,12\n25\n",
        )
        .unwrap();
        match cfg {
            BandwidthConfig::PerNode { up_bps, down_bps } => {
                assert_eq!(up_bps, vec![10e6, 1.5e6, 25e6]);
                assert_eq!(down_bps, vec![100e6, 12e6, 25e6]);
            }
            other => panic!("expected PerNode, got {other:?}"),
        }
    }

    #[test]
    fn empty_or_bad_traces_fail() {
        assert!(parse_trace("# nothing\n").is_err());
        assert!(parse_trace("10.0\nnot-a-number,5\n").is_err());
        assert!(parse_trace("10.0,-5\n").is_err());
        // A typoed FIRST data row must not be mistaken for a header — that
        // would silently shift every node's capacity assignment by one.
        assert!(parse_trace("1O.0,100\n2,3\n").is_err());
        assert!(load_trace("/definitely/not/a/file.csv").is_err());
    }

    #[test]
    fn scientific_notation_rows_are_data_not_headers() {
        // "1e1" contains a letter but is a valid f64 — it must not be
        // mistaken for a header row and dropped.
        match parse_trace("1e1,1e2\n2,3\n").unwrap() {
            BandwidthConfig::PerNode { up_bps, down_bps } => {
                assert_eq!(up_bps, vec![10e6, 2e6]);
                assert_eq!(down_bps, vec![100e6, 3e6]);
            }
            other => panic!("expected PerNode, got {other:?}"),
        }
    }

    #[test]
    fn latency_section_parses_and_validates() {
        let v = Json::parse(
            r#"{"latency": {"cities": 32, "base_ms": 2.5, "jitter": 0.1, "seed": 7}}"#,
        )
        .unwrap();
        let spec = NetworkSpec::from_json(&v).unwrap();
        let l = spec.latency.expect("latency parsed");
        assert_eq!(l.cities, 32);
        assert!((l.base_ms - 2.5).abs() < 1e-12);
        assert!((l.inflation - 1.6).abs() < 1e-12); // default retained
        assert_eq!(l.seed, Some(7));
        let p = l.params();
        assert_eq!(p.cities, 32);
        assert!((p.base_s - 0.0025).abs() < 1e-12);

        // Bad values are rejected with clear errors.
        for bad in [
            r#"{"latency": {"cities": 0}}"#,
            r#"{"latency": {"base_ms": -1.0}}"#,
            r#"{"latency": {"jitter": 1.5}}"#,
            r#"{"latency": {"inflation": 0.0}}"#,
            r#"{"latency": {"citties": 3}}"#,
        ] {
            assert!(
                NetworkSpec::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted {bad}"
            );
        }
    }

    #[test]
    fn roundtrips_through_json() {
        let spec = NetworkSpec {
            bandwidth_mbps: 25.0,
            bandwidth_sigma: 0.0,
            classes: vec![TierSpec {
                name: "fiber".into(),
                weight: 1.0,
                up_mbps: 100.0,
                down_mbps: 300.0,
            }],
            trace_file: None,
            latency: Some(LatencySpec { cities: 12, seed: Some(3), ..Default::default() }),
            loss: Some(LossSpec {
                model: LossModelSpec::Classes { tiers: vec![0.25] },
                timeout_s: 1.5,
                backoff: 1.5,
                max_timeout_s: 20.0,
                retries: 4,
            }),
        };
        let back = NetworkSpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(spec, back);
    }

    fn parse_loss(body: &str) -> Result<NetworkSpec> {
        NetworkSpec::from_json(&Json::parse(body).unwrap())
    }

    #[test]
    fn loss_section_parses_every_model() {
        let s = parse_loss(r#"{"loss": {"model": "uniform", "p": 0.2}}"#).unwrap();
        assert_eq!(
            s.loss.as_ref().unwrap().model,
            LossModelSpec::Uniform { p: 0.2 }
        );
        assert!(matches!(s.loss_model(), Some(LossModel::Uniform { p }) if p == 0.2));
        let rel = s.reliability().unwrap();
        assert_eq!(rel.timeout, SimTime::from_secs_f64(2.0));
        assert_eq!(rel.retries, 3);

        // "model" defaults to uniform; bare {"p": ...} works.
        let s = parse_loss(r#"{"loss": {"p": 0.1}}"#).unwrap();
        assert_eq!(s.loss.as_ref().unwrap().model, LossModelSpec::Uniform { p: 0.1 });

        let s = parse_loss(
            r#"{"classes": [{"weight": 1.0, "up_mbps": 10.0, "down_mbps": 50.0},
                            {"weight": 1.0, "up_mbps": 1.0, "down_mbps": 8.0}],
                "loss": {"model": "classes", "tiers": [0.0, 0.3]}}"#,
        )
        .unwrap();
        assert!(
            matches!(s.loss_model(), Some(LossModel::Classes { ref tier_p }) if tier_p == &[0.0, 0.3])
        );

        let s = parse_loss(
            r#"{"loss": {"model": "burst", "p_good": 0.01, "p_bad": 0.5,
                         "good_s": 30.0, "bad_s": 3.0,
                         "timeout_s": 1.0, "backoff": 3.0, "max_timeout_s": 10.0,
                         "retries": 2}}"#,
        )
        .unwrap();
        assert!(matches!(
            s.loss_model(),
            Some(LossModel::Burst { p_bad, bad_mean_s, .. }) if p_bad == 0.5 && bad_mean_s == 3.0
        ));
        let rel = s.reliability().unwrap();
        assert_eq!(rel.backoff, 3.0);
        assert_eq!(rel.retries, 2);
    }

    #[test]
    fn lossless_sections_compile_away() {
        // Absent, null, and all-zero sections all yield no loss model and
        // no reliability layer — the bit-identical replay guarantee.
        for body in [
            r#"{}"#,
            r#"{"loss": null}"#,
            r#"{"loss": {"model": "uniform", "p": 0.0}}"#,
            r#"{"loss": {"model": "burst", "p_good": 0.0, "p_bad": 0.0}}"#,
        ] {
            let s = parse_loss(body).unwrap();
            assert!(s.loss_model().is_none(), "{body} produced a loss model");
            assert!(s.reliability().is_none(), "{body} produced a reliability cfg");
        }
        let s = parse_loss(
            r#"{"classes": [{"weight": 1.0, "up_mbps": 10.0, "down_mbps": 50.0}],
                "loss": {"model": "classes", "tiers": [0.0]}}"#,
        )
        .unwrap();
        assert!(s.loss_model().is_none());
    }

    #[test]
    fn loss_probabilities_outside_unit_interval_fail_loudly() {
        for (body, needle) in [
            (r#"{"loss": {"p": 1.5}}"#, "loss.p must be a drop probability in [0, 1]"),
            (r#"{"loss": {"p": -0.1}}"#, "loss.p must be a drop probability in [0, 1]"),
            (
                r#"{"loss": {"model": "burst", "p_good": 2.0}}"#,
                "loss.p_good must be a drop probability in [0, 1]",
            ),
            (
                r#"{"loss": {"model": "burst", "p_bad": -1.0}}"#,
                "loss.p_bad must be a drop probability in [0, 1]",
            ),
        ] {
            let err = parse_loss(body).unwrap_err().to_string();
            assert!(err.contains(needle), "{body}: {err}");
        }
        // Out-of-range tier probabilities name the offending index.
        let err = parse_loss(
            r#"{"classes": [{"weight": 1.0, "up_mbps": 10.0, "down_mbps": 50.0},
                            {"weight": 1.0, "up_mbps": 1.0, "down_mbps": 8.0}],
                "loss": {"model": "classes", "tiers": [0.1, 7.0]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("loss.tiers[1]"), "{err}");
    }

    #[test]
    fn non_positive_dwell_times_fail_loudly() {
        for body in [
            r#"{"loss": {"model": "burst", "p_bad": 0.5, "good_s": 0.0}}"#,
            r#"{"loss": {"model": "burst", "p_bad": 0.5, "bad_s": -2.0}}"#,
        ] {
            let err = parse_loss(body).unwrap_err().to_string();
            assert!(err.contains("dwell time"), "{body}: {err}");
        }
    }

    #[test]
    fn classes_loss_tier_count_must_match_bandwidth_tiers() {
        // Mismatched counts.
        let err = parse_loss(
            r#"{"classes": [{"weight": 1.0, "up_mbps": 10.0, "down_mbps": 50.0},
                            {"weight": 1.0, "up_mbps": 1.0, "down_mbps": 8.0}],
                "loss": {"model": "classes", "tiers": [0.1]}}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("1 entries") && err.contains("2 tiers"), "{err}");
        // Classes loss with no bandwidth tiers at all.
        let err = parse_loss(r#"{"loss": {"model": "classes", "tiers": [0.1]}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("none are configured"), "{err}");
        // Missing/empty tiers array.
        assert!(parse_loss(r#"{"loss": {"model": "classes"}}"#).is_err());
        assert!(parse_loss(
            r#"{"classes": [{"weight": 1.0, "up_mbps": 10.0, "down_mbps": 50.0}],
                "loss": {"model": "classes", "tiers": []}}"#
        )
        .is_err());
    }

    #[test]
    fn retry_and_backoff_params_validate() {
        for (body, needle) in [
            (r#"{"loss": {"p": 0.1, "timeout_s": 0.0}}"#, "loss.timeout_s"),
            (r#"{"loss": {"p": 0.1, "timeout_s": -3.0}}"#, "loss.timeout_s"),
            (r#"{"loss": {"p": 0.1, "backoff": 0.5}}"#, "loss.backoff"),
            (
                r#"{"loss": {"p": 0.1, "timeout_s": 5.0, "max_timeout_s": 1.0}}"#,
                "loss.max_timeout_s",
            ),
            (r#"{"loss": {"p": 0.1, "retries": 0}}"#, "loss.retries"),
        ] {
            let err = parse_loss(body).unwrap_err().to_string();
            assert!(err.contains(needle), "{body}: {err}");
        }
    }

    #[test]
    fn unknown_loss_keys_and_models_fail() {
        assert!(parse_loss(r#"{"loss": {"modle": "uniform"}}"#).is_err());
        assert!(parse_loss(r#"{"loss": {"model": "gilbert"}}"#).is_err());
        // Keys from another model are rejected, not silently ignored.
        assert!(parse_loss(r#"{"loss": {"model": "uniform", "p_bad": 0.5}}"#).is_err());
        assert!(parse_loss(r#"{"loss": {"model": "burst", "p": 0.5}}"#).is_err());
    }
}
