//! The protocol registry: name → session factory.
//!
//! Protocols register a [`SessionBuilder`] that assembles a type-erased
//! [`Session`] from a [`ScenarioSpec`]; every launcher (CLI, experiment
//! drivers, examples, tests) dispatches through the registry instead of
//! matching on an enum. Adding a protocol = implement [`sim::Protocol`]
//! (one page), add a [`SessionBuilder`] next to it, and register it in
//! [`ProtocolRegistry::builtins`] — no edits anywhere else.

use anyhow::{bail, Result};

use crate::metrics::SessionMetrics;
use crate::net::TrafficLedger;
use crate::runtime::XlaRuntime;
use crate::sim::{ChurnSchedule, ResumeOptions, SnapshotReader};

use super::spec::ScenarioSpec;

/// A fully-assembled, runnable protocol session (type-erased).
pub trait Session {
    /// Drive the session to its budget; returns the collected metrics and
    /// the traffic ledger.
    fn run(self: Box<Self>) -> (SessionMetrics, TrafficLedger);

    /// Serialize the complete session state into snapshot bytes. Protocols
    /// opt in; the default bails loudly instead of writing a partial file.
    fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        bail!("this protocol does not support checkpointing")
    }

    /// Restore state from a snapshot positioned after its "spec" section,
    /// onto a freshly spec-built session (see `scenario::resume`).
    fn resume(&mut self, _r: &mut SnapshotReader, _opts: &ResumeOptions) -> Result<()> {
        bail!("this protocol does not support checkpointing")
    }
}

/// Static metadata a protocol publishes through the registry.
#[derive(Debug, Clone, Copy)]
pub struct ProtocolMeta {
    /// Canonical registry name (`modest`, `fedavg`, `dsgd`, `gossip`).
    pub name: &'static str,
    /// Label as the paper prints it (`MoDeST`, `FedAvg`, `D-SGD`, ...);
    /// also the source of CSV file tags via [`ProtocolMeta::csv_tag`].
    pub label: &'static str,
    /// Accepted alternative names (`fl`, `d-sgd`, `dl`, ...).
    pub aliases: &'static [&'static str],
    /// One-line description for `repro protocols`.
    pub summary: &'static str,
    /// Round budget figure drivers apply when the caller gives none
    /// (protocols that train every node every round get a lower cap).
    pub default_round_budget: u64,
    /// Protocol-specific extras and their defaults (documentation +
    /// `repro protocols`); read at build time via `ProtocolSpec::param`.
    pub default_params: &'static [(&'static str, f64)],
}

impl ProtocolMeta {
    /// Lower-cased label used in CSV/file names (`modest`, `d-sgd`, ...).
    pub fn csv_tag(&self) -> String {
        self.label.to_lowercase()
    }

    fn answers_to(&self, name: &str) -> bool {
        self.name.eq_ignore_ascii_case(name)
            || self.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
    }
}

/// Factory assembling a runnable [`Session`] for one protocol.
pub trait SessionBuilder {
    fn meta(&self) -> ProtocolMeta;

    /// Assemble the session: task, fabric, compute model, protocol state.
    /// `runtime` may be `None` for the mock dataset; builders that do not
    /// support churn scripts must reject a non-empty `churn`.
    fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>>;
}

/// Name → [`SessionBuilder`] mapping; the single dispatch point for every
/// launcher.
pub struct ProtocolRegistry {
    builders: Vec<Box<dyn SessionBuilder>>,
}

impl Default for ProtocolRegistry {
    fn default() -> Self {
        ProtocolRegistry::builtins()
    }
}

impl ProtocolRegistry {
    /// An empty registry (tests, downstream embedders).
    pub fn empty() -> ProtocolRegistry {
        ProtocolRegistry { builders: Vec::new() }
    }

    /// All in-tree protocols. This is the only place a new protocol is
    /// named outside its own module.
    pub fn builtins() -> ProtocolRegistry {
        let mut r = ProtocolRegistry::empty();
        r.register(Box::new(crate::modest::ModestBuilder));
        r.register(Box::new(crate::baselines::FedavgBuilder));
        r.register(Box::new(crate::baselines::DsgdBuilder));
        r.register(Box::new(crate::gossip::GossipBuilder));
        r
    }

    /// Register a builder. Panics on a name/alias collision — that is a
    /// programming error, not a runtime condition.
    pub fn register(&mut self, builder: Box<dyn SessionBuilder>) {
        let meta = builder.meta();
        for existing in &self.builders {
            let e = existing.meta();
            let clash = std::iter::once(meta.name)
                .chain(meta.aliases.iter().copied())
                .any(|n| e.answers_to(n));
            assert!(!clash, "protocol {:?} collides with {:?}", meta.name, e.name);
        }
        self.builders.push(builder);
    }

    /// Look up by canonical name or alias (case-insensitive).
    pub fn get(&self, name: &str) -> Result<&dyn SessionBuilder> {
        match self.builders.iter().find(|b| b.meta().answers_to(name)) {
            Some(b) => Ok(b.as_ref()),
            None => bail!(
                "unknown protocol {name:?} (registered: {})",
                self.names().join("|")
            ),
        }
    }

    /// Canonical names in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.builders.iter().map(|b| b.meta().name).collect()
    }

    /// Metadata rows in registration order.
    pub fn metas(&self) -> Vec<ProtocolMeta> {
        self.builders.iter().map(|b| b.meta()).collect()
    }

    /// Paper-style label for a protocol name (replaces the old hardcoded
    /// `algo_label` match).
    pub fn label(&self, name: &str) -> Result<&'static str> {
        Ok(self.get(name)?.meta().label)
    }

    /// Assemble the session `spec` describes, dispatching on
    /// `spec.protocol.name`. Protocol-specific `params` are validated
    /// against the builder's declared `default_params`, so a typoed
    /// `fanuot` fails loudly like every other unknown config key.
    ///
    /// Churn assembly happens here, once for every protocol: the
    /// `population.availability` section (if any) compiles into a churn
    /// schedule and merges with the caller's programmatic script
    /// (caller's events first at same-instant ties), and the combined
    /// schedule is validated against the population —
    /// [`ScenarioSpec::validate_churn`] rejects scripts that crash/leave a
    /// node id that never joins, before any session state is built.
    pub fn build(
        &self,
        spec: &ScenarioSpec,
        runtime: Option<&XlaRuntime>,
        churn: ChurnSchedule,
    ) -> Result<Box<dyn Session>> {
        let builder = self.get(&spec.protocol.name)?;
        let meta = builder.meta();
        for (key, _) in &spec.protocol.params {
            if !meta.default_params.iter().any(|(name, _)| *name == key.as_str()) {
                let known = if meta.default_params.is_empty() {
                    "none".to_string()
                } else {
                    meta.default_params
                        .iter()
                        .map(|&(name, _)| name)
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                bail!("unknown {} param {key:?} (known params: {known})", meta.name);
            }
        }
        let availability = spec.availability_churn()?;
        let churn = if availability.is_empty() { churn } else { churn.merged(availability) };
        spec.validate_churn(&churn)?;
        builder.build(spec, runtime, churn)
    }
}

/// Build and run `spec` on the builtin registry — the one-call entry point
/// for examples and tests.
pub fn run_scenario(
    spec: &ScenarioSpec,
    runtime: Option<&XlaRuntime>,
    churn: ChurnSchedule,
) -> Result<(SessionMetrics, TrafficLedger)> {
    Ok(ProtocolRegistry::builtins().build(spec, runtime, churn)?.run())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_all_four_protocols() {
        let r = ProtocolRegistry::builtins();
        assert_eq!(r.names(), vec!["modest", "fedavg", "dsgd", "gossip"]);
    }

    #[test]
    fn aliases_resolve_case_insensitively() {
        let r = ProtocolRegistry::builtins();
        assert_eq!(r.get("FL").unwrap().meta().name, "fedavg");
        assert_eq!(r.get("d-sgd").unwrap().meta().name, "dsgd");
        assert_eq!(r.get("dl").unwrap().meta().name, "dsgd");
        assert_eq!(r.get("MoDeST").unwrap().meta().name, "modest");
        assert!(r.get("nope").is_err());
    }

    #[test]
    fn labels_match_the_paper() {
        let r = ProtocolRegistry::builtins();
        assert_eq!(r.label("modest").unwrap(), "MoDeST");
        assert_eq!(r.label("fedavg").unwrap(), "FedAvg");
        assert_eq!(r.label("dsgd").unwrap(), "D-SGD");
        assert_eq!(r.get("dsgd").unwrap().meta().csv_tag(), "d-sgd");
    }

    #[test]
    #[should_panic(expected = "collides")]
    fn duplicate_registration_panics() {
        let mut r = ProtocolRegistry::builtins();
        r.register(Box::new(crate::modest::ModestBuilder));
    }
}
