//! The `population.availability` section: realistic node availability,
//! compiled into a [`ChurnSchedule`] at session build time.
//!
//! The paper's practicality claim is that sampling and aggregation keep
//! working while nodes come and go; related systems (device-availability
//! FL, dropout-resilient aggregation) treat churn as the default
//! condition. Until now churn only existed as hand-scripted event lists
//! passed programmatically. This section makes it declarative:
//!
//! ```json
//! "population": {
//!   "nodes": 100,
//!   "availability": {"model": "diurnal", "amplitude": 0.3,
//!                    "period_s": 600.0, "seed": 9}
//! }
//! ```
//!
//! Three models:
//!
//! * `"diurnal"` — a population-level sine: the expected offline fraction
//!   at time `t` is `amplitude * (1 - cos(2πt/period)) / 2`, i.e. everyone
//!   online at `t = 0`, a trough of `amplitude` offline at every
//!   half-period. Each node draws one uniform threshold from the
//!   availability seed stream; nodes under the threshold get a contiguous
//!   offline window per cycle (Crash at window start, Recover at window
//!   end), centred on the trough — the closed-form inverse of the sine.
//! * `"step"` — a square wave: a seed-chosen `amplitude` fraction of the
//!   population goes offline together for the second half of every period.
//! * `"trace"` — CSV playback of per-node offline intervals
//!   (`node,offline_from_s,offline_until_s` rows; `#` comments and an
//!   alphabetic header line are skipped), for replaying measured
//!   availability traces.
//!
//! The compiled schedule rides the existing churn machinery
//! ([`crate::scenario::ProtocolRegistry::build`] merges it with any
//! programmatic script), so every registered protocol gets availability
//! churn with no per-protocol code. Compilation is deterministic: the
//! seed stream is `SimRng::new(seed).fork("availability")` (independent of
//! the session RNG — adding availability never perturbs the draw sequence
//! of the session itself), and the emitted schedule is pinned by
//! [`ChurnSchedule::new`]'s `(at, insertion seq)` tie order.

use anyhow::{anyhow, bail, Context, Result};

use crate::sim::{ChurnEvent, ChurnKind, ChurnSchedule, SimRng, SimTime};
use crate::util::Json;
use crate::NodeId;

/// Which availability process generates the offline intervals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AvailabilityModel {
    /// Population-level sine (see module docs).
    #[default]
    Diurnal,
    /// Square wave: a fixed node subset offline every half-period.
    Step,
    /// CSV playback of per-node offline intervals.
    Trace,
}

impl AvailabilityModel {
    pub fn parse(s: &str) -> Result<AvailabilityModel> {
        match s {
            "diurnal" => Ok(AvailabilityModel::Diurnal),
            "step" => Ok(AvailabilityModel::Step),
            "trace" => Ok(AvailabilityModel::Trace),
            other => bail!(
                "unknown availability model {other:?} (expected \"diurnal\", \"step\" or \"trace\")"
            ),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            AvailabilityModel::Diurnal => "diurnal",
            AvailabilityModel::Step => "step",
            AvailabilityModel::Trace => "trace",
        }
    }
}

/// The `population.availability` section.
#[derive(Debug, Clone, PartialEq)]
pub struct AvailabilitySpec {
    pub model: AvailabilityModel,
    /// Peak offline fraction of the population, in [0, 1] (synthetic
    /// models; ignored for traces).
    pub amplitude: f64,
    /// Cycle length in virtual seconds (synthetic models; >= 1 s so a
    /// schedule cannot explode into sub-second event storms).
    pub period_s: f64,
    /// Independent availability seed; `null`/absent = derive from
    /// `run.seed`.
    pub seed: Option<u64>,
    /// Offline-interval CSV for `model = "trace"`.
    pub trace_file: Option<String>,
}

impl Default for AvailabilitySpec {
    fn default() -> Self {
        AvailabilitySpec {
            model: AvailabilityModel::Diurnal,
            amplitude: 0.25,
            period_s: 3600.0,
            seed: None,
            trace_file: None,
        }
    }
}

impl AvailabilitySpec {
    pub fn from_json(v: &Json) -> Result<AvailabilitySpec> {
        let mut out = AvailabilitySpec::default();
        for (key, val) in v.as_obj()? {
            match key.as_str() {
                "model" => out.model = AvailabilityModel::parse(val.as_str()?)?,
                "amplitude" => out.amplitude = val.as_f64()?,
                "period_s" => out.period_s = val.as_f64()?,
                "seed" => {
                    out.seed = if *val == Json::Null { None } else { Some(val.as_u64()?) }
                }
                "trace_file" => {
                    out.trace_file = if *val == Json::Null {
                        None
                    } else {
                        Some(val.as_str()?.to_string())
                    }
                }
                other => bail!("unknown availability key {other:?}"),
            }
        }
        anyhow::ensure!(
            out.amplitude.is_finite() && (0.0..=1.0).contains(&out.amplitude),
            "availability.amplitude must be in [0, 1], got {}",
            out.amplitude
        );
        anyhow::ensure!(
            out.period_s.is_finite() && out.period_s >= 1.0,
            "availability.period_s must be a finite number >= 1, got {}",
            out.period_s
        );
        match (out.model, &out.trace_file) {
            (AvailabilityModel::Trace, None) => {
                bail!("availability model \"trace\" needs a trace_file")
            }
            (AvailabilityModel::Trace, Some(_)) => {}
            (_, Some(_)) => bail!(
                "availability.trace_file requires model \"trace\" (got {:?})",
                out.model.as_str()
            ),
            (_, None) => {}
        }
        Ok(out)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.as_str().to_string())),
            ("amplitude", Json::Num(self.amplitude)),
            ("period_s", Json::Num(self.period_s)),
            (
                "seed",
                match self.seed {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            (
                "trace_file",
                match &self.trace_file {
                    Some(p) => Json::Str(p.clone()),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Compile this section into a churn schedule for a population of `n`
    /// nodes over the virtual window `[0, max_time_s)`. Deterministic
    /// given `(spec, n, run_seed, max_time_s)`; intervals that end past the
    /// window emit only their Crash (the node stays down to session end).
    pub fn compile(&self, n: usize, run_seed: u64, max_time_s: f64) -> Result<ChurnSchedule> {
        // Re-validate the knobs `from_json` guards: specs are plain-old
        // data and can be constructed literally, and a zero/NaN period
        // would spin `push_cycles` forever.
        anyhow::ensure!(
            self.period_s.is_finite() && self.period_s >= 1.0,
            "availability.period_s must be a finite number >= 1, got {}",
            self.period_s
        );
        anyhow::ensure!(
            self.amplitude.is_finite() && (0.0..=1.0).contains(&self.amplitude),
            "availability.amplitude must be in [0, 1], got {}",
            self.amplitude
        );
        let mut rng = SimRng::new(self.seed.unwrap_or(run_seed)).fork("availability");
        let mut events = Vec::new();
        match self.model {
            AvailabilityModel::Diurnal => {
                if self.amplitude > 0.0 {
                    for node in 0..n {
                        let u = rng.next_f64();
                        let v = u / self.amplitude;
                        if v >= 1.0 {
                            continue; // this node never cycles offline
                        }
                        // Offline while (1 - cos(2πt/P)) / 2 > v: one
                        // window per cycle, centred on the half-period
                        // trough; acos inverts the sine in closed form.
                        let start_frac = (1.0 - 2.0 * v).acos() / std::f64::consts::TAU;
                        let end_frac = 1.0 - start_frac;
                        push_cycles(
                            &mut events,
                            node as NodeId,
                            self.period_s,
                            start_frac,
                            end_frac,
                            max_time_s,
                        );
                    }
                }
            }
            AvailabilityModel::Step => {
                let k_off = ((self.amplitude * n as f64).round() as usize).min(n);
                if k_off > 0 {
                    // A seed-pinned subset cycles; everyone else stays up.
                    for node in rng.sample_indices(n, k_off) {
                        push_cycles(
                            &mut events,
                            node as NodeId,
                            self.period_s,
                            0.5,
                            1.0,
                            max_time_s,
                        );
                    }
                }
            }
            AvailabilityModel::Trace => {
                let path = self
                    .trace_file
                    .as_ref()
                    .ok_or_else(|| anyhow!("availability trace model without trace_file"))?;
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading availability trace {path:?}"))?;
                events = parse_offline_trace(&text, n, max_time_s)
                    .with_context(|| format!("parsing availability trace {path:?}"))?;
            }
        }
        Ok(ChurnSchedule::new(events))
    }
}

/// Emit Crash/Recover pairs for one node's per-cycle offline window
/// `[(c + start_frac) * period, (c + end_frac) * period)` over
/// `[0, max_time_s)`.
fn push_cycles(
    events: &mut Vec<ChurnEvent>,
    node: NodeId,
    period_s: f64,
    start_frac: f64,
    end_frac: f64,
    max_time_s: f64,
) {
    let mut cycle = 0f64;
    loop {
        let start = (cycle + start_frac) * period_s;
        if start >= max_time_s {
            break;
        }
        events.push(ChurnEvent {
            at: SimTime::from_secs_f64(start),
            node,
            kind: ChurnKind::Crash,
        });
        let end = (cycle + end_frac) * period_s;
        if end < max_time_s {
            events.push(ChurnEvent {
                at: SimTime::from_secs_f64(end),
                node,
                kind: ChurnKind::Recover,
            });
        }
        cycle += 1.0;
    }
}

/// CSV body: `node,offline_from_s,offline_until_s` per row, with the
/// shared trace envelope (`#` comments, optional alphabetic header,
/// line-numbered errors — [`crate::util::parse_trace_rows`]). Node ids
/// outside `[0, n)` are rejected — the trace would crash a node that
/// never joins the population, which must fail at parse time instead of
/// deep inside the session.
fn parse_offline_trace(text: &str, n: usize, max_time_s: f64) -> Result<Vec<ChurnEvent>> {
    let mut intervals: Vec<(NodeId, f64, f64)> = Vec::new();
    let saw_data =
        crate::util::parse_trace_rows(text, parse_offline_row, |lineno, (node, from_s, until_s)| {
            anyhow::ensure!(
                (node as usize) < n,
                "trace line {lineno}: node {node} never joins the population of {n} nodes"
            );
            anyhow::ensure!(
                from_s.is_finite() && until_s.is_finite() && from_s >= 0.0 && until_s > from_s,
                "trace line {lineno}: bad offline interval [{from_s}, {until_s})"
            );
            intervals.push((node, from_s, until_s));
            Ok(())
        })?;
    anyhow::ensure!(saw_data, "availability trace holds no interval rows");
    // Overlapping intervals for one node would compile into a Recover that
    // silently revives a node another interval says is still offline —
    // reject them loudly (merged measured traces hit this easily).
    intervals.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    for w in intervals.windows(2) {
        let ((n0, from0, until0), (n1, from1, until1)) = (w[0], w[1]);
        anyhow::ensure!(
            n0 != n1 || from1 >= until0,
            "availability trace: node {n0} has overlapping offline intervals \
             [{from0}, {until0}) and [{from1}, {until1})"
        );
    }
    // Emit from the per-node time-SORTED intervals, not file-row order:
    // with out-of-order rows, a shared boundary instant would otherwise
    // compile to Crash-before-Recover and leave the node wrongly online
    // through the second interval (ChurnSchedule ties keep insertion
    // order).
    let mut events = Vec::new();
    for (node, from_s, until_s) in intervals {
        if from_s >= max_time_s {
            continue;
        }
        events.push(ChurnEvent {
            at: SimTime::from_secs_f64(from_s),
            node,
            kind: ChurnKind::Crash,
        });
        if until_s < max_time_s {
            events.push(ChurnEvent {
                at: SimTime::from_secs_f64(until_s),
                node,
                kind: ChurnKind::Recover,
            });
        }
    }
    Ok(events)
}

/// One `node,offline_from_s,offline_until_s` row.
fn parse_offline_row(line: &str) -> Result<(NodeId, f64, f64)> {
    let mut cols = line.split(',').map(str::trim);
    let mut next = |name: &str| {
        cols.next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| anyhow!("missing {name}"))
    };
    let node: NodeId = next("node")?.parse().map_err(|e| anyhow!("bad node id: {e}"))?;
    let from: f64 = next("offline_from_s")?
        .parse()
        .map_err(|e| anyhow!("bad offline_from_s: {e}"))?;
    let until: f64 = next("offline_until_s")?
        .parse()
        .map_err(|e| anyhow!("bad offline_until_s: {e}"))?;
    Ok((node, from, until))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diurnal(amplitude: f64, period_s: f64, seed: u64) -> AvailabilitySpec {
        AvailabilitySpec {
            model: AvailabilityModel::Diurnal,
            amplitude,
            period_s,
            seed: Some(seed),
            trace_file: None,
        }
    }

    #[test]
    fn diurnal_compiles_paired_windows_inside_the_horizon() {
        let n = 400;
        let s = diurnal(0.3, 100.0, 9).compile(n, 42, 1000.0).unwrap();
        assert!(!s.is_empty());
        // Time-sorted, everyone online at t = 0, all events in-window.
        assert!(s.events().iter().all(|e| e.at > SimTime::ZERO));
        assert!(s.events().iter().all(|e| e.at < SimTime::from_secs_f64(1000.0)));
        // Roughly `amplitude` of the population cycles (one threshold draw
        // per node; deterministic given the seed).
        let mut cycling: Vec<NodeId> = s.events().iter().map(|e| e.node).collect();
        cycling.sort_unstable();
        cycling.dedup();
        assert!(
            (n / 5..=n * 2 / 5).contains(&cycling.len()),
            "{} of {n} nodes cycle at amplitude 0.3",
            cycling.len()
        );
        // Per node and cycle the window is Crash-then-Recover, centred on
        // the half-period trough (50 s), and every Crash has its Recover
        // inside the horizon here (windows are < one period long).
        let node = cycling[0];
        let evs: Vec<&ChurnEvent> =
            s.events().iter().filter(|e| e.node == node).collect();
        assert_eq!(evs.len() % 2, 0, "unpaired window for node {node}");
        for pair in evs.chunks(2) {
            assert_eq!(pair[0].kind, ChurnKind::Crash);
            assert_eq!(pair[1].kind, ChurnKind::Recover);
            let (a, b) = (pair[0].at.as_secs_f64(), pair[1].at.as_secs_f64());
            assert!(a < b);
            let trough = ((a / 100.0).floor() + 0.5) * 100.0;
            assert!(a < trough && trough < b, "window [{a}, {b}) misses trough {trough}");
        }
    }

    #[test]
    fn diurnal_trough_depth_tracks_amplitude() {
        // At the trough every cycling node is offline, so the concurrent
        // offline count there approximates amplitude * n.
        let n = 1000;
        let s = diurnal(0.4, 200.0, 5).compile(n, 1, 200.0).unwrap();
        let trough = SimTime::from_secs_f64(100.0);
        let offline = s
            .events()
            .iter()
            .filter(|e| e.kind == ChurnKind::Crash && e.at <= trough)
            .count()
            - s.events()
                .iter()
                .filter(|e| e.kind == ChurnKind::Recover && e.at <= trough)
                .count();
        let frac = offline as f64 / n as f64;
        assert!(
            (0.3..=0.5).contains(&frac),
            "trough offline fraction {frac} far from amplitude 0.4"
        );
    }

    #[test]
    fn compilation_is_deterministic_and_seed_decoupled() {
        let a = diurnal(0.3, 60.0, 7).compile(100, 1, 300.0).unwrap();
        let b = diurnal(0.3, 60.0, 7).compile(100, 2, 300.0).unwrap();
        assert_eq!(a.events(), b.events(), "pinned seed ignores run seed");
        // No seed: run seed drives it.
        let mut spec = diurnal(0.3, 60.0, 0);
        spec.seed = None;
        let c = spec.compile(100, 1, 300.0).unwrap();
        let d = spec.compile(100, 2, 300.0).unwrap();
        assert_ne!(c.events(), d.events());
        let e = spec.compile(100, 1, 300.0).unwrap();
        assert_eq!(c.events(), e.events());
    }

    #[test]
    fn step_model_downs_a_fixed_subset_every_half_period() {
        let spec = AvailabilitySpec {
            model: AvailabilityModel::Step,
            amplitude: 0.25,
            period_s: 100.0,
            seed: Some(3),
            trace_file: None,
        };
        let s = spec.compile(40, 9, 250.0).unwrap();
        let mut offline: Vec<NodeId> = s.events().iter().map(|e| e.node).collect();
        offline.sort_unstable();
        offline.dedup();
        assert_eq!(offline.len(), 10, "amplitude 0.25 of 40 nodes");
        // Cycle 0: down at 50, up at 100. Cycle 1: down at 150, up at 200.
        // Cycle 2: down at 250 >= horizon — nothing.
        let crashes: Vec<u64> = s
            .events()
            .iter()
            .filter(|e| e.kind == ChurnKind::Crash)
            .map(|e| e.at.0)
            .collect();
        assert_eq!(crashes.len(), 20);
        assert!(crashes.iter().all(|&t| t == 50_000_000 || t == 150_000_000));
        let recovers = s.events().iter().filter(|e| e.kind == ChurnKind::Recover).count();
        assert_eq!(recovers, 20);
    }

    #[test]
    fn compile_revalidates_literal_specs() {
        // Specs are plain data; a literally-constructed zero/NaN period
        // must fail compile() instead of spinning push_cycles forever.
        for bad_period in [0.0, -5.0, f64::NAN, 0.9] {
            let spec = AvailabilitySpec { period_s: bad_period, ..Default::default() };
            assert!(spec.compile(8, 1, 100.0).is_err(), "accepted period {bad_period}");
        }
        let spec = AvailabilitySpec { amplitude: 2.0, ..Default::default() };
        assert!(spec.compile(8, 1, 100.0).is_err());
    }

    #[test]
    fn zero_amplitude_compiles_empty() {
        let s = diurnal(0.0, 60.0, 1).compile(50, 1, 600.0).unwrap();
        assert!(s.is_empty());
        let step = AvailabilitySpec {
            model: AvailabilityModel::Step,
            amplitude: 0.0,
            period_s: 60.0,
            seed: None,
            trace_file: None,
        };
        assert!(step.compile(50, 1, 600.0).unwrap().is_empty());
    }

    #[test]
    fn offline_trace_parses_and_clamps_to_horizon() {
        let evs = parse_offline_trace(
            "# measured availability\nnode,offline_from_s,offline_until_s\n\
             3,10.0,20.0\n0,30.0,999.0\n7,500.0,600.0\n",
            8,
            100.0,
        )
        .unwrap();
        // Emission is per-node interval order (node 0 first): node 0's
        // Recover is past the horizon (Crash only); node 7 starts past
        // the horizon (dropped entirely).
        assert_eq!(evs.len(), 3);
        assert_eq!((evs[0].node, evs[0].kind), (0, ChurnKind::Crash));
        assert_eq!((evs[1].node, evs[1].kind), (3, ChurnKind::Crash));
        assert_eq!((evs[2].node, evs[2].kind), (3, ChurnKind::Recover));
    }

    #[test]
    fn out_of_order_back_to_back_intervals_compile_recover_before_crash() {
        // Rows listed out of time order share the boundary instant t=20;
        // emission from the SORTED intervals puts Recover@20 before
        // Crash@20, so the stable (at, insertion seq) churn sort keeps the
        // node offline through [20, 30) exactly as the trace declares.
        let evs =
            parse_offline_trace("1,20.0,30.0\n1,10.0,20.0\n", 8, 100.0).unwrap();
        let kinds: Vec<(u64, ChurnKind)> =
            evs.iter().map(|e| (e.at.0, e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                (10_000_000, ChurnKind::Crash),
                (20_000_000, ChurnKind::Recover),
                (20_000_000, ChurnKind::Crash),
                (30_000_000, ChurnKind::Recover),
            ]
        );
    }

    #[test]
    fn offline_trace_rejects_never_joining_nodes_and_bad_rows() {
        // The parse-time churn-validation satellite: a node id outside the
        // population fails with a pointed message, not a runtime protocol
        // error deep in the session.
        let err = parse_offline_trace("99,1.0,2.0\n", 8, 100.0).unwrap_err();
        assert!(err.to_string().contains("never joins"), "{err:#}");
        assert!(parse_offline_trace("1,5.0,5.0\n", 8, 100.0).is_err(), "empty interval");
        assert!(parse_offline_trace("1,-1.0,5.0\n", 8, 100.0).is_err(), "negative start");
        assert!(parse_offline_trace("1,abc,5.0\n", 8, 100.0).is_err());
        assert!(parse_offline_trace("1,2.0\n", 8, 100.0).is_err(), "missing column");
        assert!(parse_offline_trace("# only comments\n", 8, 100.0).is_err());
        // A typoed FIRST data row must not pass as a header.
        assert!(parse_offline_trace("1O,1.0,2.0\n2,3.0,4.0\n", 8, 100.0).is_err());
        // Overlapping intervals for one node: the inner Recover would
        // silently revive a node the outer interval says is offline.
        let err =
            parse_offline_trace("1,10.0,50.0\n1,20.0,30.0\n", 8, 100.0).unwrap_err();
        assert!(err.to_string().contains("overlapping"), "{err:#}");
        // Back-to-back and multi-node intervals are fine.
        assert!(parse_offline_trace("1,10.0,20.0\n1,20.0,30.0\n2,15.0,25.0\n", 8, 100.0).is_ok());
    }

    #[test]
    fn spec_json_roundtrip_and_validation() {
        let v = Json::parse(
            r#"{"model": "step", "amplitude": 0.5, "period_s": 120.0, "seed": 11}"#,
        )
        .unwrap();
        let spec = AvailabilitySpec::from_json(&v).unwrap();
        assert_eq!(spec.model, AvailabilityModel::Step);
        assert_eq!(spec.seed, Some(11));
        let back =
            AvailabilitySpec::from_json(&Json::parse(&spec.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(spec, back);

        for bad in [
            r#"{"amplitude": 1.5}"#,
            r#"{"amplitude": -0.1}"#,
            r#"{"period_s": 0.5}"#,
            r#"{"model": "sine"}"#,
            r#"{"model": "trace"}"#,
            r#"{"model": "diurnal", "trace_file": "x.csv"}"#,
            r#"{"amplitud": 0.2}"#,
        ] {
            assert!(
                AvailabilitySpec::from_json(&Json::parse(bad).unwrap()).is_err(),
                "accepted {bad}"
            );
        }
    }

    #[test]
    fn missing_trace_file_fails_at_compile() {
        let spec = AvailabilitySpec {
            model: AvailabilityModel::Trace,
            trace_file: Some("/definitely/not/a/file.csv".into()),
            ..Default::default()
        };
        assert!(spec.compile(8, 1, 100.0).is_err());
    }
}
