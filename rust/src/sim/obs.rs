//! Bounded-memory streaming observability: the structures every
//! million-node session records into and the live progress stream they
//! feed.
//!
//! Everything here is fixed-size and deterministic:
//!
//! * [`StreamHistogram`] — log-bucketed u64 counters (≤ [`HIST_BUCKETS`]
//!   buckets, 16 sub-buckets per power of two, values < 16 exact). No
//!   floats in state; quantile queries return a bucket upper bound, so the
//!   relative error is at most 1/16 = 6.25%. Merge is element-wise counter
//!   addition — exactly associative and commutative, which is what a
//!   future sharded harness needs to combine per-shard state.
//! * [`Hll`] — a dense HyperLogLog sketch with fixed `2^12 = 4096` one-byte
//!   registers (standard error `1.04/sqrt(4096)` ≈ 1.6%; the documented
//!   bound, checked by `obs_check selftest` against exact oracles, is 5%).
//!   The only randomness is a hash salt taken from a dedicated
//!   `fork("obs")` stream of the session seed, so same-seed runs emit
//!   bit-identical sketches and the session RNG stream is untouched.
//! * [`RoundWindow`] — a ring buffer of the last [`ROUND_WINDOW`] round
//!   starts. The first entry and the total count are retained besides the
//!   ring, so whole-session aggregates (mean round time) stay exact after
//!   eviction.
//! * [`ProgressLine`] — one compact JSONL snapshot of a running session,
//!   rendered into a caller-owned buffer (zero heap growth per tick once
//!   the buffer has grown to line size). Deterministic fields come first;
//!   the wall-clock tail (`wall_s`, `rss_kb`) is last so differential
//!   tests can strip it textually.
//!
//! The live emitter itself lives in `sim::harness` (it owns the clock and
//! the output file); `run.progress { every_s, out }` in the scenario spec
//! arms it.

use std::collections::VecDeque;
use std::fmt::Write as _;

use super::snapshot::{SnapshotReader, SnapshotWriter};
use super::time::SimTime;

/// Buckets in a [`StreamHistogram`]: 16 exact small values + 16 sub-buckets
/// for each exponent 4..=63 (index `(e-3)*16 + mantissa`, max 975).
pub const HIST_BUCKETS: usize = 976;

/// Ring capacity of [`RoundWindow`] (last W round starts kept).
pub const ROUND_WINDOW: usize = 4096;

/// HyperLogLog precision: `2^12 = 4096` registers.
pub const HLL_P: u32 = 12;
const HLL_M: usize = 1 << HLL_P;

// ---------------------------------------------------------------- histogram

/// Fixed-size log-bucketed histogram over u64 values. All state is u64
/// counters (floats appear only in quantile queries), so merge and
/// serialization are exact and deterministic.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StreamHistogram {
    /// Lazily sized to [`HIST_BUCKETS`] on first record, so an unused
    /// histogram costs three words.
    counts: Vec<u64>,
    total: u64,
    /// Saturating sum of recorded values (mean query).
    sum: u64,
    min: u64,
    max: u64,
}

/// Bucket index of `v`: exact below 16, then 16 sub-buckets per power of
/// two (relative width 1/16).
fn hist_bucket(v: u64) -> usize {
    if v < 16 {
        return v as usize;
    }
    let e = 63 - v.leading_zeros() as usize; // >= 4
    (e - 3) * 16 + ((v >> (e - 4)) & 15) as usize
}

/// Upper bound of bucket `idx` — the quantile representative. Conservative
/// (over-estimates by < 1/16 relative).
fn hist_rep(idx: usize) -> u64 {
    if idx < 16 {
        return idx as u64;
    }
    let e = idx / 16 + 3;
    let lo = (1u64 << e) + (((idx % 16) as u64) << (e - 4));
    lo + (1u64 << (e - 4)) - 1
}

impl StreamHistogram {
    pub fn new() -> StreamHistogram {
        StreamHistogram { counts: Vec::new(), total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        self.counts[hist_bucket(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Estimate of the `q`-quantile (q in [0, 1]): the upper bound of the
    /// bucket holding the rank-⌈q·total⌉ value, clamped to the observed
    /// [min, max]. Relative error ≤ 1/16 against the exact order statistic.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return hist_rep(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Element-wise counter merge — exactly associative/commutative.
    pub fn merge(&mut self, other: &StreamHistogram) {
        if other.total == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Serialize (sparse: only non-zero buckets), byte-stable across
    /// write→read→write.
    pub fn write_into(&self, w: &mut SnapshotWriter) {
        w.write_u64(self.total);
        w.write_u64(self.sum);
        w.write_u64(self.min);
        w.write_u64(self.max);
        let nonzero = self.counts.iter().filter(|&&c| c != 0).count();
        w.write_usize(nonzero);
        for (i, &c) in self.counts.iter().enumerate() {
            if c != 0 {
                w.write_u32(i as u32);
                w.write_u64(c);
            }
        }
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<StreamHistogram> {
        let total = r.read_u64()?;
        let sum = r.read_u64()?;
        let min = r.read_u64()?;
        let max = r.read_u64()?;
        let nonzero = r.read_usize()?;
        let mut counts = Vec::new();
        if total > 0 {
            counts = vec![0; HIST_BUCKETS];
        }
        for _ in 0..nonzero {
            let i = r.read_u32()? as usize;
            anyhow::ensure!(i < HIST_BUCKETS, "histogram bucket index {i} out of range");
            counts[i] = r.read_u64()?;
        }
        Ok(StreamHistogram { counts, total, sum, min, max })
    }
}

// ---------------------------------------------------------------------- hll

/// splitmix64 finalizer — the avalanche function salting HLL inserts.
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Dense HyperLogLog with `2^12` fixed one-byte registers. Distinct-count
/// estimates carry ≈1.6% standard error (documented bound 5%, verified by
/// `obs_check selftest`). Deterministic: the salt is the only entropy.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Hll {
    /// Lazily sized to [`HLL_M`] on first insert.
    registers: Vec<u8>,
    salt: u64,
    inserts: u64,
}

impl Hll {
    pub fn with_salt(salt: u64) -> Hll {
        Hll { registers: Vec::new(), salt, inserts: 0 }
    }

    /// Re-salt an empty sketch (the harness installs the `fork("obs")`
    /// stream's salt after construction). No-op guard: changing the salt
    /// after inserts would silently mix two hash spaces.
    pub fn set_salt(&mut self, salt: u64) {
        if self.inserts == 0 {
            self.salt = salt;
        }
    }

    pub fn insert(&mut self, x: u64) {
        if self.registers.is_empty() {
            self.registers = vec![0; HLL_M];
        }
        self.inserts += 1;
        let h = mix64(x ^ self.salt);
        let idx = (h >> (64 - HLL_P)) as usize;
        let rest = h << HLL_P;
        let rho = (rest.leading_zeros() + 1).min(64 - HLL_P + 1) as u8;
        if rho > self.registers[idx] {
            self.registers[idx] = rho;
        }
    }

    /// Total inserts observed (not distinct).
    pub fn inserts(&self) -> u64 {
        self.inserts
    }

    /// Distinct-count estimate: standard HLL harmonic mean with the
    /// linear-counting small-range correction.
    pub fn estimate(&self) -> f64 {
        if self.registers.is_empty() {
            return 0.0;
        }
        let m = HLL_M as f64;
        let alpha = 0.7213 / (1.0 + 1.079 / m);
        let mut sum = 0.0f64;
        let mut zeros = 0u64;
        for &r in &self.registers {
            sum += 1.0 / (1u64 << r) as f64;
            if r == 0 {
                zeros += 1;
            }
        }
        let raw = alpha * m * m / sum;
        if raw <= 2.5 * m && zeros > 0 {
            return m * (m / zeros as f64).ln();
        }
        raw
    }

    /// Rounded estimate for reporting.
    pub fn count(&self) -> u64 {
        self.estimate().round() as u64
    }

    /// Element-wise register max — exactly associative/commutative (same
    /// salt required for the union to be meaningful).
    pub fn merge(&mut self, other: &Hll) {
        if other.registers.is_empty() {
            return;
        }
        if self.registers.is_empty() {
            self.registers = vec![0; HLL_M];
        }
        for (a, &b) in self.registers.iter_mut().zip(&other.registers) {
            *a = (*a).max(b);
        }
        self.inserts += other.inserts;
    }

    pub fn write_into(&self, w: &mut SnapshotWriter) {
        w.write_u64(self.salt);
        w.write_u64(self.inserts);
        w.write_bool(!self.registers.is_empty());
        for &r in &self.registers {
            w.write_u8(r);
        }
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<Hll> {
        let salt = r.read_u64()?;
        let inserts = r.read_u64()?;
        let dense = r.read_bool()?;
        let mut registers = Vec::new();
        if dense {
            registers.reserve_exact(HLL_M);
            for _ in 0..HLL_M {
                registers.push(r.read_u8()?);
            }
        }
        Ok(Hll { registers, salt, inserts })
    }
}

// ------------------------------------------------------------- round window

/// Ring buffer of the last [`ROUND_WINDOW`] `(round, start-time)` pairs,
/// plus the retained first entry and total count so whole-session
/// aggregates stay exact after eviction. This replaces the unbounded
/// `round_starts: Vec` — the last materialize-in-rounds growth in
/// `SessionMetrics`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundWindow {
    entries: VecDeque<(u64, f64)>,
    first: Option<(u64, f64)>,
    seen: u64,
}

impl RoundWindow {
    pub fn record(&mut self, round: u64, time_s: f64) {
        if self.first.is_none() {
            self.first = Some((round, time_s));
        }
        if self.entries.len() == ROUND_WINDOW {
            self.entries.pop_front();
        }
        self.entries.push_back((round, time_s));
        self.seen += 1;
    }

    /// Entries currently retained (≤ [`ROUND_WINDOW`]).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total round starts ever recorded (including evicted ones).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// The very first recorded round start (survives eviction).
    pub fn first(&self) -> Option<(u64, f64)> {
        self.first
    }

    pub fn last(&self) -> Option<(u64, f64)> {
        self.entries.back().copied()
    }

    /// Chronological iteration over the retained window.
    pub fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.entries.iter().copied()
    }

    pub fn write_into(&self, w: &mut SnapshotWriter) {
        w.write_u64(self.seen);
        w.write_bool(self.first.is_some());
        if let Some((r, t)) = self.first {
            w.write_u64(r);
            w.write_f64(t);
        }
        w.write_usize(self.entries.len());
        for &(r, t) in &self.entries {
            w.write_u64(r);
            w.write_f64(t);
        }
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<RoundWindow> {
        let seen = r.read_u64()?;
        let first = if r.read_bool()? {
            let round = r.read_u64()?;
            Some((round, r.read_f64()?))
        } else {
            None
        };
        let n = r.read_usize()?;
        anyhow::ensure!(n <= ROUND_WINDOW, "round window length {n} exceeds capacity");
        let mut entries = VecDeque::with_capacity(n);
        for _ in 0..n {
            let round = r.read_u64()?;
            entries.push_back((round, r.read_f64()?));
        }
        Ok(RoundWindow { entries, first, seen })
    }
}

// ----------------------------------------------------------- progress spec

/// Validated `run.progress` config: emit one [`ProgressLine`] every
/// `every` of sim-time to `out` (a file path; `None` = stderr).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProgressConfig {
    pub every: SimTime,
    pub out: Option<String>,
}

// ----------------------------------------------------------- progress line

/// One JSONL snapshot of a running session. Deterministic fields first;
/// the wall-clock tail (`wall_s`, `rss_kb`) last, so checkpoint/resume
/// differentials can strip it with a textual cut at `,"wall_s":`.
#[derive(Debug, Clone, Default)]
pub struct ProgressLine {
    pub t_s: f64,
    pub alive: u64,
    pub rounds: u64,
    pub events: u64,
    pub msgs: u64,
    pub bytes_total: u64,
    pub bytes_goodput: u64,
    pub bytes_dropped: u64,
    pub bytes_retrans: u64,
    pub round_p50_s: f64,
    pub round_p95_s: f64,
    pub lat_p50_ms: f64,
    pub lat_p95_ms: f64,
    pub xfer_p50_b: u64,
    pub peers_est: u64,
    pub trainers_est: u64,
    pub wall_s: f64,
    pub rss_kb: u64,
}

impl ProgressLine {
    /// Render one JSONL line (with trailing newline) into `out`. Appends —
    /// callers clear and reuse the buffer, so steady-state ticks allocate
    /// nothing once the buffer has reached line size.
    pub fn render(&self, out: &mut String) {
        let _ = write!(
            out,
            concat!(
                "{{\"t_s\":{:.6},\"alive\":{},\"rounds\":{},\"events\":{},",
                "\"msgs\":{},\"bytes_total\":{},\"bytes_goodput\":{},",
                "\"bytes_dropped\":{},\"bytes_retrans\":{},",
                "\"round_p50_s\":{:.6},\"round_p95_s\":{:.6},",
                "\"lat_p50_ms\":{:.3},\"lat_p95_ms\":{:.3},",
                "\"xfer_p50_b\":{},\"peers_est\":{},\"trainers_est\":{},",
                "\"wall_s\":{:.3},\"rss_kb\":{}}}\n"
            ),
            self.t_s,
            self.alive,
            self.rounds,
            self.events,
            self.msgs,
            self.bytes_total,
            self.bytes_goodput,
            self.bytes_dropped,
            self.bytes_retrans,
            self.round_p50_s,
            self.round_p95_s,
            self.lat_p50_ms,
            self.lat_p95_ms,
            self.xfer_p50_b,
            self.peers_est,
            self.trainers_est,
            self.wall_s,
            self.rss_kb,
        );
    }
}

/// Peak resident set size in kB from `/proc/self/status` (`VmHWM`), best
/// effort: 0 where unreadable (non-Linux). `buf` is a caller-owned scratch
/// buffer so steady-state ticks don't grow the heap.
pub fn peak_rss_kb(buf: &mut String) -> u64 {
    buf.clear();
    use std::io::Read as _;
    let Ok(mut f) = std::fs::File::open("/proc/self/status") else {
        return 0;
    };
    if f.read_to_string(buf).is_err() {
        return 0;
    }
    for line in buf.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0);
        }
    }
    0
}

// ------------------------------------------------------- per-session state

/// The harness-side observability state folded into `SessionMetrics`:
/// round-duration and message-latency histograms (µs) plus the
/// distinct-trainers sketch. Serialized as its own `"obs"` snapshot
/// section (format v3).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ObsState {
    /// Round durations, µs (consecutive round-start gaps).
    pub round_hist: StreamHistogram,
    /// Message delivery latencies, µs (send → deliver).
    pub latency_hist: StreamHistogram,
    /// Distinct nodes that completed a training job.
    pub trainers: Hll,
}

impl ObsState {
    /// Install the dedicated `fork("obs")` salt (no-op after inserts).
    pub fn set_salt(&mut self, salt: u64) {
        self.trainers.set_salt(salt);
    }

    pub fn write_into(&self, w: &mut SnapshotWriter) {
        self.round_hist.write_into(w);
        self.latency_hist.write_into(w);
        self.trainers.write_into(w);
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<ObsState> {
        Ok(ObsState {
            round_hist: StreamHistogram::read_from(r)?,
            latency_hist: StreamHistogram::read_from(r)?,
            trainers: Hll::read_from(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_are_monotone_and_covering() {
        // Every value lands in exactly one bucket whose [lo, hi] range is
        // contiguous with its neighbours'.
        let mut prev_hi: i128 = -1;
        for idx in 0..HIST_BUCKETS {
            let (lo, hi) = if idx < 16 {
                (idx as u64, idx as u64)
            } else {
                let e = idx / 16 + 3;
                let lo = (1u64 << e) + (((idx % 16) as u64) << (e - 4));
                (lo, lo + (1u64 << (e - 4)) - 1)
            };
            assert_eq!(lo as i128, prev_hi + 1, "gap before bucket {idx}");
            prev_hi = hi as i128;
            assert_eq!(hist_bucket(lo), idx);
            assert_eq!(hist_bucket(hi), idx);
            assert_eq!(hist_rep(idx), hi);
        }
        assert_eq!(hist_bucket(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn hist_quantiles_within_relative_bound() {
        // LCG-driven sample vs the exact order statistic: the bucket upper
        // bound over-estimates by less than 1/16.
        let mut h = StreamHistogram::new();
        let mut vals = Vec::new();
        let mut x = 0x2545F4914F6CDD1Du64;
        for _ in 0..20_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = 1 + (x >> 40); // ~[1, 2^24]
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.95, 0.99] {
            let est = h.quantile(q) as f64;
            let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[rank - 1] as f64;
            let err = (est - exact).abs() / exact;
            assert!(err <= 0.0625 + 1e-9, "q={q}: est {est} vs exact {exact} ({err:.4})");
        }
        assert_eq!(h.quantile(0.0), *vals.first().unwrap());
        assert_eq!(h.quantile(1.0), *vals.last().unwrap());
    }

    #[test]
    fn hist_merge_is_associative_and_deterministic() {
        let fill = |seed: u64, n: u64| {
            let mut h = StreamHistogram::new();
            let mut x = seed;
            for _ in 0..n {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                h.record(x >> 44);
            }
            h
        };
        let (a, b, c) = (fill(1, 500), fill(2, 800), fill(3, 300));
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "merge not associative");
        assert_eq!(left.total(), 1600);
        assert_eq!(fill(7, 1000), fill(7, 1000), "record not deterministic");
    }

    #[test]
    fn hll_estimates_within_documented_bound() {
        // Salts mirror the python oracle in the design notes; 5% is the
        // documented bound (σ ≈ 1.6% at 2^12 registers).
        for n in [1_000u64, 100_000] {
            for salt_seed in [0u64, 1, 0xCAFE] {
                let mut hll = Hll::with_salt(mix64(salt_seed));
                for i in 0..n {
                    hll.insert(i.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(7));
                }
                let est = hll.estimate();
                let err = (est - n as f64).abs() / n as f64;
                assert!(err <= 0.05, "n={n} salt={salt_seed}: est {est:.1} ({err:.4})");
            }
        }
    }

    #[test]
    fn hll_merge_equals_union_and_duplicates_are_free() {
        let salt = mix64(9);
        let mut a = Hll::with_salt(salt);
        let mut b = Hll::with_salt(salt);
        let mut union = Hll::with_salt(salt);
        for i in 0..5_000u64 {
            a.insert(i);
            union.insert(i);
        }
        for i in 2_500..7_500u64 {
            b.insert(i);
            union.insert(i);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.registers, union.registers, "merge != union sketch");
        // Re-inserting everything changes nothing.
        let before = union.registers.clone();
        for i in 0..7_500u64 {
            union.insert(i);
        }
        assert_eq!(union.registers, before);
    }

    #[test]
    fn round_window_matches_full_materialization_oracle() {
        let mut w = RoundWindow::default();
        let mut oracle: Vec<(u64, f64)> = Vec::new();
        for r in 0..10_000u64 {
            let t = r as f64 * 0.37;
            w.record(r, t);
            oracle.push((r, t));
        }
        assert_eq!(w.seen(), oracle.len() as u64);
        assert_eq!(w.first(), Some(oracle[0]));
        assert_eq!(w.last(), oracle.last().copied());
        assert_eq!(w.len(), ROUND_WINDOW);
        let tail: Vec<(u64, f64)> = w.iter().collect();
        assert_eq!(tail.as_slice(), &oracle[oracle.len() - ROUND_WINDOW..]);
        // Below capacity the window IS the full materialization.
        let mut small = RoundWindow::default();
        for r in 0..100u64 {
            small.record(r, r as f64);
        }
        let all: Vec<(u64, f64)> = small.iter().collect();
        assert_eq!(all.len(), 100);
        assert_eq!(small.len(), 100);
    }

    #[test]
    fn obs_state_snapshot_roundtrips_byte_identically() {
        let mut obs = ObsState::default();
        obs.set_salt(0xDEC0DE);
        for i in 0..3_000u64 {
            obs.round_hist.record(i * 17 + 3);
            obs.latency_hist.record(i % 977);
            obs.trainers.insert(i % 700);
        }
        let write = |o: &ObsState| {
            let mut w = SnapshotWriter::new();
            w.begin_section("obs");
            o.write_into(&mut w);
            w.end_section();
            w.finish()
        };
        let bytes = write(&obs);
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("obs").unwrap();
        let back = ObsState::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(back, obs);
        // write→read→write byte identity: the wire form is canonical.
        assert_eq!(write(&back), bytes);
    }

    #[test]
    fn round_window_snapshot_roundtrips_after_eviction() {
        let mut w = RoundWindow::default();
        for r in 0..(ROUND_WINDOW as u64 + 123) {
            w.record(r, r as f64 * 0.5);
        }
        let write = |win: &RoundWindow| {
            let mut sw = SnapshotWriter::new();
            sw.begin_section("w");
            win.write_into(&mut sw);
            sw.end_section();
            sw.finish()
        };
        let bytes = write(&w);
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("w").unwrap();
        let back = RoundWindow::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(back, w);
        assert_eq!(write(&back), bytes);
    }

    #[test]
    fn progress_line_renders_wall_fields_last() {
        let mut buf = String::new();
        ProgressLine { t_s: 5.0, bytes_total: 10, bytes_goodput: 10, ..Default::default() }
            .render(&mut buf);
        assert!(buf.starts_with("{\"t_s\":5.000000,"), "{buf}");
        assert!(buf.ends_with("}\n"), "{buf}");
        let cut = buf.find(",\"wall_s\":").expect("wall tail missing");
        // Everything after the cut is the non-deterministic tail.
        assert!(buf[cut..].contains("\"rss_kb\":"));
        // The stripped prefix is itself followed only by the tail.
        assert!(!buf[..cut].contains("wall_s"));
    }
}
