//! Deterministic RNG for the simulator: splittable xoshiro256**.
//!
//! Every source of randomness in a session (latency jitter, data
//! partitioning, node compute heterogeneity, churn schedules, batch order)
//! derives from the session seed through labelled streams, so a session is
//! exactly reproducible from its config — a property the proptest suite and
//! the experiment harness both rely on.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct SimRng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl SimRng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        SimRng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream for a labelled purpose.
    ///
    /// The label is hashed (FNV-1a) together with the parent's next output,
    /// so `fork("data")` and `fork("latency")` never collide.
    pub fn fork(&mut self, label: &str) -> SimRng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        SimRng::new(h ^ self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with given mean.
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -mean * u.ln()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 supported through boost).
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.next_gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) over `k` categories.
    pub fn next_dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SimRng::new(7);
        let mut b = SimRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = SimRng::new(7);
        let mut a = root.fork("data");
        let mut root2 = SimRng::new(7);
        let mut b = root2.fork("latency");
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = SimRng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SimRng::new(2);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = SimRng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = SimRng::new(4);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.next_dirichlet(alpha, 12);
            assert_eq!(d.len(), 12);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_peaky() {
        let mut r = SimRng::new(5);
        let d = r.next_dirichlet(0.05, 10);
        let max = d.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.5, "alpha=0.05 should concentrate: {d:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SimRng::new(6);
        let s = r.sample_indices(50, 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SimRng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }
}
