//! Deterministic RNG for the simulator: splittable xoshiro256**.
//!
//! Every source of randomness in a session (latency jitter, data
//! partitioning, node compute heterogeneity, churn schedules, batch order)
//! derives from the session seed through labelled streams, so a session is
//! exactly reproducible from its config — a property the proptest suite and
//! the experiment harness both rely on.
//!
//! Peer sampling is **versioned** ([`SamplingVersion`]): the historical
//! full-shuffle stream (`v1`) stays bit-identical forever, while `v2` draws
//! the same set distribution in O(k) time and memory for the 100k-node fast
//! path. Sessions select a version through `ScenarioSpec.run.sampling`.

use std::collections::HashMap;

/// Which peer-sampling stream a session draws from.
///
/// Both versions sample `k` distinct indices uniformly from `[0, n)` — the
/// *set distribution* is identical — but they consume the RNG stream
/// differently, so same-seed session fingerprints are only stable within a
/// version. `V1Shuffle` is the historical default and must never change;
/// `V2Partial` is the O(k) stream for large populations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplingVersion {
    /// Full Fisher–Yates shuffle of `[0, n)` truncated to `k`: O(n) time,
    /// one O(n) allocation, exactly `n - 1` `gen_range` draws. The stream
    /// every pre-versioning session fingerprint was recorded under.
    #[default]
    V1Shuffle,
    /// Partial front Fisher–Yates over an implicit identity array (a small
    /// map holds only displaced slots): O(k) time and memory, exactly `k`
    /// `gen_range` draws. Use for n ≫ k populations (100k-node sessions).
    V2Partial,
}

impl SamplingVersion {
    /// Parse the JSON/CLI spelling (`"v1"` | `"v2"`).
    pub fn parse(s: &str) -> anyhow::Result<SamplingVersion> {
        match s {
            "v1" => Ok(SamplingVersion::V1Shuffle),
            "v2" => Ok(SamplingVersion::V2Partial),
            other => {
                anyhow::bail!("unknown sampling version {other:?} (expected \"v1\" or \"v2\")")
            }
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SamplingVersion::V1Shuffle => "v1",
            SamplingVersion::V2Partial => "v2",
        }
    }
}

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct SimRng {
    s: [u64; 4],
    /// Count of raw `next_u64` outputs (complexity assertions in tests;
    /// one wrapping add per draw, noise-level on the hot path).
    draws: u64,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl SimRng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        SimRng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            draws: 0,
        }
    }

    /// How many raw `next_u64` outputs this stream has produced. Used by
    /// the sampling complexity tests (`V2Partial` must stay O(k) at
    /// n = 100k); not part of the reproducibility contract.
    pub fn draw_count(&self) -> u64 {
        self.draws
    }

    /// The complete serializable state of this stream: the four xoshiro256**
    /// words plus the draw counter. There is no other hidden state (no
    /// cached Gaussian spare — `next_gaussian` computes both Box–Muller
    /// branches fresh), so `from_state(state())` resumes the stream
    /// bit-identically, including every future `fork` derivation (forks
    /// hash the label with the parent's *next output*, a pure function of
    /// `s`).
    pub fn state(&self) -> ([u64; 4], u64) {
        (self.s, self.draws)
    }

    /// Rebuild a stream from [`SimRng::state`]. The restored stream's
    /// `next_u64`/`fork`/`draw_count` sequences continue exactly where the
    /// saved stream left off.
    pub fn from_state(s: [u64; 4], draws: u64) -> SimRng {
        SimRng { s, draws }
    }

    /// Derive an independent stream for a labelled purpose.
    ///
    /// The label is hashed (FNV-1a) together with the parent's next output,
    /// so `fork("data")` and `fork("latency")` never collide.
    pub fn fork(&mut self, label: &str) -> SimRng {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        SimRng::new(h ^ self.next_u64())
    }

    pub fn next_u64(&mut self) -> u64 {
        self.draws = self.draws.wrapping_add(1);
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with given mean.
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -mean * u.ln()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 supported through boost).
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.next_gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_gaussian();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1) over `k` categories.
    pub fn next_dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.next_gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// Fisher–Yates shuffle.
    ///
    /// RNG-stream contract: consumes exactly `len - 1` `gen_range` draws
    /// for slices of length >= 2 and exactly **zero** draws for empty or
    /// single-element slices (the early return below — there is nothing to
    /// permute, so no stream entropy may be spent). Callers rely on exact
    /// draw counts for same-seed reproducibility; never add or remove
    /// draws here.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        if v.len() <= 1 {
            return;
        }
        for i in (1..v.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    ///
    /// This is the **V1** sampling stream ([`SamplingVersion::V1Shuffle`]):
    /// a full shuffle truncated to `k` — O(n) work, an O(n) allocation,
    /// and exactly `n - 1` `gen_range` draws regardless of `k`. Every
    /// pre-versioning session fingerprint was recorded against this exact
    /// draw sequence, so its behaviour is frozen; large-n callers opt into
    /// [`SimRng::sample_indices_v2`] through the scenario's `run.sampling`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order,
    /// in O(k) time and memory.
    ///
    /// This is the **V2** sampling stream ([`SamplingVersion::V2Partial`]):
    /// a partial front Fisher–Yates over an *implicit* identity array.
    /// Draw-sequence contract: for `i` in `0..k` the stream consumes
    /// exactly one `gen_range(n - i)` draw selecting swap target
    /// `j = i + draw`; the output is the (virtual) value at slot `j`, and
    /// slot `j` inherits slot `i`'s value. Only displaced slots are stored
    /// (a map of at most `k` entries), so no O(n) array is ever
    /// materialized. The distribution over ordered k-tuples — and hence
    /// over sets — is identical to [`SimRng::sample_indices`]; the byte
    /// stream is not, which is why the version is part of a scenario's
    /// reproducibility fingerprint.
    pub fn sample_indices_v2(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut out = Vec::with_capacity(k);
        let mut displaced: HashMap<usize, usize> = HashMap::with_capacity(k);
        for i in 0..k {
            let j = i + self.gen_range((n - i) as u64) as usize;
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            out.push(vj);
            // Slot j inherits slot i's value; slot i is never read again
            // (future swap targets are all > i), so it needs no entry.
            displaced.insert(j, vi);
        }
        out
    }

    /// Version-dispatched sampling: the one entry point session code uses,
    /// so a scenario's `run.sampling` selects the stream everywhere at
    /// once.
    pub fn sample_indices_versioned(
        &mut self,
        version: SamplingVersion,
        n: usize,
        k: usize,
    ) -> Vec<usize> {
        match version {
            SamplingVersion::V1Shuffle => self.sample_indices(n, k),
            SamplingVersion::V2Partial => self.sample_indices_v2(n, k),
        }
    }

    /// Sample up to `k` distinct indices from `[0, n)` minus `excluded` —
    /// the all-alive "every id but one" fast path shared by
    /// `Ctx::sample_peers` (excluding the sender) and the FedAvg
    /// participant draw (excluding the server). Draws exactly one
    /// `sample_indices_versioned(n - 1, k')` call and remaps the picks
    /// around the hole, so the stream equals sampling from the
    /// materialized peer list — keep both properties in sync with any
    /// caller-side slow path.
    pub fn sample_indices_excluding(
        &mut self,
        version: SamplingVersion,
        n: usize,
        excluded: usize,
        k: usize,
    ) -> Vec<usize> {
        assert!(excluded < n, "exclude {excluded} from [0, {n})");
        let m = n - 1;
        if m == 0 {
            return Vec::new();
        }
        let k = k.min(m);
        self.sample_indices_versioned(version, m, k)
            .into_iter()
            .map(|i| if i < excluded { i } else { i + 1 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SimRng::new(7);
        let mut b = SimRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = SimRng::new(7);
        let mut a = root.fork("data");
        let mut root2 = SimRng::new(7);
        let mut b = root2.fork("latency");
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = SimRng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SimRng::new(2);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = SimRng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = SimRng::new(4);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.next_dirichlet(alpha, 12);
            assert_eq!(d.len(), 12);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_alpha_is_peaky() {
        let mut r = SimRng::new(5);
        let d = r.next_dirichlet(0.05, 10);
        let max = d.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.5, "alpha=0.05 should concentrate: {d:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = SimRng::new(6);
        let s = r.sample_indices(50, 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SimRng::new(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn shuffle_consumes_no_draws_for_trivial_slices() {
        // The RNG-stream contract: len <= 1 must spend zero entropy, so a
        // caller interleaving trivial shuffles replays identically.
        let mut r = SimRng::new(11);
        let before = r.draw_count();
        r.shuffle::<u32>(&mut []);
        r.shuffle(&mut [42u32]);
        assert_eq!(r.draw_count(), before);
        let mut two = [1u32, 2];
        r.shuffle(&mut two);
        assert!(r.draw_count() > before);
    }

    #[test]
    fn v1_sample_stream_is_bit_stable() {
        // Golden vector pinned from the frozen V1 draw sequence (full
        // Fisher–Yates truncated to k). If this test ever fails, the V1
        // stream changed and every recorded same-seed session fingerprint
        // breaks with it — that is exactly what SamplingVersion exists to
        // prevent. Do NOT update the constant; fix the regression.
        let mut r = SimRng::new(0xD5);
        assert_eq!(
            r.sample_indices(100, 10),
            vec![64, 23, 78, 49, 53, 45, 57, 36, 5, 70]
        );
        let mut r = SimRng::new(6);
        assert_eq!(r.sample_indices(8, 3), vec![1, 2, 3]);
    }

    #[test]
    fn v2_sample_stream_matches_documented_contract() {
        // Golden vector for the V2 draw-sequence contract (one
        // gen_range(n - i) draw per output, partial front Fisher–Yates).
        let mut r = SimRng::new(0xD5);
        assert_eq!(
            r.sample_indices_v2(100, 10),
            vec![9, 62, 24, 40, 13, 12, 14, 86, 97, 74]
        );
        let mut r = SimRng::new(6);
        assert_eq!(r.sample_indices_v2(8, 3), vec![6, 7, 1]);
    }

    #[test]
    fn v2_sample_indices_distinct_and_in_range() {
        let mut r = SimRng::new(13);
        for &(n, k) in &[(1usize, 1usize), (2, 2), (50, 20), (50, 50), (1000, 1)] {
            let s = r.sample_indices_v2(n, k);
            assert_eq!(s.len(), k);
            assert!(s.iter().all(|&i| i < n), "{s:?} out of [0, {n})");
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {s:?}");
        }
        assert!(r.sample_indices_v2(7, 0).is_empty());
    }

    #[test]
    fn v2_full_sample_is_a_permutation() {
        let mut r = SimRng::new(14);
        let mut s = r.sample_indices_v2(64, 64);
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<usize>>());
    }

    #[test]
    fn versioned_dispatch_matches_direct_calls() {
        let mut a = SimRng::new(21);
        let mut b = SimRng::new(21);
        assert_eq!(
            a.sample_indices_versioned(SamplingVersion::V1Shuffle, 40, 6),
            b.sample_indices(40, 6)
        );
        assert_eq!(
            a.sample_indices_versioned(SamplingVersion::V2Partial, 40, 6),
            b.sample_indices_v2(40, 6)
        );
    }

    #[test]
    fn sample_excluding_matches_manual_remap() {
        // The helper must be draw-for-draw identical to sampling from a
        // materialized "every index but `excluded`" list (that is what
        // keeps the all-alive fast paths fingerprint-neutral).
        let mut a = SimRng::new(33);
        let mut b = SimRng::new(33);
        for version in [SamplingVersion::V1Shuffle, SamplingVersion::V2Partial] {
            let got = a.sample_indices_excluding(version, 20, 7, 5);
            let manual: Vec<usize> = b
                .sample_indices_versioned(version, 19, 5)
                .into_iter()
                .map(|i| if i < 7 { i } else { i + 1 })
                .collect();
            assert_eq!(got, manual);
            assert_eq!(got.len(), 5);
            assert!(!got.contains(&7));
            assert!(got.iter().all(|&i| i < 20));
        }
        // n = 1: the only index is excluded — empty, zero draws.
        let before = a.draw_count();
        assert!(a
            .sample_indices_excluding(SamplingVersion::V2Partial, 1, 0, 3)
            .is_empty());
        assert_eq!(a.draw_count(), before);
    }

    #[test]
    fn state_roundtrip_replays_the_stream_bit_identically() {
        // Snapshot/restore contract: `from_state(state())` continues every
        // derived sequence — raw draws, fork-label derivation, draw_count —
        // exactly where the saved stream stopped. Exercised at arbitrary
        // offsets so no hidden state (e.g. a cached Gaussian spare, which
        // SimRng deliberately does not have) can hide between draws.
        let mut a = SimRng::new(0xC0FFEE);
        for warmup in [0usize, 1, 5, 64] {
            for _ in 0..warmup {
                a.next_u64();
                a.next_gaussian();
                a.gen_range(97);
            }
            let (s, draws) = a.state();
            let mut b = SimRng::from_state(s, draws);
            assert_eq!(b.draw_count(), a.draw_count(), "draw_count continuity");
            for _ in 0..32 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
            let mut fa = a.fork("branch");
            let mut fb = b.fork("branch");
            assert_eq!(a.draw_count(), b.draw_count(), "fork consumed one draw on both");
            for _ in 0..16 {
                assert_eq!(fa.next_u64(), fb.next_u64(), "forked streams diverged");
            }
        }
    }

    #[test]
    fn state_golden_vector_matches_reference_port() {
        // Golden constants generated from the exact Python port of
        // splitmix64 + xoshiro256** + the FNV-1a fork derivation. Pins the
        // on-disk meaning of a snapshot's RNG section: if this fails, old
        // snapshots no longer resume bit-identically. Do NOT update the
        // constants; fix the regression.
        let mut r = SimRng::new(0xC0FFEE);
        for _ in 0..5 {
            r.next_u64();
        }
        let (s, draws) = r.state();
        assert_eq!(
            s,
            [
                0x0ed4ceed52f98ad0,
                0x6b8658a5488a5dce,
                0x90e698fdd33b99ff,
                0x6bbfada957669f67
            ]
        );
        assert_eq!(draws, 5);
        let mut restored = SimRng::from_state(s, draws);
        assert_eq!(restored.next_u64(), 0x4eca86e0293e9b6c);
        assert_eq!(restored.next_u64(), 0x534afa30daeeca16);
        assert_eq!(restored.next_u64(), 0xfbcc18b345689622);
        let mut f = restored.fork("branch");
        assert_eq!(f.next_u64(), 0xf359392d6d3e3169);
        assert_eq!(f.next_u64(), 0x0be2a0e20add2b75);
        assert_eq!(restored.draw_count(), 9, "3 draws + the fork's one");
    }

    #[test]
    fn sampling_version_parses_and_prints() {
        assert_eq!(SamplingVersion::parse("v1").unwrap(), SamplingVersion::V1Shuffle);
        assert_eq!(SamplingVersion::parse("v2").unwrap(), SamplingVersion::V2Partial);
        assert!(SamplingVersion::parse("v3").is_err());
        assert_eq!(SamplingVersion::default().as_str(), "v1");
        assert_eq!(SamplingVersion::V2Partial.as_str(), "v2");
    }
}
