//! Virtual time: microsecond-resolution, totally ordered, overflow-checked.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in microseconds since session start.
///
/// Microseconds keep every latency the WAN model produces exactly
/// representable while still covering ~584k years of virtual time in a u64.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_micros(us: u64) -> Self {
        SimTime(us)
    }

    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000)
    }

    pub fn from_secs_f64(s: f64) -> Self {
        debug_assert!(s >= 0.0 && s.is_finite(), "negative/NaN duration: {s}");
        SimTime((s * 1e6).round() as u64)
    }

    pub fn as_micros(self) -> u64 {
        self.0
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_minutes_f64(self) -> f64 {
        self.as_secs_f64() / 60.0
    }

    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_add(rhs.0).expect("virtual time overflow"))
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        *self = *self + rhs;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("virtual time underflow"))
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(SimTime::from_millis(1500).as_secs_f64(), 1.5);
        assert_eq!(SimTime::from_secs_f64(2.5).as_micros(), 2_500_000);
        assert_eq!(SimTime::from_micros(42).as_micros(), 42);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_millis(100);
        let b = SimTime::from_millis(250);
        assert_eq!((a + b).as_micros(), 350_000);
        assert_eq!((b - a).as_micros(), 150_000);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    fn ordering_is_total() {
        let mut v = vec![
            SimTime::from_millis(3),
            SimTime::ZERO,
            SimTime::from_micros(1),
        ];
        v.sort();
        assert_eq!(v[0], SimTime::ZERO);
        assert_eq!(v[2], SimTime::from_millis(3));
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime::ZERO - SimTime::from_micros(1);
    }
}
