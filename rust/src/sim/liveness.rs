//! Protocol-side liveness mirror: the churn bookkeeping every
//! leaderless protocol was copying.
//!
//! The harness owns the authoritative liveness table ([`super::Status`])
//! and drops events at dead nodes, but a protocol still needs its own view
//! of who is live to (1) keep the round-start trace monotone when churn
//! moves the recording node, (2) filter evaluation and `final_round` to
//! live replicas, and (3) decide "is anyone left". Gossip-DL and D-SGD
//! each grew an identical `dead: Vec<bool>` + `started: Round` +
//! lowest-live-recorder idiom; [`LivenessMirror`] is that idiom extracted
//! once, before a third protocol copies it again (ROADMAP item).
//!
//! Everything here is pure bookkeeping — no RNG, no event scheduling — so
//! adopting the mirror cannot change a session's event order or its
//! same-seed fingerprint (the gossip/D-SGD churn tests pin that).

use crate::{NodeId, Round};

/// Dead/live flags plus the monotone round-start recorder.
#[derive(Debug, Clone)]
pub struct LivenessMirror {
    /// `true` = crashed/left (or a scripted joiner that has not joined).
    dead: Vec<bool>,
    /// Highest round recorded so far (keeps the trace monotone when churn
    /// hands the recorder role to a different node).
    started: Round,
}

impl LivenessMirror {
    /// All `n` nodes start live.
    pub fn all_live(n: usize) -> LivenessMirror {
        LivenessMirror { dead: vec![false; n], started: 0 }
    }

    /// `total` node slots of which the first `live` start live — the
    /// shape of a session whose churn script introduces joiners later.
    pub fn with_live_prefix(total: usize, live: usize) -> LivenessMirror {
        LivenessMirror { dead: (0..total).map(|i| i >= live).collect(), started: 0 }
    }

    pub fn len(&self) -> usize {
        self.dead.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dead.is_empty()
    }

    /// Ids outside the table count as dead (same defensive contract as the
    /// harness's own dispatch check).
    pub fn is_dead(&self, i: usize) -> bool {
        self.dead.get(i).copied().unwrap_or(true)
    }

    pub fn set_dead(&mut self, i: usize) {
        if let Some(d) = self.dead.get_mut(i) {
            *d = true;
        }
    }

    pub fn set_live(&mut self, i: usize) {
        if let Some(d) = self.dead.get_mut(i) {
            *d = false;
        }
    }

    pub fn any_live(&self) -> bool {
        self.dead.iter().any(|&d| !d)
    }

    /// Indices of live nodes, ascending (evaluation subsampling).
    pub fn live_indices(&self) -> Vec<usize> {
        (0..self.dead.len()).filter(|&i| !self.dead[i]).collect()
    }

    /// The node that records round starts: the lowest live id (node 0
    /// unless churn killed it). `None` during a total outage.
    pub fn recorder(&self) -> Option<usize> {
        self.dead.iter().position(|&d| !d)
    }

    /// Highest round recorded so far.
    pub fn started(&self) -> Round {
        self.started
    }

    /// Bootstrap: the caller recorded `round` itself (e.g. round 1 at
    /// t=0); pin the monotone guard there.
    pub fn force_started(&mut self, round: Round) {
        self.started = round;
    }

    /// True exactly when `node` is the current recorder and `round`
    /// advances the trace; updates the guard so each round is recorded
    /// once. The caller then calls `ctx.record_round_start(round)`.
    pub fn should_record(&mut self, node: NodeId, round: Round) -> bool {
        if self.recorder() == Some(node as usize) && round > self.started {
            self.started = round;
            true
        } else {
            false
        }
    }

    /// Minimum of `rounds` over live nodes (the session's `final_round`);
    /// 0 during a total outage. `rounds` must iterate node-table order.
    pub fn min_live_round<I: IntoIterator<Item = Round>>(&self, rounds: I) -> Round {
        rounds
            .into_iter()
            .zip(&self.dead)
            .filter(|&(_, &dead)| !dead)
            .map(|(r, _)| r)
            .min()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_construction_marks_joiners_dead() {
        let m = LivenessMirror::with_live_prefix(5, 3);
        assert_eq!(m.len(), 5);
        assert!(!m.is_dead(0) && !m.is_dead(2));
        assert!(m.is_dead(3) && m.is_dead(4));
        assert!(m.is_dead(99), "out-of-table ids are dead");
        assert_eq!(m.live_indices(), vec![0, 1, 2]);
    }

    #[test]
    fn recorder_is_lowest_live_and_hands_off_on_crash() {
        let mut m = LivenessMirror::all_live(4);
        assert_eq!(m.recorder(), Some(0));
        m.set_dead(0);
        assert_eq!(m.recorder(), Some(1));
        m.set_dead(1);
        m.set_dead(2);
        m.set_dead(3);
        assert_eq!(m.recorder(), None);
        assert!(!m.any_live());
        m.set_live(2); // revival
        assert_eq!(m.recorder(), Some(2));
    }

    #[test]
    fn trace_stays_monotone_across_recorder_handoff() {
        // The exact crash/leave/revival sequence the gossip churn tests
        // exercise: node 0 records 1..3, crashes, node 1 takes over — but
        // must not re-record a round <= 3; a revival of node 0 reclaims
        // the role with the guard intact.
        let mut m = LivenessMirror::all_live(3);
        assert!(m.should_record(0, 1));
        assert!(m.should_record(0, 2));
        assert!(m.should_record(0, 3));
        assert!(!m.should_record(1, 4), "non-recorder must not record");
        m.set_dead(0);
        assert!(!m.should_record(1, 3), "stale round after handoff");
        assert!(m.should_record(1, 4));
        m.set_live(0); // recover: lowest live again
        assert!(!m.should_record(1, 5), "role returned to node 0");
        assert!(m.should_record(0, 5));
        assert_eq!(m.started(), 5);
    }

    #[test]
    fn repeated_rounds_record_once() {
        let mut m = LivenessMirror::all_live(2);
        assert!(m.should_record(0, 1));
        assert!(!m.should_record(0, 1));
        assert!(m.should_record(0, 2));
    }

    #[test]
    fn force_started_pins_bootstrap_round() {
        let mut m = LivenessMirror::all_live(2);
        m.force_started(1);
        assert!(!m.should_record(0, 1));
        assert!(m.should_record(0, 2));
    }

    #[test]
    fn min_live_round_filters_dead_nodes() {
        let mut m = LivenessMirror::all_live(4);
        let rounds = [7u64, 3, 9, 5];
        assert_eq!(m.min_live_round(rounds.iter().copied()), 3);
        m.set_dead(1); // the slowest node dies: min moves to a live one
        assert_eq!(m.min_live_round(rounds.iter().copied()), 5);
        m.set_dead(0);
        m.set_dead(2);
        m.set_dead(3);
        assert_eq!(m.min_live_round(rounds.iter().copied()), 0);
    }

    #[test]
    fn join_sequence_extends_live_set() {
        let mut m = LivenessMirror::with_live_prefix(4, 2);
        assert_eq!(m.live_indices(), vec![0, 1]);
        m.set_live(2); // scripted Join fires
        m.set_dead(0); // then the original recorder leaves
        assert_eq!(m.live_indices(), vec![1, 2]);
        assert_eq!(m.recorder(), Some(1));
    }
}
