//! Shared ack/timeout/retransmit layer for lossy sessions.
//!
//! With `network.loss` configured, the fabric may drop any transfer in
//! flight; protocols that need a message to arrive route it through a
//! [`ReliableOutbox`]: the message is sent with an embedded sequence
//! number, a retransmit timer is armed at the *sender* through the
//! existing [`Ctx::schedule_timer`] machinery, and the receiver answers
//! with a protocol-level ack carrying the same seq. Retransmits back off
//! exponentially (`timeout · backoff^(attempt−1)`, capped at
//! `max_timeout`) up to a retry cap; when the cap is exhausted the entry
//! is handed back as [`TimerVerdict::Expired`] and the protocol runs its
//! degradation path (aggregate without the model, re-sample the
//! participant, …).
//!
//! Determinism: the outbox draws no randomness — sequence numbers are a
//! counter, timer delays are pure functions of the config — and lossless
//! sessions never construct one, so the pre-loss event stream is
//! untouched. Stale acks (a retransmit raced the original's ack) hit a
//! missing map entry and are ignored; duplicate *deliveries* are the
//! receiving protocol's job: handle idempotently and re-ack, because the
//! first ack may itself have been lost.
//!
//! Timer-id space: ids with [`RELIABLE_TIMER_BIT`] set belong to the
//! outbox. Protocols route those to [`ReliableOutbox::on_timer`] and keep
//! their own ids below the bit.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::net::MsgKind;
use crate::sim::harness::Ctx;
use crate::sim::snapshot::{SnapshotReader, SnapshotWriter};
use crate::sim::SimTime;
use crate::NodeId;

/// Timer ids with this bit set are retransmit timers owned by a
/// [`ReliableOutbox`]; the low 62 bits carry the sequence number.
pub const RELIABLE_TIMER_BIT: u64 = 1 << 63;

/// Most parts a tracked message can carry (model + view + control +
/// membership — one slot per [`MsgKind`]).
const MAX_PARTS: usize = 4;

/// The timeout/retransmit contract, compiled from `network.loss`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReliabilityConfig {
    /// Ack deadline for the first transmission.
    pub timeout: SimTime,
    /// Multiplicative backoff per retransmit (>= 1).
    pub backoff: f64,
    /// Ceiling on the backed-off deadline.
    pub max_timeout: SimTime,
    /// Retransmissions after the initial send before giving up.
    pub retries: u32,
}

impl ReliabilityConfig {
    /// Ack deadline armed after transmission number `attempt` (1-based:
    /// the initial send is attempt 1).
    pub fn delay(&self, attempt: u32) -> SimTime {
        let factor = self.backoff.powi(attempt.saturating_sub(1) as i32);
        let d = SimTime::from_secs_f64(self.timeout.as_secs_f64() * factor);
        d.min(self.max_timeout)
    }

    /// Worst-case span from the initial send to expiry: the sum of every
    /// attempt's deadline. Receivers that arm degradation backstops (the
    /// aggregator deadline, the D-SGD barrier timeout) size them off this
    /// so the backstop cannot fire while a retransmit could still land.
    pub fn expiry_window(&self) -> SimTime {
        let mut total = SimTime::ZERO;
        for attempt in 1..=self.retries + 1 {
            total += self.delay(attempt);
        }
        total
    }
}

/// One tracked message awaiting its ack.
#[derive(Debug, Clone)]
pub struct Pending<M> {
    pub from: NodeId,
    pub to: NodeId,
    parts: [(MsgKind, u64); MAX_PARTS],
    nparts: u8,
    pub msg: M,
    /// Transmissions so far (1 after the initial send).
    pub attempts: u32,
}

impl<M> Pending<M> {
    pub fn parts(&self) -> &[(MsgKind, u64)] {
        &self.parts[..self.nparts as usize]
    }
}

/// What [`ReliableOutbox::on_timer`] made of a timer id.
pub enum TimerVerdict<M> {
    /// Not a retransmit timer — the protocol's own id space.
    NotOurs,
    /// Consumed: either the message was already acked, or a retransmit
    /// went out and a new deadline is armed.
    Handled,
    /// The retry cap is exhausted; the protocol owns the degradation.
    Expired(Pending<M>),
}

/// Per-protocol retransmit ledger. One outbox serves every node in the
/// session (entries carry their sender); protocols hold `Option<...>` and
/// leave it `None` in lossless sessions so tracked sends decay to plain
/// [`Ctx::send`] calls with zero bookkeeping.
#[derive(Debug)]
pub struct ReliableOutbox<M> {
    cfg: ReliabilityConfig,
    /// Next sequence number; 0 is reserved for "untracked".
    next_seq: u64,
    /// Keyed by seq. BTreeMap: snapshot iteration order is the insertion
    /// (= seq) order, deterministically.
    inflight: BTreeMap<u64, Pending<M>>,
}

impl<M: Clone> ReliableOutbox<M> {
    pub fn new(cfg: ReliabilityConfig) -> Self {
        ReliableOutbox { cfg, next_seq: 1, inflight: BTreeMap::new() }
    }

    pub fn cfg(&self) -> &ReliabilityConfig {
        &self.cfg
    }

    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Send a tracked message: allocate a seq, build the concrete message
    /// via `make(seq)` (the protocol embeds the seq so the receiver can
    /// ack it), transmit, and arm the first retransmit deadline at the
    /// sender. Returns the seq.
    pub fn track(
        &mut self,
        ctx: &mut Ctx<'_, M>,
        from: NodeId,
        to: NodeId,
        parts: &[(MsgKind, u64)],
        make: impl FnOnce(u64) -> M,
    ) -> u64 {
        assert!(parts.len() <= MAX_PARTS, "tracked message with {} parts", parts.len());
        let seq = self.next_seq;
        self.next_seq += 1;
        debug_assert_eq!(seq & RELIABLE_TIMER_BIT, 0, "seq overflowed into the timer tag bit");
        let msg = make(seq);
        let mut fixed = [(MsgKind::Control, 0u64); MAX_PARTS];
        fixed[..parts.len()].copy_from_slice(parts);
        self.inflight.insert(
            seq,
            Pending {
                from,
                to,
                parts: fixed,
                nparts: parts.len() as u8,
                msg: msg.clone(),
                attempts: 1,
            },
        );
        ctx.send(from, to, parts, msg);
        ctx.schedule_timer(self.cfg.delay(1), from, RELIABLE_TIMER_BIT | seq);
        seq
    }

    /// An ack for `seq` arrived. Returns `false` for stale acks (already
    /// acked, or expired before the ack landed) — callers ignore those.
    pub fn ack(&mut self, seq: u64) -> bool {
        self.inflight.remove(&seq).is_some()
    }

    /// Route a fired timer. Protocols call this first in `on_timer` and
    /// only interpret `id` themselves on [`TimerVerdict::NotOurs`].
    pub fn on_timer(&mut self, ctx: &mut Ctx<'_, M>, id: u64) -> TimerVerdict<M> {
        if id & RELIABLE_TIMER_BIT == 0 {
            return TimerVerdict::NotOurs;
        }
        let seq = id & !RELIABLE_TIMER_BIT;
        let Some(pending) = self.inflight.get_mut(&seq) else {
            return TimerVerdict::Handled; // acked before the deadline
        };
        if pending.attempts >= self.cfg.retries + 1 {
            let pending = self.inflight.remove(&seq).expect("entry just found");
            return TimerVerdict::Expired(pending);
        }
        pending.attempts += 1;
        let attempts = pending.attempts;
        let (from, to, msg) = (pending.from, pending.to, pending.msg.clone());
        let parts = pending.parts;
        let nparts = pending.nparts as usize;
        ctx.send_retransmit(from, to, &parts[..nparts], msg);
        ctx.schedule_timer(self.cfg.delay(attempts), from, RELIABLE_TIMER_BIT | seq);
        TimerVerdict::Handled
    }

    /// Serialize the retransmit ledger; `write_msg` serializes one tracked
    /// message (protocols reuse their [`crate::sim::Protocol::write_msg`]).
    pub fn write_into(
        &self,
        w: &mut SnapshotWriter,
        mut write_msg: impl FnMut(&mut SnapshotWriter, &M) -> Result<()>,
    ) -> Result<()> {
        w.write_u64(self.next_seq);
        w.write_usize(self.inflight.len());
        for (seq, p) in &self.inflight {
            w.write_u64(*seq);
            w.write_u32(p.from);
            w.write_u32(p.to);
            w.write_u32(p.attempts);
            w.write_u8(p.nparts);
            for &(kind, bytes) in p.parts() {
                w.write_u8(kind.tag());
                w.write_u64(bytes);
            }
            write_msg(w, &p.msg)?;
        }
        Ok(())
    }

    pub fn read_from(
        r: &mut SnapshotReader,
        cfg: ReliabilityConfig,
        mut read_msg: impl FnMut(&mut SnapshotReader) -> Result<M>,
    ) -> Result<ReliableOutbox<M>> {
        let next_seq = r.read_u64()?;
        let n = r.read_usize()?;
        let mut inflight = BTreeMap::new();
        for _ in 0..n {
            let seq = r.read_u64()?;
            let from = r.read_u32()?;
            let to = r.read_u32()?;
            let attempts = r.read_u32()?;
            let nparts = r.read_u8()?;
            anyhow::ensure!(
                (nparts as usize) <= MAX_PARTS,
                "pending message claims {nparts} parts"
            );
            let mut parts = [(MsgKind::Control, 0u64); MAX_PARTS];
            for slot in parts.iter_mut().take(nparts as usize) {
                let kind = MsgKind::from_tag(r.read_u8()?)?;
                let bytes = r.read_u64()?;
                *slot = (kind, bytes);
            }
            let msg = read_msg(r)?;
            inflight.insert(seq, Pending { from, to, parts, nparts, msg, attempts });
        }
        Ok(ReliableOutbox { cfg, next_seq, inflight })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ReliabilityConfig {
        ReliabilityConfig {
            timeout: SimTime::from_secs_f64(2.0),
            backoff: 2.0,
            max_timeout: SimTime::from_secs_f64(5.0),
            retries: 3,
        }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let c = cfg();
        assert_eq!(c.delay(1), SimTime::from_secs_f64(2.0));
        assert_eq!(c.delay(2), SimTime::from_secs_f64(4.0));
        assert_eq!(c.delay(3), SimTime::from_secs_f64(5.0)); // capped, not 8
        assert_eq!(c.delay(4), SimTime::from_secs_f64(5.0));
        // 2 + 4 + 5 + 5: initial + three retries.
        assert_eq!(c.expiry_window(), SimTime::from_secs_f64(16.0));
    }

    #[test]
    fn flat_backoff_window() {
        let c = ReliabilityConfig {
            timeout: SimTime::from_secs_f64(1.0),
            backoff: 1.0,
            max_timeout: SimTime::from_secs_f64(30.0),
            retries: 2,
        };
        assert_eq!(c.delay(5), SimTime::from_secs_f64(1.0));
        assert_eq!(c.expiry_window(), SimTime::from_secs_f64(3.0));
    }

    #[test]
    fn acks_consume_entries_and_stale_acks_miss() {
        let mut ob: ReliableOutbox<u64> = ReliableOutbox::new(cfg());
        // Seed an entry without a Ctx: the map mechanics are what's under
        // test (the send path is covered by the protocol suites).
        ob.inflight.insert(
            7,
            Pending {
                from: 0,
                to: 1,
                parts: [(MsgKind::Control, 10); MAX_PARTS],
                nparts: 1,
                msg: 99,
                attempts: 1,
            },
        );
        assert_eq!(ob.in_flight(), 1);
        assert!(ob.ack(7), "first ack lands");
        assert!(!ob.ack(7), "duplicate ack is stale");
        assert!(!ob.ack(12345), "unknown seq is stale");
        assert_eq!(ob.in_flight(), 0);
    }

    #[test]
    fn snapshot_roundtrips_pending_entries() {
        let mut ob: ReliableOutbox<u64> = ReliableOutbox::new(cfg());
        ob.next_seq = 42;
        ob.inflight.insert(
            3,
            Pending {
                from: 5,
                to: 9,
                parts: {
                    let mut p = [(MsgKind::Control, 0u64); MAX_PARTS];
                    p[0] = (MsgKind::ModelPayload, 5000);
                    p[1] = (MsgKind::Control, 132);
                    p
                },
                nparts: 2,
                msg: 777,
                attempts: 2,
            },
        );
        let mut w = SnapshotWriter::new();
        w.begin_section("outbox");
        ob.write_into(&mut w, |w, m| {
            w.write_u64(*m);
            Ok(())
        })
        .unwrap();
        w.end_section();
        let bytes = w.finish();

        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("outbox").unwrap();
        let back: ReliableOutbox<u64> =
            ReliableOutbox::read_from(&mut r, cfg(), |r| r.read_u64()).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(back.next_seq, 42);
        assert_eq!(back.in_flight(), 1);
        let p = &back.inflight[&3];
        assert_eq!((p.from, p.to, p.attempts, p.msg), (5, 9, 2, 777));
        assert_eq!(p.parts(), &[(MsgKind::ModelPayload, 5000), (MsgKind::Control, 132)]);
    }
}
