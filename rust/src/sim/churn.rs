//! Churn schedules: scripted joins, graceful leaves, and crashes.
//!
//! The paper's §4.6 experiment joins one node per minute for ten minutes;
//! §4.7 crashes five nodes per minute until 80% are gone. Both are instances
//! of a [`ChurnSchedule`] — a time-ordered list of scripted membership
//! events the session injects into the simulation.

use super::time::SimTime;
use crate::NodeId;

/// What happens to the node at the scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    /// Node joins (Alg. 2 `request join`): advertises to `s` random peers.
    Join,
    /// Node gracefully leaves: advertises `left` before going silent.
    Leave,
    /// Node crashes: becomes silently unresponsive (no advertisement).
    Crash,
    /// Node recovers from a crash and re-joins.
    Recover,
}

/// One scripted membership change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    pub at: SimTime,
    pub node: NodeId,
    pub kind: ChurnKind,
}

/// A time-sorted script of churn events.
#[derive(Debug, Clone, Default)]
pub struct ChurnSchedule {
    events: Vec<ChurnEvent>,
}

impl ChurnSchedule {
    /// Build a schedule from `events`, sorted by `(at, insertion seq)`.
    ///
    /// Tie-order contract: events at the *same instant* keep the order the
    /// caller supplied them in — `sort_by_key` is a stable sort (a
    /// documented guarantee of the std sort, relied on here; the tie-order
    /// tests below pin it), so the effective key is `(at, insertion seq)`
    /// without materializing the index. The harness schedules events into
    /// the DES queue in schedule order — whose pop order is
    /// `(time, insertion seq)` — so same-instant churn applies in exactly
    /// this order. That makes availability-generated schedules (which
    /// routinely emit many events at one instant) reproducible
    /// byte-for-byte across builds and platforms.
    pub fn new(mut events: Vec<ChurnEvent>) -> Self {
        events.sort_by_key(|e| e.at);
        ChurnSchedule { events }
    }

    pub fn empty() -> Self {
        ChurnSchedule::default()
    }

    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// One past the highest node id referenced by any event (0 if none) —
    /// how far a session's node tables must stretch to cover the script.
    pub fn node_extent(&self) -> usize {
        self.events.iter().map(|e| e.node as usize + 1).max().unwrap_or(0)
    }

    /// One past the highest node id that ever joins or recovers (0 if
    /// none) — the only events that may legitimately introduce ids beyond
    /// the initial population.
    pub fn join_extent(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e.kind, ChurnKind::Join | ChurnKind::Recover))
            .map(|e| e.node as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Paper §4.6: `joiners` nodes join one-by-one at `interval`, starting at
    /// `start`. Node ids are `first..first+joiners`.
    pub fn staggered_joins(first: NodeId, joiners: u32, start: SimTime, interval: SimTime) -> Self {
        let events = (0..joiners)
            .map(|i| ChurnEvent {
                at: SimTime(start.0 + interval.0 * i as u64),
                node: first + i,
                kind: ChurnKind::Join,
            })
            .collect();
        ChurnSchedule::new(events)
    }

    /// Paper §4.7: starting at `start`, crash `per_step` nodes every
    /// `interval` until only `survivors` remain out of `total`. The crash
    /// order is by descending node id, so the lowest ids survive (matching
    /// the "20 reliable nodes" framing).
    pub fn mass_crash(
        total: u32,
        survivors: u32,
        per_step: u32,
        start: SimTime,
        interval: SimTime,
    ) -> Self {
        assert!(survivors <= total);
        let mut events = Vec::new();
        let mut next = total;
        let mut step = 0u64;
        while next > survivors {
            for _ in 0..per_step {
                if next == survivors {
                    break;
                }
                next -= 1;
                events.push(ChurnEvent {
                    at: SimTime(start.0 + interval.0 * step),
                    node: next,
                    kind: ChurnKind::Crash,
                });
            }
            step += 1;
        }
        ChurnSchedule::new(events)
    }

    /// Merge two schedules, keeping global time order. Same-instant ties
    /// resolve to `self`'s events before `other`'s (the `(at, insertion
    /// seq)` contract of [`ChurnSchedule::new`] applied to the
    /// concatenation), so merging a hand-written script with an
    /// availability-compiled one is deterministic.
    pub fn merged(self, other: ChurnSchedule) -> ChurnSchedule {
        let mut all = self.events;
        all.extend(other.events);
        ChurnSchedule::new(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staggered_joins_match_paper_setup() {
        // §4.6: 90 initial nodes, 10 joiners at 1-minute intervals.
        let s = ChurnSchedule::staggered_joins(
            90,
            10,
            SimTime::from_secs_f64(60.0),
            SimTime::from_secs_f64(60.0),
        );
        assert_eq!(s.events().len(), 10);
        assert_eq!(s.events()[0].node, 90);
        assert_eq!(s.events()[0].at, SimTime::from_secs_f64(60.0));
        assert_eq!(s.events()[9].node, 99);
        assert_eq!(s.events()[9].at, SimTime::from_secs_f64(600.0));
        assert!(s.events().iter().all(|e| e.kind == ChurnKind::Join));
    }

    #[test]
    fn mass_crash_matches_paper_setup() {
        // §4.7: 100 nodes, crash 5/min from minute 5 until 20 remain.
        let s = ChurnSchedule::mass_crash(
            100,
            20,
            5,
            SimTime::from_secs_f64(300.0),
            SimTime::from_secs_f64(60.0),
        );
        assert_eq!(s.events().len(), 80);
        // 16 steps of 5 crashes.
        assert_eq!(s.events()[0].at, SimTime::from_secs_f64(300.0));
        assert_eq!(
            s.events().last().unwrap().at,
            SimTime::from_secs_f64(300.0 + 15.0 * 60.0)
        );
        // survivors 0..20 never crash
        assert!(s.events().iter().all(|e| e.node >= 20));
    }

    #[test]
    fn schedule_is_time_sorted() {
        let s = ChurnSchedule::new(vec![
            ChurnEvent { at: SimTime::from_millis(30), node: 1, kind: ChurnKind::Crash },
            ChurnEvent { at: SimTime::from_millis(10), node: 2, kind: ChurnKind::Join },
        ]);
        assert!(s.events()[0].at < s.events()[1].at);
    }

    #[test]
    fn same_instant_events_keep_insertion_order() {
        // The (at, insertion seq) tie-order contract: three events pinned
        // to one instant must come out exactly as supplied, after any
        // earlier-timed event.
        let t = SimTime::from_millis(10);
        let s = ChurnSchedule::new(vec![
            ChurnEvent { at: t, node: 5, kind: ChurnKind::Crash },
            ChurnEvent { at: t, node: 2, kind: ChurnKind::Join },
            ChurnEvent { at: SimTime::from_millis(5), node: 9, kind: ChurnKind::Leave },
            ChurnEvent { at: t, node: 1, kind: ChurnKind::Recover },
        ]);
        let order: Vec<(u64, NodeId)> = s.events().iter().map(|e| (e.at.0, e.node)).collect();
        assert_eq!(order, vec![(5_000, 9), (10_000, 5), (10_000, 2), (10_000, 1)]);
    }

    #[test]
    fn merged_ties_keep_self_before_other() {
        let t = SimTime::from_millis(30);
        let a = ChurnSchedule::new(vec![
            ChurnEvent { at: t, node: 0, kind: ChurnKind::Crash },
            ChurnEvent { at: t, node: 1, kind: ChurnKind::Crash },
        ]);
        let b = ChurnSchedule::new(vec![
            ChurnEvent { at: t, node: 2, kind: ChurnKind::Recover },
            ChurnEvent { at: SimTime::from_millis(1), node: 3, kind: ChurnKind::Join },
        ]);
        let m = a.merged(b);
        let nodes: Vec<NodeId> = m.events().iter().map(|e| e.node).collect();
        assert_eq!(nodes, vec![3, 0, 1, 2], "self's same-instant events come first");
    }

    #[test]
    fn merged_preserves_order() {
        let a = ChurnSchedule::staggered_joins(0, 3, SimTime::ZERO, SimTime::from_millis(100));
        let b = ChurnSchedule::mass_crash(10, 9, 1, SimTime::from_millis(50), SimTime::from_millis(100));
        let m = a.merged(b);
        let times: Vec<u64> = m.events().iter().map(|e| e.at.0).collect();
        let mut sorted = times.clone();
        sorted.sort_unstable();
        assert_eq!(times, sorted);
    }
}
