//! Versioned binary snapshot codec: deterministic checkpoint/restore.
//!
//! A snapshot freezes the **complete** state of a running session — RNG
//! streams, `Population` status, `NodeTable` columns, the live event-queue
//! slots, `TrafficLedger`, `SessionMetrics` (including the bounded
//! reservoir's stride state), and per-protocol state via the
//! `Protocol::snapshot`/`Protocol::restore` hooks — such that resuming from
//! the snapshot replays the rest of the session **bit-identically** to an
//! uninterrupted run (the oracle in `tests/snapshot_differential.rs`).
//!
//! Format discipline mirrors `util/json.rs`: no serde, no derives — every
//! byte is written and read by hand so the wire layout is an explicit,
//! reviewable contract. Layout:
//!
//! ```text
//! magic "MDSTSNAP" (8 bytes) | format version (u32 LE)
//! section*  :=  name (len-prefixed str) | body length (u64 LE) | body
//! ```
//!
//! Sections are length-prefixed so a reader can verify it consumed exactly
//! the bytes the writer produced (truncation and drift are loud errors, not
//! silent misreads), and so future format versions can skip sections they
//! do not understand. **Version policy:** any change to a section's byte
//! layout bumps [`SNAPSHOT_VERSION`]; readers reject versions they were not
//! built for — resuming across format versions is never silently attempted.
//!
//! Only *dynamic* state is serialized. Anything deterministically
//! re-derivable from the scenario spec (latency matrix, bandwidth config,
//! topology graphs, calendar-queue bucket geometry, Fenwick trees) is
//! rebuilt on restore — that keeps snapshots small and means performance
//! tuning of derived structures can never invalidate old snapshots.
//!
//! Model payloads (`Arc<Vec<f32>>`) are **interned**: the first write of an
//! `Arc` emits its contents and registers the pointer; later writes of the
//! same `Arc` emit a 4-byte back-reference. The reader rebuilds the same
//! `Arc` graph, so sharing (and therefore memory footprint *and* a
//! write→read→write byte-identical round trip) survives restore.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::learning::Model;

use super::rng::SimRng;
use super::time::SimTime;

/// First 8 bytes of every snapshot file.
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"MDSTSNAP";
/// Current snapshot format version. Bump on ANY wire-layout change.
/// v2: fabric gained per-node bandwidth tiers + the loss layer, the
/// ledger its dropped/retransmitted columns, metrics the goodput split,
/// and protocol sections their reliability outboxes.
/// v3: streaming observability — the harness writes an `obs` section
/// (round/latency histograms + distinct-trainers HLL), the ledger carries
/// its transfer-size histogram and distinct-peers sketch, metrics'
/// round-start record became a bounded ring window and its traffic
/// summary gained `distinct_peers`, and the queue section knows the
/// `ProgressTick` event tag (5).
pub const SNAPSHOT_VERSION: u32 = 3;

/// Sentinel model index meaning "inline payload follows" (vs a back-ref).
const MODEL_INLINE: u32 = u32::MAX;

// ---------------------------------------------------------------- writer

/// Append-only snapshot builder. Sections must be closed in LIFO order;
/// [`SnapshotWriter::finish`] panics on an unbalanced section stack (a
/// programming error, not an I/O condition).
pub struct SnapshotWriter {
    buf: Vec<u8>,
    /// Byte offsets of the open sections' length placeholders.
    open: Vec<usize>,
    /// Arc-pointer → intern index for already-written models.
    models: HashMap<usize, u32>,
}

impl Default for SnapshotWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotWriter {
    pub fn new() -> Self {
        let mut buf = Vec::with_capacity(4096);
        buf.extend_from_slice(&SNAPSHOT_MAGIC);
        buf.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        SnapshotWriter { buf, open: Vec::new(), models: HashMap::new() }
    }

    /// Open a named, length-prefixed section. The length is patched in by
    /// the matching [`SnapshotWriter::end_section`].
    pub fn begin_section(&mut self, name: &str) {
        self.write_str(name);
        self.open.push(self.buf.len());
        self.buf.extend_from_slice(&0u64.to_le_bytes());
    }

    pub fn end_section(&mut self) {
        let start = self.open.pop().expect("end_section without begin_section");
        let body_len = (self.buf.len() - start - 8) as u64;
        self.buf[start..start + 8].copy_from_slice(&body_len.to_le_bytes());
    }

    pub fn finish(self) -> Vec<u8> {
        assert!(self.open.is_empty(), "snapshot finished with an open section");
        self.buf
    }

    pub fn write_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn write_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn write_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn write_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as u64 so 32- and 64-bit builds agree on the wire.
    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Bit-exact: f64 travels as its IEEE-754 bits, never through text.
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_time(&mut self, t: SimTime) {
        self.write_u64(t.0);
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// An RNG stream: the four xoshiro words + the draw counter
    /// ([`SimRng::state`] is the complete state — no hidden spare).
    pub fn write_rng(&mut self, rng: &SimRng) {
        let (s, draws) = rng.state();
        for word in s {
            self.write_u64(word);
        }
        self.write_u64(draws);
    }

    /// A plain (unshared) model payload: length + raw f32 bits.
    pub fn write_model_plain(&mut self, m: &Model) {
        self.write_u64(m.len() as u64);
        for &w in m {
            self.buf.extend_from_slice(&w.to_bits().to_le_bytes());
        }
    }

    /// An `Arc`-shared model: back-reference if this exact `Arc` was
    /// already written, inline payload (then registered) otherwise. The
    /// reader reconstructs identical sharing, which is what makes a
    /// write→read→write round trip byte-identical.
    pub fn write_model(&mut self, m: &Arc<Model>) {
        let key = Arc::as_ptr(m) as usize;
        if let Some(&idx) = self.models.get(&key) {
            self.write_u32(idx);
        } else {
            let idx = u32::try_from(self.models.len())
                .expect("snapshot: more than u32::MAX - 1 distinct models");
            assert!(idx != MODEL_INLINE, "model intern table overflow");
            self.write_u32(MODEL_INLINE);
            self.write_model_plain(m);
            self.models.insert(key, idx);
        }
    }
}

// ---------------------------------------------------------------- reader

/// Positioned snapshot reader. Every decode error carries the byte offset
/// so corruption reports point at the damage.
pub struct SnapshotReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// End offset of the currently open sections (LIFO).
    open: Vec<usize>,
    /// Intern table: models in first-write order.
    models: Vec<Arc<Model>>,
}

impl<'a> SnapshotReader<'a> {
    /// Validate magic + version and position the cursor at the first
    /// section. Rejects foreign files and unsupported format versions
    /// loudly — a snapshot is never "best-effort" decoded.
    pub fn new(buf: &'a [u8]) -> Result<Self> {
        if buf.len() < 12 {
            bail!("snapshot truncated: {} bytes is shorter than the 12-byte header", buf.len());
        }
        if buf[..8] != SNAPSHOT_MAGIC {
            bail!(
                "not a snapshot: bad magic {:02x?} (expected {:02x?} = \"MDSTSNAP\")",
                &buf[..8],
                SNAPSHOT_MAGIC
            );
        }
        let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if version != SNAPSHOT_VERSION {
            bail!(
                "unsupported snapshot format version {version} (this build reads version \
                 {SNAPSHOT_VERSION}); re-create the snapshot with a matching build"
            );
        }
        Ok(SnapshotReader { buf, pos: 12, open: Vec::new(), models: Vec::new() })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = if let Some(&limit) = self.open.last() { limit } else { self.buf.len() };
        if self.pos + n > end {
            bail!(
                "snapshot truncated: need {n} bytes at offset {}, only {} available \
                 (corrupted or incomplete file)",
                self.pos,
                end - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Enter the named section; errors if the next section has a different
    /// name (layout drift between writer and reader builds).
    pub fn begin_section(&mut self, name: &str) -> Result<()> {
        let at = self.pos;
        let got = self.read_str().with_context(|| format!("reading section name at offset {at}"))?;
        if got != name {
            bail!("snapshot section mismatch at offset {at}: expected {name:?}, found {got:?}");
        }
        let len = self.read_u64()? as usize;
        let end = self.pos + len;
        if end > self.buf.len() {
            bail!(
                "snapshot truncated: section {name:?} claims {len} bytes at offset {} but only \
                 {} remain",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        self.open.push(end);
        Ok(())
    }

    /// Leave the current section; errors unless its body was consumed
    /// exactly (any slack means writer/reader disagree on the layout).
    pub fn end_section(&mut self) -> Result<()> {
        let end = self.open.pop().expect("end_section without begin_section");
        if self.pos != end {
            bail!(
                "snapshot section not fully consumed: reader at offset {}, section ends at {end} \
                 ({} bytes of drift)",
                self.pos,
                end as i64 - self.pos as i64
            );
        }
        Ok(())
    }

    /// Verify the whole buffer was consumed (no trailing garbage).
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "snapshot has {} trailing bytes after the last section (offset {})",
                self.buf.len() - self.pos,
                self.pos
            );
        }
        Ok(())
    }

    pub fn read_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn read_bool(&mut self) -> Result<bool> {
        let at = self.pos;
        match self.read_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("snapshot: invalid bool byte {other} at offset {at}"),
        }
    }

    pub fn read_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn read_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn read_usize(&mut self) -> Result<usize> {
        let at = self.pos;
        let v = self.read_u64()?;
        usize::try_from(v).map_err(|_| {
            anyhow::anyhow!("snapshot: length {v} at offset {at} exceeds this platform's usize")
        })
    }

    pub fn read_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.read_u64()?))
    }

    pub fn read_time(&mut self) -> Result<SimTime> {
        Ok(SimTime(self.read_u64()?))
    }

    pub fn read_str(&mut self) -> Result<String> {
        let at = self.pos;
        let len = self.read_usize()?;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .with_context(|| format!("snapshot: non-UTF-8 string at offset {at}"))
    }

    pub fn read_rng(&mut self) -> Result<SimRng> {
        let mut s = [0u64; 4];
        for word in &mut s {
            *word = self.read_u64()?;
        }
        let draws = self.read_u64()?;
        Ok(SimRng::from_state(s, draws))
    }

    pub fn read_model_plain(&mut self) -> Result<Model> {
        let len = self.read_usize()?;
        let mut m = Vec::with_capacity(len);
        for _ in 0..len {
            m.push(f32::from_bits(u32::from_le_bytes(self.take(4)?.try_into().unwrap())));
        }
        Ok(m)
    }

    pub fn read_model(&mut self) -> Result<Arc<Model>> {
        let at = self.pos;
        let tag = self.read_u32()?;
        if tag == MODEL_INLINE {
            let m = Arc::new(self.read_model_plain()?);
            self.models.push(Arc::clone(&m));
            Ok(m)
        } else {
            self.models.get(tag as usize).cloned().ok_or_else(|| {
                anyhow::anyhow!(
                    "snapshot: dangling model back-reference {tag} at offset {at} \
                     (only {} models seen)",
                    self.models.len()
                )
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut w = SnapshotWriter::new();
        w.begin_section("prims");
        w.write_u8(7);
        w.write_bool(true);
        w.write_bool(false);
        w.write_u32(0xDEADBEEF);
        w.write_u64(u64::MAX - 3);
        w.write_usize(123_456);
        w.write_f64(-0.0); // signed zero must survive (bit-exact contract)
        w.write_f64(f64::NAN);
        w.write_time(SimTime::from_micros(42));
        w.write_str("hällo");
        w.end_section();
        let bytes = w.finish();

        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("prims").unwrap();
        assert_eq!(r.read_u8().unwrap(), 7);
        assert!(r.read_bool().unwrap());
        assert!(!r.read_bool().unwrap());
        assert_eq!(r.read_u32().unwrap(), 0xDEADBEEF);
        assert_eq!(r.read_u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.read_usize().unwrap(), 123_456);
        assert_eq!(r.read_f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.read_f64().unwrap().is_nan());
        assert_eq!(r.read_time().unwrap(), SimTime::from_micros(42));
        assert_eq!(r.read_str().unwrap(), "hällo");
        r.end_section().unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn model_interning_preserves_sharing_and_bytes() {
        let shared = Arc::new(vec![1.0f32, 2.5, -3.25]);
        let other = Arc::new(vec![9.0f32]);
        let mut w = SnapshotWriter::new();
        w.begin_section("m");
        w.write_model(&shared);
        w.write_model(&other);
        w.write_model(&shared); // back-ref, 4 bytes
        w.end_section();
        let bytes = w.finish();

        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("m").unwrap();
        let a = r.read_model().unwrap();
        let b = r.read_model().unwrap();
        let c = r.read_model().unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
        assert_eq!(*a, vec![1.0f32, 2.5, -3.25]);
        assert_eq!(*b, vec![9.0f32]);
        assert!(Arc::ptr_eq(&a, &c), "sharing lost across restore");
        assert!(!Arc::ptr_eq(&a, &b));

        // Re-writing the restored graph reproduces the exact bytes: the
        // write→read→write fixpoint the differential test relies on.
        let mut w2 = SnapshotWriter::new();
        w2.begin_section("m");
        w2.write_model(&a);
        w2.write_model(&b);
        w2.write_model(&c);
        w2.end_section();
        assert_eq!(w2.finish(), bytes);
    }

    #[test]
    fn corrupt_headers_fail_loudly() {
        let mut w = SnapshotWriter::new();
        w.begin_section("s");
        w.write_u64(1);
        w.end_section();
        let bytes = w.finish();

        // Truncated anywhere: loud error, never a partial decode.
        for cut in [0, 4, 11, bytes.len() - 1] {
            let err = match SnapshotReader::new(&bytes[..cut]) {
                Err(e) => e.to_string(),
                Ok(mut r) => {
                    let e = r
                        .begin_section("s")
                        .and_then(|_| r.read_u64().map(|_| ()))
                        .and_then(|_| r.end_section())
                        .expect_err("truncated snapshot decoded");
                    e.to_string()
                }
            };
            assert!(err.contains("truncated"), "cut at {cut}: {err}");
        }

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(SnapshotReader::new(&bad).unwrap_err().to_string().contains("bad magic"));

        // Future format version.
        let mut future = bytes.clone();
        future[8..12].copy_from_slice(&(SNAPSHOT_VERSION + 1).to_le_bytes());
        let err = SnapshotReader::new(&future).unwrap_err().to_string();
        assert!(err.contains("unsupported snapshot format version"), "{err}");

        // Wrong section name = layout drift.
        let mut r = SnapshotReader::new(&bytes).unwrap();
        let err = r.begin_section("other").unwrap_err().to_string();
        assert!(err.contains("section mismatch"), "{err}");

        // Under-consuming a section is drift too.
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("s").unwrap();
        let err = r.end_section().unwrap_err().to_string();
        assert!(err.contains("not fully consumed"), "{err}");
    }

    #[test]
    fn section_reads_cannot_cross_section_ends() {
        // A read inside a section must not silently consume the next
        // section's bytes even when the buffer physically continues.
        let mut w = SnapshotWriter::new();
        w.begin_section("a");
        w.write_u32(5);
        w.end_section();
        w.begin_section("b");
        w.write_u64(99);
        w.end_section();
        let bytes = w.finish();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("a").unwrap();
        let err = r.read_u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
