//! The consolidated liveness subsystem: one source of truth for "who is
//! alive right now".
//!
//! Before this module, aliveness was scattered across three places: the
//! harness's `Vec<Status>` table + hand-maintained alive counter, the O(n)
//! alive-peer materialization inside `Ctx::sample_peers`, and the
//! protocol-side `LivenessMirror` bookkeeping. [`Population`] owns all of
//! it: the [`Status`] table, the O(1) alive count, and a **Fenwick-tree
//! alive index** supporting `rank`/`select` over alive node ids — which is
//! what makes a churned fan-out O(k log n) with *zero* peer-list
//! materialization ([`Population::sample_alive_excluding`]).
//!
//! Reproducibility contract: the churned sampling path draws the identical
//! `sample_indices_versioned(alive_peer_count, k)` RNG stream the old
//! materialize-then-index code drew, and maps each sampled *rank* to a node
//! id through `select` — bit-for-bit the same peers, so every recorded
//! same-seed churn fingerprint (gossip, D-SGD, MoDeST) replays unchanged.
//! `tests/sampling_differential.rs` pins this against a materialized-list
//! oracle.

use crate::{NodeId, Round};

use super::rng::{SamplingVersion, SimRng};
use super::snapshot::{SnapshotReader, SnapshotWriter};

/// Liveness status of a simulated node process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Alive,
    /// Crashed or left: the harness drops its deliveries and timers.
    Dead,
    /// Scripted to join later; does not exist yet.
    NotJoined,
}

/// Status table + O(1) alive counter + Fenwick alive index.
///
/// The Fenwick tree stores one bit per node (1 = alive) as prefix-summable
/// counts, giving O(log n) [`Population::rank`] (alive nodes below an id)
/// and [`Population::select`] (the r-th smallest alive id). All mutation
/// goes through [`Population::mark_alive`] / [`Population::mark_dead`], so
/// table, counter, and index can never disagree.
#[derive(Debug, Clone)]
pub struct Population {
    status: Vec<Status>,
    /// 1-based Fenwick tree over alive flags (`tree[0]` unused).
    tree: Vec<u32>,
    alive: usize,
}

impl Population {
    /// `total` node slots of which the first `initial_alive` start alive;
    /// the rest are `NotJoined` placeholders for churn-scripted joiners.
    pub fn new(total: usize, initial_alive: usize) -> Population {
        assert!(initial_alive <= total, "{initial_alive} alive of {total}");
        let mut status = vec![Status::NotJoined; total];
        for s in status.iter_mut().take(initial_alive) {
            *s = Status::Alive;
        }
        Population::from_status(status)
    }

    /// Rebuild a population from a bare status table (the snapshot-restore
    /// path): the alive counter and the Fenwick index are derived state and
    /// are reconstructed in O(n), so they can never disagree with the table.
    pub fn from_status(status: Vec<Status>) -> Population {
        let total = status.len();
        // O(n) in-place Fenwick build: each node's bit lands in tree[i],
        // then i's finished total is pushed up to its parent once.
        let mut tree = vec![0u32; total + 1];
        let mut alive = 0usize;
        for i in 1..=total {
            if status[i - 1] == Status::Alive {
                tree[i] += 1;
                alive += 1;
            }
            let parent = i + (i & i.wrapping_neg());
            if parent <= total {
                let v = tree[i];
                tree[parent] += v;
            }
        }
        Population { status, tree, alive }
    }

    /// Serialize the status table. Only the table travels: the alive count
    /// and Fenwick tree are re-derived by [`Population::from_status`], so a
    /// snapshot can never carry an index that disagrees with its statuses.
    pub fn write_into(&self, w: &mut SnapshotWriter) {
        w.write_usize(self.status.len());
        for &s in &self.status {
            w.write_u8(match s {
                Status::Alive => 0,
                Status::Dead => 1,
                Status::NotJoined => 2,
            });
        }
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<Population> {
        let n = r.read_usize()?;
        let mut status = Vec::with_capacity(n);
        for i in 0..n {
            status.push(match r.read_u8()? {
                0 => Status::Alive,
                1 => Status::Dead,
                2 => Status::NotJoined,
                other => anyhow::bail!("snapshot: invalid node status byte {other} for node {i}"),
            });
        }
        Ok(Population::from_status(status))
    }

    /// Size of the node table (initial population + scripted joiners).
    pub fn len(&self) -> usize {
        self.status.len()
    }

    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    /// Number of currently alive nodes (O(1)).
    pub fn alive_count(&self) -> usize {
        self.alive
    }

    /// Ids outside the table count as not alive (same defensive contract
    /// as harness event dispatch).
    pub fn is_alive(&self, i: usize) -> bool {
        self.status.get(i) == Some(&Status::Alive)
    }

    pub fn status(&self, i: usize) -> Option<Status> {
        self.status.get(i).copied()
    }

    fn index_update(&mut self, i: usize, inc: bool) {
        let mut i = i + 1;
        while i < self.tree.len() {
            if inc {
                self.tree[i] += 1;
            } else {
                self.tree[i] -= 1;
            }
            i += i & i.wrapping_neg();
        }
    }

    /// Mark `i` alive (Join/Recover). Returns whether the node was not
    /// alive before; out-of-table ids are a no-op.
    pub fn mark_alive(&mut self, i: usize) -> bool {
        match self.status.get(i).copied() {
            Some(Status::Alive) | None => false,
            Some(_) => {
                self.status[i] = Status::Alive;
                self.alive += 1;
                self.index_update(i, true);
                true
            }
        }
    }

    /// Mark `i` dead (Crash/Leave — also turns a `NotJoined` placeholder
    /// dead, matching the historical harness semantics). Returns whether
    /// the node was alive before; out-of-table ids are a no-op.
    pub fn mark_dead(&mut self, i: usize) -> bool {
        match self.status.get(i).copied() {
            None => false,
            Some(Status::Alive) => {
                self.status[i] = Status::Dead;
                self.alive -= 1;
                self.index_update(i, false);
                true
            }
            Some(_) => {
                self.status[i] = Status::Dead;
                false
            }
        }
    }

    /// Number of alive node ids strictly below `i` (O(log n)).
    pub fn rank(&self, i: usize) -> usize {
        let mut i = i.min(self.status.len());
        let mut sum = 0usize;
        while i > 0 {
            sum += self.tree[i] as usize;
            i -= i & i.wrapping_neg();
        }
        sum
    }

    /// The `r`-th smallest alive node id, 0-based (O(log n)). Requires
    /// `r < alive_count()`.
    pub fn select(&self, r: usize) -> usize {
        debug_assert!(r < self.alive, "select({r}) of {} alive", self.alive);
        let n = self.status.len();
        let mut pos = 0usize;
        let mut rem = r;
        // Binary descent over the implicit tree: at each step `tree[next]`
        // is the alive count in (pos, next], so skipping it means the
        // answer lies further right.
        let mut step = n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= n && (self.tree[next] as usize) <= rem {
                pos = next;
                rem -= self.tree[next] as usize;
            }
            step >>= 1;
        }
        pos
    }

    /// Lowest alive node id (`None` during a total outage) — the round
    /// recorder role, O(log n) instead of an O(n) scan.
    pub fn lowest_alive(&self) -> Option<usize> {
        if self.alive == 0 {
            None
        } else {
            Some(self.select(0))
        }
    }

    /// All alive node ids, ascending (an explicitly materialized list —
    /// inherently O(n); sampling paths never call this).
    pub fn alive_ids(&self) -> Vec<usize> {
        (0..self.status.len()).filter(|&i| self.is_alive(i)).collect()
    }

    /// All alive nodes except `of` (bootstrap/advertisement peer sets).
    ///
    /// Fast path for the common churn-free large-population case: when the
    /// whole table is alive the peer set is just "every id but `of`", so
    /// large fan-outs skip the per-call liveness scan. Both paths produce
    /// the identical ascending-id vector.
    pub fn alive_peers(&self, of: NodeId) -> Vec<NodeId> {
        let n = self.status.len();
        if self.alive == n && (of as usize) < n {
            let mut peers = Vec::with_capacity(n - 1);
            peers.extend(0..of);
            peers.extend(of + 1..n as NodeId);
            return peers;
        }
        (0..n as NodeId)
            .filter(|&j| j != of && self.is_alive(j as usize))
            .collect()
    }

    /// Draw up to `k` distinct uniformly-random alive nodes excluding
    /// `excluded` (if it is alive), under `version`, with **zero peer-list
    /// materialization**:
    ///
    /// * all alive — sampled indices map straight to node ids
    ///   ([`SimRng::sample_indices_excluding`]), O(k) under `V2Partial`;
    /// * churned — the stream draws the identical
    ///   `sample_indices_versioned(m, k)` call the old materialized path
    ///   drew (`m` = alive count minus the excluded node), and each sampled
    ///   *rank* maps to a node id through the Fenwick [`Population::select`]
    ///   (skipping over `excluded`'s own alive-rank), O(k log n) under
    ///   `V2Partial`.
    ///
    /// Both paths are draw-for-draw and peer-for-peer identical to sampling
    /// positions from the materialized `alive_peers(excluded)` vector, so
    /// session fingerprints never depend on which path ran —
    /// `tests/sampling_differential.rs` pins this against that oracle.
    pub fn sample_alive_excluding(
        &self,
        rng: &mut SimRng,
        version: SamplingVersion,
        excluded: usize,
        k: usize,
    ) -> Vec<NodeId> {
        let n = self.status.len();
        if self.alive == n {
            if excluded < n {
                return rng
                    .sample_indices_excluding(version, n, excluded, k)
                    .into_iter()
                    .map(|i| i as NodeId)
                    .collect();
            }
            let k = k.min(n);
            if n == 0 {
                return Vec::new();
            }
            return rng
                .sample_indices_versioned(version, n, k)
                .into_iter()
                .map(|i| i as NodeId)
                .collect();
        }
        // `excluded` only shrinks the candidate set when it is itself
        // alive; its rank among alive ids is where the "hole" sits.
        let hole = if excluded < n && self.is_alive(excluded) {
            Some(self.rank(excluded))
        } else {
            None
        };
        let m = self.alive - hole.is_some() as usize;
        if m == 0 {
            return Vec::new();
        }
        let k = k.min(m);
        rng.sample_indices_versioned(version, m, k)
            .into_iter()
            .map(|p| {
                let r = match hole {
                    Some(h) if p >= h => p + 1,
                    _ => p,
                };
                self.select(r) as NodeId
            })
            .collect()
    }
}

/// Protocol-side liveness mirror: the churn bookkeeping every leaderless
/// protocol was copying, now a thin layer over [`Population`].
///
/// The harness owns the authoritative liveness table and drops events at
/// dead nodes, but a protocol still needs its own view of who is live to
/// (1) keep the round-start trace monotone when churn moves the recording
/// node, (2) filter evaluation and `final_round` to live replicas, and
/// (3) decide "is anyone left". Gossip-DL and D-SGD each grew an identical
/// `dead: Vec<bool>` + `started: Round` + lowest-live-recorder idiom;
/// [`LivenessMirror`] is that idiom extracted once — and since the fold
/// into [`Population`], the recorder lookup is an O(log n) Fenwick
/// `select(0)` instead of an O(n) scan.
///
/// Everything here is pure bookkeeping — no RNG, no event scheduling — so
/// adopting the mirror cannot change a session's event order or its
/// same-seed fingerprint (the gossip/D-SGD churn tests pin that).
#[derive(Debug, Clone)]
pub struct LivenessMirror {
    pop: Population,
    /// Highest round recorded so far (keeps the trace monotone when churn
    /// hands the recorder role to a different node).
    started: Round,
}

impl LivenessMirror {
    /// All `n` nodes start live.
    pub fn all_live(n: usize) -> LivenessMirror {
        LivenessMirror { pop: Population::new(n, n), started: 0 }
    }

    /// `total` node slots of which the first `live` start live — the
    /// shape of a session whose churn script introduces joiners later.
    pub fn with_live_prefix(total: usize, live: usize) -> LivenessMirror {
        LivenessMirror { pop: Population::new(total, live), started: 0 }
    }

    pub fn len(&self) -> usize {
        self.pop.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pop.is_empty()
    }

    /// Ids outside the table count as dead (same defensive contract as the
    /// harness's own dispatch check).
    pub fn is_dead(&self, i: usize) -> bool {
        !self.pop.is_alive(i)
    }

    pub fn set_dead(&mut self, i: usize) {
        self.pop.mark_dead(i);
    }

    pub fn set_live(&mut self, i: usize) {
        self.pop.mark_alive(i);
    }

    pub fn any_live(&self) -> bool {
        self.pop.alive_count() > 0
    }

    /// Indices of live nodes, ascending (evaluation subsampling).
    pub fn live_indices(&self) -> Vec<usize> {
        self.pop.alive_ids()
    }

    /// The node that records round starts: the lowest live id (node 0
    /// unless churn killed it). `None` during a total outage.
    pub fn recorder(&self) -> Option<usize> {
        self.pop.lowest_alive()
    }

    /// Highest round recorded so far.
    pub fn started(&self) -> Round {
        self.started
    }

    /// Bootstrap: the caller recorded `round` itself (e.g. round 1 at
    /// t=0); pin the monotone guard there.
    pub fn force_started(&mut self, round: Round) {
        self.started = round;
    }

    /// True exactly when `node` is the current recorder and `round`
    /// advances the trace; updates the guard so each round is recorded
    /// once. The caller then calls `ctx.record_round_start(round)`.
    pub fn should_record(&mut self, node: NodeId, round: Round) -> bool {
        if self.recorder() == Some(node as usize) && round > self.started {
            self.started = round;
            true
        } else {
            false
        }
    }

    /// Serialize mirror state (status table + monotone round guard).
    pub fn write_into(&self, w: &mut SnapshotWriter) {
        self.pop.write_into(w);
        w.write_u64(self.started);
    }

    pub fn read_from(r: &mut SnapshotReader) -> anyhow::Result<LivenessMirror> {
        Ok(LivenessMirror { pop: Population::read_from(r)?, started: r.read_u64()? })
    }

    /// Minimum of `rounds` over live nodes (the session's `final_round`);
    /// 0 during a total outage. `rounds` must iterate node-table order.
    pub fn min_live_round<I: IntoIterator<Item = Round>>(&self, rounds: I) -> Round {
        rounds
            .into_iter()
            .enumerate()
            .filter(|&(i, _)| self.pop.is_alive(i))
            .map(|(_, r)| r)
            .min()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ----------------------------------------------------------- Population

    #[test]
    fn prefix_construction_and_counts() {
        let p = Population::new(5, 3);
        assert_eq!(p.len(), 5);
        assert_eq!(p.alive_count(), 3);
        assert!(p.is_alive(0) && p.is_alive(2));
        assert!(!p.is_alive(3) && !p.is_alive(4));
        assert!(!p.is_alive(99), "out-of-table ids are not alive");
        assert_eq!(p.status(3), Some(Status::NotJoined));
        assert_eq!(p.status(99), None);
        assert_eq!(p.alive_ids(), vec![0, 1, 2]);
    }

    #[test]
    fn rank_and_select_track_mutations() {
        let mut p = Population::new(8, 8);
        assert_eq!(p.rank(8), 8);
        assert_eq!(p.select(0), 0);
        assert_eq!(p.select(7), 7);
        assert!(p.mark_dead(3));
        assert!(!p.mark_dead(3), "already dead");
        assert!(p.mark_dead(0));
        assert_eq!(p.alive_count(), 6);
        // alive = [1, 2, 4, 5, 6, 7]
        assert_eq!(p.rank(0), 0);
        assert_eq!(p.rank(4), 2);
        assert_eq!(p.rank(8), 6);
        assert_eq!(p.select(0), 1);
        assert_eq!(p.select(2), 4);
        assert_eq!(p.select(5), 7);
        assert!(p.mark_alive(0));
        assert!(!p.mark_alive(0), "already alive");
        assert_eq!(p.select(0), 0);
        assert_eq!(p.lowest_alive(), Some(0));
    }

    #[test]
    fn not_joined_placeholders_join_and_die() {
        let mut p = Population::new(4, 2);
        assert!(p.mark_alive(3), "join from NotJoined");
        assert_eq!(p.alive_ids(), vec![0, 1, 3]);
        // Crash of a NotJoined placeholder turns it Dead without touching
        // the counter (historical harness semantics).
        assert!(!p.mark_dead(2));
        assert_eq!(p.status(2), Some(Status::Dead));
        assert_eq!(p.alive_count(), 3);
        // Out-of-table mutations are no-ops.
        assert!(!p.mark_alive(17));
        assert!(!p.mark_dead(17));
        assert_eq!(p.alive_count(), 3);
    }

    #[test]
    fn total_outage_and_empty_tables() {
        let mut p = Population::new(2, 2);
        p.mark_dead(0);
        p.mark_dead(1);
        assert_eq!(p.alive_count(), 0);
        assert_eq!(p.lowest_alive(), None);
        assert!(p.alive_ids().is_empty());
        let empty = Population::new(0, 0);
        assert!(empty.is_empty());
        assert_eq!(empty.lowest_alive(), None);
    }

    #[test]
    fn alive_peers_matches_filter_on_both_paths() {
        // All-alive fast path.
        let p = Population::new(6, 6);
        assert_eq!(p.alive_peers(2), vec![0, 1, 3, 4, 5]);
        // Churned slow path.
        let mut p = Population::new(6, 6);
        p.mark_dead(1);
        p.mark_dead(4);
        assert_eq!(p.alive_peers(2), vec![0, 3, 5]);
        assert_eq!(p.alive_peers(1), vec![0, 2, 3, 5], "dead `of` excludes nothing");
        // Out-of-range `of` on the all-alive table falls back to the full
        // alive list.
        let p = Population::new(3, 3);
        assert_eq!(p.alive_peers(9), vec![0, 1, 2]);
    }

    #[test]
    fn churned_sample_is_valid_and_deterministic() {
        let mut p = Population::new(50, 50);
        for i in [0usize, 3, 7, 8, 9, 20, 21, 33, 49] {
            p.mark_dead(i);
        }
        for version in [SamplingVersion::V1Shuffle, SamplingVersion::V2Partial] {
            let mut a = SimRng::new(77);
            let mut b = SimRng::new(77);
            let sa = p.sample_alive_excluding(&mut a, version, 5, 10);
            let sb = p.sample_alive_excluding(&mut b, version, 5, 10);
            assert_eq!(sa, sb, "same seed, same draw");
            assert_eq!(sa.len(), 10);
            let mut sorted = sa.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10, "duplicates in {sa:?}");
            for &x in &sa {
                assert!(p.is_alive(x as usize), "dead peer {x} in {sa:?}");
                assert_ne!(x, 5, "excluded peer sampled");
            }
        }
    }

    #[test]
    fn sample_caps_k_and_handles_empty_sets() {
        let mut p = Population::new(4, 4);
        p.mark_dead(1);
        p.mark_dead(2);
        let mut rng = SimRng::new(3);
        // Only node 3 remains besides the excluded node 0.
        let s = p.sample_alive_excluding(&mut rng, SamplingVersion::V2Partial, 0, 10);
        assert_eq!(s, vec![3]);
        p.mark_dead(3);
        let before = rng.draw_count();
        let s = p.sample_alive_excluding(&mut rng, SamplingVersion::V2Partial, 0, 10);
        assert!(s.is_empty());
        assert_eq!(rng.draw_count(), before, "empty candidate set spends no entropy");
    }

    #[test]
    fn snapshot_roundtrip_rebuilds_identical_index() {
        let mut p = Population::new(40, 30);
        for i in [0usize, 7, 12, 29] {
            p.mark_dead(i);
        }
        p.mark_alive(35); // a joiner
        p.mark_dead(31); // a dead placeholder
        let mut w = SnapshotWriter::new();
        w.begin_section("pop");
        p.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("pop").unwrap();
        let q = Population::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        assert_eq!(q.len(), p.len());
        assert_eq!(q.alive_count(), p.alive_count());
        for i in 0..p.len() {
            assert_eq!(q.status(i), p.status(i), "node {i}");
            assert_eq!(q.rank(i), p.rank(i), "rank {i} (Fenwick rebuild drift)");
        }
        for rk in 0..p.alive_count() {
            assert_eq!(q.select(rk), p.select(rk), "select {rk}");
        }
        // The restored table must draw the identical sampling stream.
        let mut ra = SimRng::new(9);
        let mut rb = SimRng::new(9);
        assert_eq!(
            p.sample_alive_excluding(&mut ra, SamplingVersion::V2Partial, 3, 8),
            q.sample_alive_excluding(&mut rb, SamplingVersion::V2Partial, 3, 8),
        );
    }

    #[test]
    fn mirror_snapshot_roundtrip_keeps_guard_and_recorder() {
        let mut m = LivenessMirror::with_live_prefix(6, 4);
        assert!(m.should_record(0, 1));
        m.set_dead(0);
        m.set_live(4);
        let mut w = SnapshotWriter::new();
        w.begin_section("m");
        m.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("m").unwrap();
        let mut back = LivenessMirror::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        assert_eq!(back.started(), 1);
        assert_eq!(back.recorder(), m.recorder());
        assert_eq!(back.live_indices(), m.live_indices());
        assert!(!back.should_record(1, 1), "monotone guard lost in restore");
        assert!(back.should_record(1, 2));
    }

    // ------------------------------------------------------- LivenessMirror

    #[test]
    fn prefix_construction_marks_joiners_dead() {
        let m = LivenessMirror::with_live_prefix(5, 3);
        assert_eq!(m.len(), 5);
        assert!(!m.is_dead(0) && !m.is_dead(2));
        assert!(m.is_dead(3) && m.is_dead(4));
        assert!(m.is_dead(99), "out-of-table ids are dead");
        assert_eq!(m.live_indices(), vec![0, 1, 2]);
    }

    #[test]
    fn recorder_is_lowest_live_and_hands_off_on_crash() {
        let mut m = LivenessMirror::all_live(4);
        assert_eq!(m.recorder(), Some(0));
        m.set_dead(0);
        assert_eq!(m.recorder(), Some(1));
        m.set_dead(1);
        m.set_dead(2);
        m.set_dead(3);
        assert_eq!(m.recorder(), None);
        assert!(!m.any_live());
        m.set_live(2); // revival
        assert_eq!(m.recorder(), Some(2));
    }

    #[test]
    fn trace_stays_monotone_across_recorder_handoff() {
        // The exact crash/leave/revival sequence the gossip churn tests
        // exercise: node 0 records 1..3, crashes, node 1 takes over — but
        // must not re-record a round <= 3; a revival of node 0 reclaims
        // the role with the guard intact.
        let mut m = LivenessMirror::all_live(3);
        assert!(m.should_record(0, 1));
        assert!(m.should_record(0, 2));
        assert!(m.should_record(0, 3));
        assert!(!m.should_record(1, 4), "non-recorder must not record");
        m.set_dead(0);
        assert!(!m.should_record(1, 3), "stale round after handoff");
        assert!(m.should_record(1, 4));
        m.set_live(0); // recover: lowest live again
        assert!(!m.should_record(1, 5), "role returned to node 0");
        assert!(m.should_record(0, 5));
        assert_eq!(m.started(), 5);
    }

    #[test]
    fn repeated_rounds_record_once() {
        let mut m = LivenessMirror::all_live(2);
        assert!(m.should_record(0, 1));
        assert!(!m.should_record(0, 1));
        assert!(m.should_record(0, 2));
    }

    #[test]
    fn force_started_pins_bootstrap_round() {
        let mut m = LivenessMirror::all_live(2);
        m.force_started(1);
        assert!(!m.should_record(0, 1));
        assert!(m.should_record(0, 2));
    }

    #[test]
    fn min_live_round_filters_dead_nodes() {
        let mut m = LivenessMirror::all_live(4);
        let rounds = [7u64, 3, 9, 5];
        assert_eq!(m.min_live_round(rounds.iter().copied()), 3);
        m.set_dead(1); // the slowest node dies: min moves to a live one
        assert_eq!(m.min_live_round(rounds.iter().copied()), 5);
        m.set_dead(0);
        m.set_dead(2);
        m.set_dead(3);
        assert_eq!(m.min_live_round(rounds.iter().copied()), 0);
    }

    #[test]
    fn join_sequence_extends_live_set() {
        let mut m = LivenessMirror::with_live_prefix(4, 2);
        assert_eq!(m.live_indices(), vec![0, 1]);
        m.set_live(2); // scripted Join fires
        m.set_dead(0); // then the original recorder leaves
        assert_eq!(m.live_indices(), vec![1, 2]);
        assert_eq!(m.recorder(), Some(1));
    }
}
