//! Struct-of-arrays storage for hot per-node protocol state.
//!
//! At million-node scale the binding constraint is bytes, not cycles:
//! an array-of-structs `Vec<Node>` pays for every field of every node on
//! every cache line it touches, and padding + cold payload (models,
//! inboxes) pushed the per-node footprint far past what the counters
//! themselves need. `NodeTable` splits the *hot* fields — round counters,
//! training sequence numbers, staleness epochs, activity timers — into
//! parallel flat arrays alongside [`super::Population`], so protocol
//! structs keep only cold/aggregate state and the per-event accesses
//! (round check, seq check) stream through dense homogeneous columns.
//!
//! Columns are opt-in: a protocol enables exactly the columns it uses via
//! the `with_*` builders and the rest stay unallocated (`Vec::new()`), so
//! gossip does not pay for MoDeST's activity timers and vice versa.
//! Accessing a column that was never enabled panics on the out-of-bounds
//! index — a programming error, not a runtime condition.

use super::time::SimTime;
use crate::Round;

/// Parallel flat columns of hot per-node state (see module docs).
///
/// All columns are indexed by node id; enabled columns always have
/// exactly `len()` entries.
#[derive(Debug, Clone, Default)]
pub struct NodeTable {
    len: usize,
    /// Per-node protocol round counter.
    rounds: Vec<Round>,
    /// Per-node training/staleness sequence: bumped on every dispatched
    /// job and on churn recovery, so exactly one in-flight completion is
    /// ever valid per node.
    seqs: Vec<u64>,
    /// Per-node epoch marker (e.g. D-SGD's `resumed_at` rejoin round).
    epochs: Vec<Round>,
    /// Per-node activity timestamp (e.g. MoDeST's `last_active`).
    timers: Vec<SimTime>,
    /// Per-node generic counter (e.g. MoDeST's membership counter).
    counters: Vec<u64>,
}

impl NodeTable {
    /// An empty table for `len` nodes; enable columns with `with_*`.
    pub fn new(len: usize) -> NodeTable {
        NodeTable { len, ..NodeTable::default() }
    }

    /// Enable the round column, every node starting at `init`.
    pub fn with_rounds(mut self, init: Round) -> NodeTable {
        self.rounds = vec![init; self.len];
        self
    }

    /// Enable the sequence column (zeroed).
    pub fn with_seqs(mut self) -> NodeTable {
        self.seqs = vec![0; self.len];
        self
    }

    /// Enable the epoch column (zeroed).
    pub fn with_epochs(mut self) -> NodeTable {
        self.epochs = vec![0; self.len];
        self
    }

    /// Enable the timer column (all `SimTime::ZERO`).
    pub fn with_timers(mut self) -> NodeTable {
        self.timers = vec![SimTime::ZERO; self.len];
        self
    }

    /// Enable the counter column (zeroed).
    pub fn with_counters(mut self) -> NodeTable {
        self.counters = vec![0; self.len];
        self
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    // ------------------------------------------------------------- rounds

    #[inline]
    pub fn round(&self, i: usize) -> Round {
        self.rounds[i]
    }

    #[inline]
    pub fn set_round(&mut self, i: usize, round: Round) {
        self.rounds[i] = round;
    }

    /// All rounds in node order (e.g. for
    /// [`super::population::LivenessMirror::min_live_round`]).
    pub fn rounds(&self) -> impl Iterator<Item = Round> + '_ {
        self.rounds.iter().copied()
    }

    // -------------------------------------------------------------- seqs

    #[inline]
    pub fn seq(&self, i: usize) -> u64 {
        self.seqs[i]
    }

    /// Advance node `i`'s sequence and return the new value: the freshly
    /// dispatched job's id, invalidating every older in-flight completion.
    #[inline]
    pub fn bump_seq(&mut self, i: usize) -> u64 {
        self.seqs[i] += 1;
        self.seqs[i]
    }

    // ------------------------------------------------------------ epochs

    #[inline]
    pub fn epoch(&self, i: usize) -> Round {
        self.epochs[i]
    }

    #[inline]
    pub fn set_epoch(&mut self, i: usize, epoch: Round) {
        self.epochs[i] = epoch;
    }

    // ------------------------------------------------------------ timers

    #[inline]
    pub fn timer(&self, i: usize) -> SimTime {
        self.timers[i]
    }

    #[inline]
    pub fn set_timer(&mut self, i: usize, at: SimTime) {
        self.timers[i] = at;
    }

    // ---------------------------------------------------------- counters

    #[inline]
    pub fn counter(&self, i: usize) -> u64 {
        self.counters[i]
    }

    #[inline]
    pub fn set_counter(&mut self, i: usize, value: u64) {
        self.counters[i] = value;
    }

    /// Advance node `i`'s counter and return the new value.
    #[inline]
    pub fn bump_counter(&mut self, i: usize) -> u64 {
        self.counters[i] += 1;
        self.counters[i]
    }

    /// Serialize every enabled column. Column presence is encoded (an
    /// empty Vec = disabled), so a restored table panics on exactly the
    /// same disabled-column accesses as the original.
    pub fn write_into(&self, w: &mut super::snapshot::SnapshotWriter) {
        w.write_usize(self.len);
        for col in [&self.rounds, &self.seqs, &self.epochs, &self.counters] {
            w.write_usize(col.len());
            for &v in col {
                w.write_u64(v);
            }
        }
        w.write_usize(self.timers.len());
        for &t in &self.timers {
            w.write_time(t);
        }
    }

    pub fn read_from(r: &mut super::snapshot::SnapshotReader) -> anyhow::Result<NodeTable> {
        let len = r.read_usize()?;
        let mut read_col = |r: &mut super::snapshot::SnapshotReader| -> anyhow::Result<Vec<u64>> {
            let n = r.read_usize()?;
            if n != 0 && n != len {
                anyhow::bail!("snapshot: node-table column has {n} rows, table has {len}");
            }
            (0..n).map(|_| r.read_u64()).collect()
        };
        let rounds = read_col(r)?;
        let seqs = read_col(r)?;
        let epochs = read_col(r)?;
        let counters = read_col(r)?;
        let timers: Vec<SimTime> = read_col(r)?.into_iter().map(SimTime).collect();
        Ok(NodeTable { len, rounds, seqs, epochs, timers, counters })
    }

    /// Heap bytes held by the enabled columns (memory-budget accounting).
    pub fn heap_bytes(&self) -> usize {
        self.rounds.capacity() * std::mem::size_of::<Round>()
            + self.seqs.capacity() * std::mem::size_of::<u64>()
            + self.epochs.capacity() * std::mem::size_of::<Round>()
            + self.timers.capacity() * std::mem::size_of::<SimTime>()
            + self.counters.capacity() * std::mem::size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn columns_initialize_and_mutate() {
        let mut t = NodeTable::new(4).with_rounds(1).with_seqs();
        assert_eq!(t.len(), 4);
        assert!(!t.is_empty());
        assert_eq!(t.round(3), 1);
        t.set_round(3, 9);
        assert_eq!(t.round(3), 9);
        assert_eq!(t.round(0), 1, "other rows untouched");
        assert_eq!(t.seq(2), 0);
        assert_eq!(t.bump_seq(2), 1);
        assert_eq!(t.bump_seq(2), 2);
        assert_eq!(t.seq(2), 2);
        assert_eq!(t.rounds().collect::<Vec<_>>(), vec![1, 1, 1, 9]);
    }

    #[test]
    fn epoch_timer_and_counter_columns() {
        let mut t = NodeTable::new(2).with_epochs().with_timers().with_counters();
        assert_eq!(t.epoch(0), 0);
        t.set_epoch(0, 7);
        assert_eq!(t.epoch(0), 7);
        assert_eq!(t.timer(1), SimTime::ZERO);
        t.set_timer(1, SimTime::from_millis(250));
        assert_eq!(t.timer(1), SimTime::from_millis(250));
        t.set_counter(1, 5);
        assert_eq!(t.bump_counter(1), 6);
        assert_eq!(t.counter(0), 0);
    }

    #[test]
    fn unused_columns_stay_unallocated() {
        let t = NodeTable::new(1_000).with_rounds(1);
        // Only the round column costs memory: the diet depends on it.
        assert_eq!(t.heap_bytes(), 1_000 * std::mem::size_of::<Round>());
    }

    #[test]
    #[should_panic]
    fn disabled_column_access_panics() {
        let t = NodeTable::new(8).with_rounds(1);
        let _ = t.seq(0);
    }

    #[test]
    fn snapshot_roundtrip_preserves_columns_and_gaps() {
        use crate::sim::snapshot::{SnapshotReader, SnapshotWriter};
        let mut t = NodeTable::new(3).with_rounds(1).with_timers();
        t.set_round(2, 8);
        t.set_timer(0, SimTime::from_millis(40));
        let mut w = SnapshotWriter::new();
        w.begin_section("nt");
        t.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = SnapshotReader::new(&bytes).unwrap();
        r.begin_section("nt").unwrap();
        let back = NodeTable::read_from(&mut r).unwrap();
        r.end_section().unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.rounds().collect::<Vec<_>>(), vec![1, 1, 8]);
        assert_eq!(back.timer(0), SimTime::from_millis(40));
        // Disabled columns stay disabled (and unallocated) after restore.
        assert_eq!(back.heap_bytes(), t.heap_bytes());
        std::panic::catch_unwind(|| back.seq(0)).expect_err("seqs column should be disabled");
    }

    #[test]
    fn empty_table() {
        let t = NodeTable::new(0);
        assert!(t.is_empty());
        assert_eq!(t.heap_bytes(), 0);
        assert_eq!(t.rounds().count(), 0);
    }
}
