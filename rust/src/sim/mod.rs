//! Deterministic discrete-event simulation substrate.
//!
//! The paper evaluates MoDeST by *simulating the passing of time* on top of
//! a customized asyncio event loop (§4.2); this module is the rust
//! equivalent: a virtual clock, a monotone event queue with deterministic
//! tie-breaking, a seeded RNG, churn (join/crash) schedule generators, the
//! consolidated [`population::Population`] liveness subsystem (status
//! table, O(1) alive counter, Fenwick alive index for O(k log n) churned
//! peer sampling), and — tying them together — the generic
//! [`harness::SimHarness`] that drives any [`harness::Protocol`] over the
//! shared substrate.

pub mod churn;
pub mod engine;
pub mod harness;
pub mod node_table;
pub mod obs;
pub mod parallel;
pub mod population;
pub mod reliability;
pub mod rng;
pub mod snapshot;
pub mod time;

pub use churn::{ChurnEvent, ChurnKind, ChurnSchedule};
pub use engine::{CalendarEventQueue, EventQueue, HeapEventQueue, ScheduledEvent};
pub use node_table::NodeTable;
pub use harness::{Ctx, EvalPoint, HarnessConfig, HarnessEvent, Protocol, ResumeOptions, SimHarness};
pub use obs::{Hll, ObsState, ProgressConfig, ProgressLine, RoundWindow, StreamHistogram};
pub use parallel::{stable_shard, SessionQueue, ShardedQueue};
pub use population::{LivenessMirror, Population, Status};
pub use reliability::{
    Pending, ReliabilityConfig, ReliableOutbox, TimerVerdict, RELIABLE_TIMER_BIT,
};
pub use rng::{SamplingVersion, SimRng};
pub use snapshot::{SnapshotReader, SnapshotWriter, SNAPSHOT_MAGIC, SNAPSHOT_VERSION};
pub use time::SimTime;
