//! Deterministic discrete-event simulation substrate.
//!
//! The paper evaluates MoDeST by *simulating the passing of time* on top of
//! a customized asyncio event loop (§4.2); this module is the rust
//! equivalent: a virtual clock, a monotone event queue with deterministic
//! tie-breaking, a seeded RNG, and churn (join/crash) schedule generators.

pub mod churn;
pub mod engine;
pub mod rng;
pub mod time;

pub use churn::{ChurnEvent, ChurnKind, ChurnSchedule};
pub use engine::{EventQueue, ScheduledEvent};
pub use rng::SimRng;
pub use time::SimTime;
