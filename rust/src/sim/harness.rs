//! The generic simulation harness: one DES kernel, many protocols.
//!
//! Every protocol session used to own its own copy of the simulation
//! substrate — event queue, liveness table, churn application, probe/eval
//! loop, stop conditions, metrics assembly. [`SimHarness`] extracts that
//! substrate once; a protocol (MoDeST, D-SGD, the FedAvg emulation, and
//! whatever comes next) implements [`Protocol`] and only ever sees a
//! [`Ctx`] — it cannot touch the event queue directly, which is what keeps
//! every session deterministic and every new protocol ~a page of glue.
//!
//! The harness owns:
//! * the [`EventQueue`] and the virtual clock,
//! * the node liveness subsystem ([`Population`]: [`Status`] table, O(1)
//!   alive counter, Fenwick alive index) and churn-script application,
//! * the session RNG,
//! * the [`NetworkFabric`] (latency + per-node capacity + FIFO contention),
//! * the learning [`Task`] and [`ComputeModel`],
//! * the periodic probe/eval loop, the stop conditions
//!   (`max_time` / `max_rounds` / `target_metric`), and the final
//!   [`SessionMetrics`] assembly.

use anyhow::Result;

use crate::learning::{ComputeModel, Task};
use crate::metrics::{SessionMetrics, TrafficSummary};
use crate::net::{MsgKind, NetworkFabric, TrafficLedger};
use crate::{NodeId, Round};

use super::churn::{ChurnEvent, ChurnKind, ChurnSchedule};
use super::engine::EventQueue;
use super::obs::{peak_rss_kb, ObsState, ProgressConfig, ProgressLine};
use super::parallel::{SessionQueue, ShardedQueue};
use super::population::Population;
use super::rng::{SamplingVersion, SimRng};
use super::snapshot::{SnapshotReader, SnapshotWriter};
use super::time::SimTime;

pub use super::population::Status;

/// Session-plumbing knobs shared by every protocol.
#[derive(Debug, Clone)]
pub struct HarnessConfig {
    /// Stop after this much virtual time.
    pub max_time: SimTime,
    /// Round budget surfaced to protocols via [`Ctx::round_budget_exceeded`]
    /// (0 = unlimited).
    pub max_rounds: Round,
    /// Evaluate via [`Protocol::evaluate`] this often.
    pub eval_interval: SimTime,
    /// Stop early when the metric crosses this target (accuracy >=, mse <=).
    pub target_metric: Option<f64>,
    /// Seed of the harness RNG stream.
    pub seed: u64,
    /// Which peer-sampling stream [`Ctx::sample_peers`] draws from
    /// (`V1Shuffle` = the frozen historical stream, `V2Partial` = O(k)).
    pub sampling: SamplingVersion,
    /// Canonical scenario-spec JSON embedded into snapshots so a resume can
    /// rebuild the static substrate (latency, bandwidth config, task) from
    /// the exact spec the checkpointing run used. `None` disables
    /// checkpointing (snapshot requests fail loudly).
    pub spec_json: Option<String>,
    /// Write a snapshot and stop once the next event's time reaches this
    /// instant (the snapshot is taken *between* events, so the resumed run
    /// replays the identical event stream).
    pub checkpoint_at: Option<SimTime>,
    /// Where the checkpoint snapshot file goes.
    pub checkpoint_out: Option<String>,
    /// Live progress stream: emit one JSONL [`ProgressLine`] every
    /// `every` of sim-time. `None` (the default everywhere) arms nothing —
    /// zero extra events, zero RNG draws, bit-identical fingerprints.
    pub progress: Option<ProgressConfig>,
    /// Event-queue execution threads. 1 (the default everywhere) is the
    /// classic single-threaded loop; T > 1 runs T sharded queue partitions
    /// under the conservative-window scheduler in [`crate::sim::parallel`],
    /// with the minimum pairwise fabric latency as lookahead —
    /// bit-identical to T = 1 by construction. Sessions whose latency
    /// matrix contains a zero-latency link have no conservative window and
    /// fall back to single-threaded execution with a loud warning.
    pub threads: usize,
}

/// How a snapshot is replayed into a freshly built harness.
#[derive(Debug, Clone, Default)]
pub struct ResumeOptions {
    /// Fork the restored harness RNG under this label: the what-if branch
    /// keeps the snapshot's past but diverges randomly from the branch
    /// point (the harness RNG is the only runtime stream).
    pub fork: Option<String>,
    /// The resume overlay changed the churn script: drop the snapshot's
    /// queued churn events and schedule the freshly compiled script's
    /// future events instead. When `false`, the snapshot's script is
    /// installed verbatim so queued `Churn(i)` indices stay valid.
    pub reschedule_churn: bool,
}

/// Internal DES events; `M` is the protocol's wire-message type.
pub enum HarnessEvent<M> {
    Deliver { to: NodeId, msg: M },
    Timer { node: NodeId, id: u64 },
    TrainDone { node: NodeId, seq: u64 },
    Churn(usize),
    Probe,
    /// Periodic progress emission (only ever scheduled when
    /// [`HarnessConfig::progress`] is set). Rides snapshots like any other
    /// event, so a resumed run continues the same JSONL cadence.
    ProgressTick,
}

/// One probe-time evaluation produced by a protocol.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub round: Round,
    pub metric: f64,
    pub loss: f64,
    /// Std-dev across node models when evaluating D-SGD-style (else 0).
    pub metric_std: f64,
}

/// What a protocol sees while handling an event: the fabric, the task, the
/// compute model, the RNG, the metrics sink, and scheduling methods. The
/// event queue itself stays private to the harness.
pub struct Ctx<'a, M> {
    queue: &'a mut SessionQueue<HarnessEvent<M>>,
    pub fabric: &'a mut NetworkFabric,
    pub task: &'a mut dyn Task,
    pub compute: &'a ComputeModel,
    pub rng: &'a mut SimRng,
    pub metrics: &'a mut SessionMetrics,
    pop: &'a Population,
    max_rounds: Round,
    sampling: SamplingVersion,
    done: &'a mut bool,
}

impl<M> Ctx<'_, M> {
    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    pub fn is_alive(&self, node: NodeId) -> bool {
        self.pop.is_alive(node as usize)
    }

    /// Size of the node table (initial population + scripted joiners).
    pub fn n_nodes(&self) -> usize {
        self.pop.len()
    }

    /// Number of currently alive nodes (maintained by the harness, O(1)).
    pub fn alive_count(&self) -> usize {
        self.pop.alive_count()
    }

    /// The harness's consolidated liveness subsystem (status table, alive
    /// counter, Fenwick alive index). Protocols that sample from their own
    /// labelled RNG streams (e.g. the FedAvg participant draw) go through
    /// this to get the same zero-materialization path as
    /// [`Ctx::sample_peers`].
    pub fn population(&self) -> &Population {
        self.pop
    }

    /// The sampling-stream version this session runs under.
    pub fn sampling(&self) -> SamplingVersion {
        self.sampling
    }

    /// Draw up to `k` distinct uniformly-random alive peers of `of`
    /// (excluding `of` itself) from the session RNG, under the session's
    /// [`SamplingVersion`].
    ///
    /// Delegates to [`Population::sample_alive_excluding`]: the all-alive
    /// fast path maps sampled indices straight to node ids, and the
    /// churned path maps sampled alive-ranks through the Fenwick `select`
    /// — O(k log n) under `V2Partial`, with zero peer-list
    /// materialization on either path. Both draw the identical
    /// `sample_indices(m, k)` call with `m` = the alive-peer count, so
    /// the RNG stream — and the session fingerprint — never depends on
    /// which path ran.
    pub fn sample_peers(&mut self, of: NodeId, k: usize) -> Vec<NodeId> {
        self.pop
            .sample_alive_excluding(self.rng, self.sampling, of as usize, k)
    }

    /// Send `msg` from `from` to `to`, charging `parts` bytes against the
    /// fabric (ledger + latency + per-link FIFO capacity). Self-sends are
    /// loopback: no traffic, no delay. Under fault injection the fabric
    /// may drop the message in flight — the bytes are charged and the
    /// `Deliver` never fires; senders that must know arm an ack through
    /// [`crate::sim::ReliableOutbox`].
    pub fn send(&mut self, from: NodeId, to: NodeId, parts: &[(MsgKind, u64)], msg: M) {
        self.send_attempt(from, to, parts, msg, false);
    }

    /// [`Ctx::send`] with the ledger's retransmission tag: delivered bytes
    /// count as wire cost but not goodput. Only the reliability layer
    /// sends these.
    pub fn send_retransmit(&mut self, from: NodeId, to: NodeId, parts: &[(MsgKind, u64)], msg: M) {
        self.send_attempt(from, to, parts, msg, true);
    }

    fn send_attempt(
        &mut self,
        from: NodeId,
        to: NodeId,
        parts: &[(MsgKind, u64)],
        msg: M,
        retransmit: bool,
    ) {
        if from == to {
            self.queue
                .schedule_in(SimTime::ZERO, HarnessEvent::Deliver { to, msg });
            return;
        }
        let now = self.queue.now();
        match self.fabric.try_transfer(now, from, to, parts, retransmit) {
            Some(at) => {
                // Streaming latency histogram (send → deliver, µs).
                self.metrics.obs.latency_hist.record(at.0.saturating_sub(now.0));
                self.queue.schedule_at(at, HarnessEvent::Deliver { to, msg })
            }
            None => {} // lost in flight: charged, never delivered
        }
    }

    /// Deliver `msg` to `to` immediately without touching the network
    /// (bootstrap injection).
    pub fn deliver_local(&mut self, to: NodeId, msg: M) {
        self.queue
            .schedule_in(SimTime::ZERO, HarnessEvent::Deliver { to, msg });
    }

    /// Fire [`Protocol::on_timer`] for `node` with `id` after `delay`.
    /// Timers at dead nodes are dropped by the harness.
    pub fn schedule_timer(&mut self, delay: SimTime, node: NodeId, id: u64) {
        self.queue
            .schedule_in(delay, HarnessEvent::Timer { node, id });
    }

    /// Fire [`Protocol::on_train_done`] for `node` with `seq` after `delay`.
    pub fn schedule_train_done(&mut self, delay: SimTime, node: NodeId, seq: u64) {
        self.queue
            .schedule_in(delay, HarnessEvent::TrainDone { node, seq });
    }

    /// Record the first dispatch time of `round`.
    pub fn record_round_start(&mut self, round: Round) {
        let now = self.queue.now();
        self.metrics.record_round_start(round, now);
    }

    /// Record a completed sampling operation.
    pub fn record_sample(&mut self, started: SimTime, round: Round, retries: u32) {
        let now = self.queue.now();
        self.metrics.record_sample(now, started, round, retries);
    }

    /// Whether `round` is past the configured round budget.
    pub fn round_budget_exceeded(&self, round: Round) -> bool {
        self.max_rounds > 0 && round > self.max_rounds
    }

    /// Stop the session after the current event.
    pub fn finish(&mut self) {
        *self.done = true;
    }
}

/// A protocol drivable by [`SimHarness`]: pure reactions to deliveries,
/// timers, training completions, and churn, plus an evaluation hook.
pub trait Protocol {
    /// Wire-message type delivered between nodes. `Send + 'static` so
    /// queued deliveries may live in a sharded queue partition owned by a
    /// worker thread (every payload here is plain data or `Arc`s of it).
    type Msg: Send + 'static;

    /// Kick the protocol off at t=0 (schedule round 1, start training, …).
    fn bootstrap(&mut self, ctx: &mut Ctx<'_, Self::Msg>);

    /// A message arrived at an alive node.
    fn on_deliver(&mut self, ctx: &mut Ctx<'_, Self::Msg>, to: NodeId, msg: Self::Msg);

    /// A timer scheduled via [`Ctx::schedule_timer`] fired at an alive node.
    fn on_timer(&mut self, _ctx: &mut Ctx<'_, Self::Msg>, _node: NodeId, _id: u64) {}

    /// A local training job scheduled via [`Ctx::schedule_train_done`]
    /// finished at an alive node.
    fn on_train_done(&mut self, ctx: &mut Ctx<'_, Self::Msg>, node: NodeId, seq: u64);

    /// A scripted churn event was applied to the liveness table. For
    /// `Leave` the node is still alive during this call (it may advertise);
    /// for `Join`/`Recover`/`Crash` the table is already updated.
    fn on_churn(&mut self, _ctx: &mut Ctx<'_, Self::Msg>, _ev: ChurnEvent) {}

    /// Protocol-specific probe-time bookkeeping (e.g. join-propagation
    /// traces); runs before [`Protocol::evaluate`] on every probe tick.
    fn on_probe(&mut self, _ctx: &mut Ctx<'_, Self::Msg>) {}

    /// Evaluate the protocol's current model(s) for the convergence curve.
    fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint>;

    /// The final round reached (for [`SessionMetrics::final_round`]).
    fn final_round(&self) -> Round;

    // ------------------------------------------------- checkpoint/restore
    //
    // Protocols that support deterministic checkpointing serialize their
    // *dynamic* state (models, inboxes, per-node tables, in-flight ops) —
    // anything rebuilt from the scenario spec (configs, static graphs,
    // payload-size tables) stays out of the snapshot. The defaults fail
    // loudly so snapshot-oblivious protocols still compile but cannot
    // silently produce an unresumable file.

    /// Serialize the protocol's dynamic state into the open section.
    fn snapshot(&self, _w: &mut SnapshotWriter) -> Result<()> {
        anyhow::bail!("this protocol does not support checkpointing")
    }

    /// Overwrite a freshly built protocol's dynamic state from a snapshot.
    fn restore(&mut self, _r: &mut SnapshotReader) -> Result<()> {
        anyhow::bail!("this protocol does not support checkpointing")
    }

    /// Serialize one in-flight wire message (a queued `Deliver` payload).
    fn write_msg(&self, _w: &mut SnapshotWriter, _msg: &Self::Msg) -> Result<()> {
        anyhow::bail!("this protocol does not support checkpointing")
    }

    /// Deserialize one in-flight wire message.
    fn read_msg(&self, _r: &mut SnapshotReader) -> Result<Self::Msg> {
        anyhow::bail!("this protocol does not support checkpointing")
    }
}

/// Build a [`Ctx`] over disjoint fields of a harness (kept as a macro so
/// the borrow checker sees the field-level split).
macro_rules! harness_ctx {
    ($h:ident) => {
        Ctx {
            queue: &mut $h.queue,
            fabric: &mut $h.fabric,
            task: $h.task.as_mut(),
            compute: &$h.compute,
            rng: &mut $h.rng,
            metrics: &mut $h.metrics,
            pop: &$h.population,
            max_rounds: $h.cfg.max_rounds,
            sampling: $h.cfg.sampling,
            done: &mut $h.done,
        }
    };
}

/// Live progress stream state: the validated config plus the reusable
/// buffers that keep per-tick work allocation-free once warmed up. The
/// sink opens lazily at the first emit so a checkpoint taken before any
/// tick leaves no empty file behind, and a resumed run can append to the
/// stream the interrupted run started.
struct ProgressEmitter {
    cfg: ProgressConfig,
    sink: Option<Box<dyn std::io::Write + Send>>,
    line: String,
    rss_buf: String,
    wall_start: std::time::Instant,
}

impl ProgressEmitter {
    fn new(cfg: ProgressConfig) -> ProgressEmitter {
        ProgressEmitter {
            cfg,
            sink: None,
            line: String::new(),
            rss_buf: String::new(),
            wall_start: std::time::Instant::now(),
        }
    }

    /// Render and write one line. `append` selects the sink-open mode on
    /// the first emit: a fresh run truncates its out file, a resumed run
    /// appends so checkpoint/resume produces one seamless stream.
    fn emit(&mut self, mut line: ProgressLine, append: bool) {
        use std::io::Write as _;
        line.wall_s = self.wall_start.elapsed().as_secs_f64();
        line.rss_kb = peak_rss_kb(&mut self.rss_buf);
        self.line.clear();
        line.render(&mut self.line);
        let sink = self.sink.get_or_insert_with(|| match self.cfg.out.as_deref() {
            None => Box::new(std::io::stderr()),
            Some(path) => {
                let f = if append {
                    std::fs::OpenOptions::new().append(true).create(true).open(path)
                } else {
                    std::fs::File::create(path)
                };
                match f {
                    Ok(f) => Box::new(f) as Box<dyn std::io::Write + Send>,
                    Err(e) => panic!("opening progress stream {path}: {e}"),
                }
            }
        });
        let _ = sink.write_all(self.line.as_bytes());
        let _ = sink.flush();
    }
}

/// Stable routing key of a harness event — the node it concerns, which is
/// what partitions state across shards (probe/progress housekeeping pins
/// to shard family 0).
fn route_event<M>(e: &HarnessEvent<M>) -> u64 {
    match e {
        HarnessEvent::Deliver { to, .. } => *to as u64,
        HarnessEvent::Timer { node, .. } => *node as u64,
        HarnessEvent::TrainDone { node, .. } => *node as u64,
        HarnessEvent::Churn(i) => *i as u64,
        HarnessEvent::Probe | HarnessEvent::ProgressTick => 0,
    }
}

/// The shared session driver: owns every simulation substrate and drives a
/// [`Protocol`] to its time/round/metric budget.
pub struct SimHarness<P: Protocol> {
    cfg: HarnessConfig,
    protocol: P,
    queue: SessionQueue<HarnessEvent<P::Msg>>,
    fabric: NetworkFabric,
    /// The liveness subsystem: status table, O(1) alive counter, and the
    /// Fenwick alive index behind [`Ctx::sample_peers`].
    population: Population,
    task: Box<dyn Task>,
    compute: ComputeModel,
    churn: ChurnSchedule,
    rng: SimRng,
    metrics: SessionMetrics,
    done: bool,
    /// Set by [`SimHarness::restore_from`]: the run loop skips the t=0
    /// prologue (churn/probe scheduling, bootstrap, baseline probe) —
    /// everything it would schedule is already in the restored queue.
    resumed: bool,
    /// Armed iff `cfg.progress` is set.
    progress: Option<ProgressEmitter>,
}

impl<P: Protocol> SimHarness<P> {
    /// Build a harness over `total_nodes` node slots of which the first
    /// `initial_alive` start alive (the rest are churn-scripted joiners).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: HarnessConfig,
        protocol: P,
        total_nodes: usize,
        initial_alive: usize,
        task: Box<dyn Task>,
        compute: ComputeModel,
        mut fabric: NetworkFabric,
        churn: ChurnSchedule,
    ) -> SimHarness<P> {
        let population = Population::new(total_nodes, initial_alive);
        fabric.ensure_nodes(total_nodes);
        let rng = SimRng::new(cfg.seed ^ 0x5b_4841_524e_4553); // "HARNES"
        // Observability hash salt from a dedicated stream of the raw seed:
        // same-seed runs emit identical sketches, and the session RNG above
        // never sees a draw for it (fingerprints are untouched). On resume
        // the restored sketches keep their serialized salt (`set_salt` is a
        // no-op once a sketch has inserts, and restore replaces these
        // objects wholesale anyway).
        let obs_salt = SimRng::new(cfg.seed).fork("obs").next_u64();
        fabric.ledger_mut().set_obs_salt(obs_salt);
        // Size the metrics sink up front: the probe schedule and the round
        // budget bound the curve/round-start growth exactly, so long runs
        // never reallocate those vectors mid-session.
        let probes = if cfg.eval_interval > SimTime::ZERO {
            (cfg.max_time.0 / cfg.eval_interval.0) as usize + 2
        } else {
            2
        };
        let mut metrics = SessionMetrics::with_budget(cfg.max_rounds, probes);
        metrics.obs.set_salt(obs_salt);
        let progress = cfg.progress.clone().map(ProgressEmitter::new);
        let queue = match Self::shard_plan(&cfg, &fabric) {
            Some((threads, lookahead)) => SessionQueue::Sharded(ShardedQueue::new(
                threads,
                lookahead,
                route_event::<P::Msg>,
            )),
            None => {
                if cfg.threads > 1 {
                    eprintln!(
                        "warning: run.threads = {} requested but the latency matrix \
                         contains a zero-latency link (conservative lookahead would be \
                         empty); falling back to single-threaded execution",
                        cfg.threads
                    );
                }
                SessionQueue::Single(EventQueue::new())
            }
        };
        SimHarness {
            cfg,
            protocol,
            queue,
            fabric,
            population,
            task,
            compute,
            churn,
            rng,
            metrics,
            done: false,
            resumed: false,
            progress,
        }
    }

    /// Decide whether this run executes sharded: `Some((threads, lookahead))`
    /// iff `cfg.threads > 1` and the latency matrix's minimum one-way delay
    /// is positive (a zero-latency link leaves no conservative window).
    fn shard_plan(cfg: &HarnessConfig, fabric: &NetworkFabric) -> Option<(usize, SimTime)> {
        if cfg.threads <= 1 {
            return None;
        }
        let lookahead = fabric.min_one_way();
        if lookahead.0 == 0 {
            return None;
        }
        Some((cfg.threads, lookahead))
    }

    pub fn protocol(&self) -> &P {
        &self.protocol
    }

    pub fn fabric(&self) -> &NetworkFabric {
        &self.fabric
    }

    // ------------------------------------------------- checkpoint/restore

    /// Serialize the complete dynamic session state into a snapshot blob.
    ///
    /// Section order (write order == read order): `spec` (the canonical
    /// scenario JSON the resume path rebuilds the static substrate from),
    /// `rng`, `pop`, `churn`, `fabric`, `metrics`, `obs`, `protocol`,
    /// `queue`.
    /// Everything re-derivable from the spec — latency matrix, bandwidth
    /// config, task data, static graphs, calendar-queue geometry — is
    /// rebuilt on restore and never serialized.
    pub fn snapshot_bytes(&self) -> Result<Vec<u8>> {
        let spec = self.cfg.spec_json.as_deref().ok_or_else(|| {
            anyhow::anyhow!("harness was built without an embedded scenario spec; cannot snapshot")
        })?;
        let mut w = SnapshotWriter::new();
        w.begin_section("spec");
        w.write_str(spec);
        w.end_section();
        w.begin_section("rng");
        w.write_rng(&self.rng);
        w.end_section();
        w.begin_section("pop");
        self.population.write_into(&mut w);
        w.end_section();
        w.begin_section("churn");
        let churn = self.churn.events();
        w.write_usize(churn.len());
        for ev in churn {
            w.write_time(ev.at);
            w.write_u32(ev.node);
            w.write_u8(match ev.kind {
                ChurnKind::Join => 0,
                ChurnKind::Leave => 1,
                ChurnKind::Crash => 2,
                ChurnKind::Recover => 3,
            });
        }
        w.end_section();
        w.begin_section("fabric");
        self.fabric.write_into(&mut w);
        w.end_section();
        w.begin_section("metrics");
        self.metrics.write_into(&mut w);
        w.end_section();
        w.begin_section("obs");
        self.metrics.obs.write_into(&mut w);
        w.end_section();
        w.begin_section("protocol");
        self.protocol.snapshot(&mut w)?;
        w.end_section();
        w.begin_section("queue");
        w.write_time(self.queue.now());
        w.write_u64(self.queue.seq_counter());
        w.write_u64(self.queue.events_processed());
        w.write_usize(self.queue.arena_capacity());
        self.queue.with_live_events(|live| -> Result<()> {
            w.write_usize(live.len());
            for &(at, seq, ev) in live {
                w.write_time(at);
                w.write_u64(seq);
                match ev {
                    HarnessEvent::Deliver { to, msg } => {
                        w.write_u8(0);
                        w.write_u32(*to);
                        self.protocol.write_msg(&mut w, msg)?;
                    }
                    HarnessEvent::Timer { node, id } => {
                        w.write_u8(1);
                        w.write_u32(*node);
                        w.write_u64(*id);
                    }
                    HarnessEvent::TrainDone { node, seq } => {
                        w.write_u8(2);
                        w.write_u32(*node);
                        w.write_u64(*seq);
                    }
                    HarnessEvent::Churn(i) => {
                        w.write_u8(3);
                        w.write_usize(*i);
                    }
                    HarnessEvent::Probe => w.write_u8(4),
                    HarnessEvent::ProgressTick => w.write_u8(5),
                }
            }
            Ok(())
        })?;
        w.end_section();
        Ok(w.finish())
    }

    /// Overwrite this freshly built harness's dynamic state from a snapshot.
    ///
    /// The reader must be positioned just past the `spec` section (the
    /// resume helper consumes it to rebuild the session). The protocol,
    /// task, compute model, and fabric statics were already rebuilt from
    /// that spec; this replays the dynamic state on top.
    pub fn restore_from(&mut self, r: &mut SnapshotReader, opts: &ResumeOptions) -> Result<()> {
        r.begin_section("rng")?;
        self.rng = r.read_rng()?;
        r.end_section()?;
        if let Some(label) = opts.fork.as_deref() {
            // Branch the what-if run's randomness at the resume point; the
            // harness RNG is the only runtime stream, so every divergence
            // is strictly after the branch.
            self.rng = self.rng.fork(label);
        }
        r.begin_section("pop")?;
        self.population = Population::read_from(r)?;
        r.end_section()?;
        r.begin_section("churn")?;
        let n = r.read_usize()?;
        let mut churn = Vec::with_capacity(n);
        for _ in 0..n {
            let at = r.read_time()?;
            let node = r.read_u32()?;
            let kind = match r.read_u8()? {
                0 => ChurnKind::Join,
                1 => ChurnKind::Leave,
                2 => ChurnKind::Crash,
                3 => ChurnKind::Recover,
                k => anyhow::bail!("snapshot: unknown churn kind tag {k}"),
            };
            churn.push(ChurnEvent { at, node, kind });
        }
        r.end_section()?;
        if !opts.reschedule_churn {
            // Install the snapshot's script verbatim: queued `Churn(i)`
            // events index into it. (Under an overlay the session keeps
            // its freshly compiled script instead.)
            self.churn = ChurnSchedule::new(churn);
        }
        r.begin_section("fabric")?;
        self.fabric.restore_from(r)?;
        r.end_section()?;
        r.begin_section("metrics")?;
        self.metrics = SessionMetrics::read_from(r)?;
        r.end_section()?;
        r.begin_section("obs")?;
        self.metrics.obs = ObsState::read_from(r)?;
        r.end_section()?;
        r.begin_section("protocol")?;
        self.protocol.restore(r)?;
        r.end_section()?;
        r.begin_section("queue")?;
        let now = r.read_time()?;
        let seq = r.read_u64()?;
        let popped = r.read_u64()?;
        let peak = r.read_usize()?;
        let n = r.read_usize()?;
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at = r.read_time()?;
            let s = r.read_u64()?;
            let ev = match r.read_u8()? {
                0 => {
                    let to = r.read_u32()?;
                    let msg = self.protocol.read_msg(r)?;
                    HarnessEvent::Deliver { to, msg }
                }
                1 => {
                    let node = r.read_u32()?;
                    let id = r.read_u64()?;
                    HarnessEvent::Timer { node, id }
                }
                2 => {
                    let node = r.read_u32()?;
                    let seq = r.read_u64()?;
                    HarnessEvent::TrainDone { node, seq }
                }
                3 => HarnessEvent::Churn(r.read_usize()?),
                4 => HarnessEvent::Probe,
                5 => HarnessEvent::ProgressTick,
                t => anyhow::bail!("snapshot: unknown harness event tag {t}"),
            };
            events.push((at, s, ev));
        }
        r.end_section()?;
        if opts.reschedule_churn {
            // The snapshot's queued churn points into the *old* script;
            // drop it and schedule the overlay script's future events with
            // fresh seqs (the what-if future differs by design).
            events.retain(|(_, _, e)| !matches!(e, HarnessEvent::Churn(_)));
        }
        self.queue = SessionQueue::restore(
            Self::shard_plan(&self.cfg, &self.fabric),
            route_event::<P::Msg>,
            now,
            seq,
            popped,
            peak,
            events,
        )?;
        if opts.reschedule_churn {
            for i in 0..self.churn.events().len() {
                let ev = self.churn.events()[i];
                if ev.at >= now {
                    self.queue.schedule_at(ev.at, HarnessEvent::Churn(i));
                }
            }
        }
        self.done = false;
        self.resumed = true;
        Ok(())
    }

    /// Liveness check used by event dispatch: ids outside the node table
    /// (a protocol bug) are treated as dead, so their events are dropped
    /// instead of panicking mid-run.
    fn is_alive(&self, node: NodeId) -> bool {
        self.population.is_alive(node as usize)
    }

    fn handle_churn(&mut self, idx: usize) {
        let ev = self.churn.events()[idx];
        let i = ev.node as usize;
        if i >= self.population.len() {
            return;
        }
        match ev.kind {
            ChurnKind::Join | ChurnKind::Recover => {
                self.population.mark_alive(i);
                self.fabric.ensure_nodes(i + 1);
                let mut ctx = harness_ctx!(self);
                self.protocol.on_churn(&mut ctx, ev);
            }
            ChurnKind::Leave => {
                if !self.population.is_alive(i) {
                    return;
                }
                // The node advertises `left` while still up, then dies.
                let mut ctx = harness_ctx!(self);
                self.protocol.on_churn(&mut ctx, ev);
                self.population.mark_dead(i);
            }
            ChurnKind::Crash => {
                self.population.mark_dead(i);
                let mut ctx = harness_ctx!(self);
                self.protocol.on_churn(&mut ctx, ev);
            }
        }
    }

    fn probe(&mut self) {
        {
            let mut ctx = harness_ctx!(self);
            self.protocol.on_probe(&mut ctx);
        }
        let ep = self
            .protocol
            .evaluate(self.task.as_mut())
            .expect("evaluate");
        self.metrics
            .record_eval(self.queue.now(), ep.round, ep.metric, ep.loss, ep.metric_std);
        if let Some(target) = self.cfg.target_metric {
            let hit = if self.task.metric_is_accuracy() {
                ep.metric >= target
            } else {
                ep.metric <= target
            };
            if hit {
                self.done = true;
            }
        }
    }

    /// Assemble the deterministic fields of one progress line (the
    /// emitter stamps the wall-clock tail). O(1) in nodes and rounds:
    /// every input is a counter, a sketch, or a fixed-size histogram.
    fn progress_line(&self) -> ProgressLine {
        let ledger = self.fabric.ledger();
        let obs: &ObsState = &self.metrics.obs;
        ProgressLine {
            t_s: self.queue.now().as_secs_f64(),
            alive: self.population.alive_count() as u64,
            rounds: self.protocol.final_round() as u64,
            events: self.queue.events_processed(),
            msgs: ledger.messages(),
            bytes_total: ledger.total(),
            bytes_goodput: ledger.goodput(),
            bytes_dropped: ledger.dropped_bytes(),
            bytes_retrans: ledger.retransmitted_bytes(),
            round_p50_s: obs.round_hist.quantile(0.5) as f64 / 1e6,
            round_p95_s: obs.round_hist.quantile(0.95) as f64 / 1e6,
            lat_p50_ms: obs.latency_hist.quantile(0.5) as f64 / 1e3,
            lat_p95_ms: obs.latency_hist.quantile(0.95) as f64 / 1e3,
            xfer_p50_b: ledger.xfer_hist().quantile(0.5),
            peers_est: ledger.distinct_peers(),
            trainers_est: obs.trainers.count(),
            wall_s: 0.0,
            rss_kb: 0,
        }
    }

    fn emit_progress(&mut self) {
        if self.progress.is_none() {
            return;
        }
        let line = self.progress_line();
        let append = self.resumed;
        self.progress.as_mut().unwrap().emit(line, append);
    }

    /// Run to completion; returns the collected metrics and the ledger.
    pub fn run(self) -> (SessionMetrics, TrafficLedger) {
        let (metrics, ledger, _) = self.run_into_parts();
        (metrics, ledger)
    }

    /// Like [`SimHarness::run`], but also hands the terminal protocol state
    /// back so tests can assert per-node columns (rounds, seqs) directly.
    pub fn run_into_parts(mut self) -> (SessionMetrics, TrafficLedger, P) {
        if !self.resumed {
            for (i, ev) in self.churn.events().iter().enumerate() {
                self.queue.schedule_at(ev.at, HarnessEvent::Churn(i));
            }
            let mut t = self.cfg.eval_interval;
            while t <= self.cfg.max_time {
                self.queue.schedule_at(t, HarnessEvent::Probe);
                t += self.cfg.eval_interval;
            }
            // One live tick in flight at a time: each tick reschedules the
            // next, so an early-finished session doesn't idle to max_time
            // on a lattice of pre-scheduled ticks.
            if let Some(p) = self.cfg.progress.as_ref() {
                if p.every <= self.cfg.max_time {
                    self.queue.schedule_at(p.every, HarnessEvent::ProgressTick);
                }
            }
            {
                let mut ctx = harness_ctx!(self);
                self.protocol.bootstrap(&mut ctx);
            }
            // Baseline evaluation of the initial model at t=0.
            self.probe();
        }

        let mut checkpointed = false;
        loop {
            // Checkpoint *between* events, before the trigger-crossing event
            // pops: the snapshot captures the queue with that event still
            // live, so the resumed run replays the identical stream. Taken
            // before the terminal probe below, which would otherwise
            // pollute the snapshot (it consumes protocol/metrics state).
            if let (Some(ck), Some(out)) =
                (self.cfg.checkpoint_at, self.cfg.checkpoint_out.as_deref())
            {
                let due = !self.done && self.queue.peek_time().is_some_and(|t| t >= ck);
                if due {
                    let bytes = self.snapshot_bytes().expect("snapshot serialization failed");
                    std::fs::write(out, &bytes)
                        .unwrap_or_else(|e| panic!("writing checkpoint {out}: {e}"));
                    checkpointed = true;
                    break;
                }
            }
            let Some((now, ev)) = self.queue.pop() else { break };
            if now > self.cfg.max_time || self.done {
                break;
            }
            match ev {
                HarnessEvent::Deliver { to, msg } => {
                    if self.is_alive(to) {
                        let mut ctx = harness_ctx!(self);
                        self.protocol.on_deliver(&mut ctx, to, msg);
                    }
                }
                HarnessEvent::Timer { node, id } => {
                    if self.is_alive(node) {
                        let mut ctx = harness_ctx!(self);
                        self.protocol.on_timer(&mut ctx, node, id);
                    }
                }
                HarnessEvent::TrainDone { node, seq } => {
                    if self.is_alive(node) {
                        self.metrics.obs.trainers.insert(node as u64);
                        let mut ctx = harness_ctx!(self);
                        self.protocol.on_train_done(&mut ctx, node, seq);
                    }
                }
                HarnessEvent::Churn(i) => self.handle_churn(i),
                HarnessEvent::Probe => self.probe(),
                HarnessEvent::ProgressTick => {
                    self.emit_progress();
                    if let Some(p) = self.cfg.progress.as_ref() {
                        let next = SimTime::from_micros(now.0 + p.every.0);
                        // Reschedule only while other events remain: a
                        // drained session must end, not tick to max_time.
                        if next <= self.cfg.max_time && !self.queue.is_empty() {
                            self.queue.schedule_at(next, HarnessEvent::ProgressTick);
                        }
                    }
                }
            }
        }

        // Final progress line at session end. A checkpoint-interrupted run
        // skips it — the resumed run appends the rest of the stream and
        // owns the terminal line.
        if !checkpointed {
            self.emit_progress();
        }

        // Terminal evaluation so short sessions still produce a curve.
        self.probe();
        self.metrics.final_round = self.protocol.final_round();
        self.metrics.duration_s = self.queue.now().as_secs_f64();
        self.metrics.events = self.queue.events_processed();
        let nodes = self.population.len();
        let ledger = self.fabric.into_ledger();
        self.metrics.traffic = TrafficSummary::from_ledger(&ledger, nodes);
        (self.metrics, ledger, self.protocol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learning::MockTask;
    use crate::net::LatencyMatrix;

    /// Minimal test protocol: every node pings its successor once per
    /// "round" and counts deliveries; trains once at bootstrap.
    struct RingProtocol {
        n: usize,
        delivered: u64,
        round: Round,
        model: Vec<f32>,
    }

    struct RingMsg {
        round: Round,
    }

    impl Protocol for RingProtocol {
        type Msg = RingMsg;

        fn bootstrap(&mut self, ctx: &mut Ctx<'_, RingMsg>) {
            ctx.record_round_start(1);
            for node in 0..self.n as NodeId {
                ctx.schedule_train_done(SimTime::from_millis(50), node, 1);
            }
        }

        fn on_deliver(&mut self, ctx: &mut Ctx<'_, RingMsg>, to: NodeId, msg: RingMsg) {
            self.delivered += 1;
            if msg.round > self.round {
                self.round = msg.round;
                ctx.record_round_start(msg.round);
            }
            if ctx.round_budget_exceeded(msg.round + 1) {
                ctx.finish();
                return;
            }
            // Everyone forwards; node 0 advances the round label.
            let next = ((to + 1) as usize % self.n) as NodeId;
            let round = if to == 0 { msg.round + 1 } else { msg.round };
            ctx.send(to, next, &[(MsgKind::Control, 100)], RingMsg { round });
        }

        fn on_train_done(&mut self, ctx: &mut Ctx<'_, RingMsg>, node: NodeId, _seq: u64) {
            let next = ((node + 1) as usize % self.n) as NodeId;
            ctx.send(node, next, &[(MsgKind::Control, 100)], RingMsg { round: 1 });
        }

        fn evaluate(&mut self, task: &mut dyn Task) -> Result<EvalPoint> {
            let e = task.evaluate(&self.model)?;
            Ok(EvalPoint { round: self.round, metric: e.metric, loss: e.loss, metric_std: 0.0 })
        }

        fn final_round(&self) -> Round {
            self.round
        }
    }

    fn ring_harness(n: usize, max_rounds: Round) -> SimHarness<RingProtocol> {
        ring_harness_t(n, max_rounds, 1)
    }

    fn ring_harness_t(n: usize, max_rounds: Round, threads: usize) -> SimHarness<RingProtocol> {
        let task = MockTask::new(n, 8, 0.2, 1);
        let model = task.init_model();
        let latency = LatencyMatrix::uniform(n, SimTime::from_millis(20));
        let fabric = NetworkFabric::uniform(latency, 10e6, n);
        SimHarness::new(
            HarnessConfig {
                max_time: SimTime::from_secs_f64(60.0),
                max_rounds,
                eval_interval: SimTime::from_secs_f64(5.0),
                target_metric: None,
                seed: 9,
                sampling: SamplingVersion::default(),
                spec_json: None,
                checkpoint_at: None,
                checkpoint_out: None,
                progress: None,
                threads,
            },
            RingProtocol { n, delivered: 0, round: 1, model },
            n,
            n,
            Box::new(task),
            ComputeModel::uniform(n, 0.01),
            fabric,
            ChurnSchedule::empty(),
        )
    }

    #[test]
    fn harness_drives_protocol_and_assembles_metrics() {
        let (m, ledger) = ring_harness(4, 0).run();
        assert!(m.events > 100, "{} events", m.events);
        assert!(m.final_round > 5);
        assert!(!m.curve.is_empty());
        assert!(ledger.is_conserved());
        assert!(ledger.total() > 0);
        assert_eq!(m.traffic.total, ledger.total());
    }

    #[test]
    fn round_budget_stops_the_session() {
        let (m, _) = ring_harness(4, 10).run();
        assert!(m.final_round <= 11, "ran to {}", m.final_round);
        assert!(m.duration_s < 60.0);
    }

    #[test]
    fn max_time_bounds_the_clock() {
        let (m, _) = ring_harness(3, 0).run();
        // The clock stops at the first event past the budget (same contract
        // as the pre-harness sessions), so allow one hop of slack.
        assert!(m.duration_s <= 61.0, "ran to {}s", m.duration_s);
    }

    #[test]
    fn identical_seeds_replay_identically() {
        let (a, ta) = ring_harness(5, 0).run();
        let (b, tb) = ring_harness(5, 0).run();
        assert_eq!(a.events, b.events);
        assert_eq!(a.final_round, b.final_round);
        assert_eq!(ta.total(), tb.total());
        let ca: Vec<(Round, u64)> =
            a.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
        let cb: Vec<(Round, u64)> =
            b.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn snapshot_without_embedded_spec_fails_loudly() {
        let h = ring_harness(3, 0);
        let err = h.snapshot_bytes().expect_err("no spec_json configured");
        assert!(err.to_string().contains("embedded scenario spec"), "{err}");
    }

    #[test]
    fn progress_stream_emits_reconciling_jsonl() {
        let out = std::env::temp_dir().join("modest_harness_progress_unit.jsonl");
        let out_s = out.to_str().unwrap().to_string();
        let n = 4;
        let task = MockTask::new(n, 8, 0.2, 1);
        let model = task.init_model();
        let latency = LatencyMatrix::uniform(n, SimTime::from_millis(20));
        let fabric = NetworkFabric::uniform(latency, 10e6, n);
        let h = SimHarness::new(
            HarnessConfig {
                max_time: SimTime::from_secs_f64(60.0),
                max_rounds: 0,
                eval_interval: SimTime::from_secs_f64(5.0),
                target_metric: None,
                seed: 9,
                sampling: SamplingVersion::default(),
                spec_json: None,
                checkpoint_at: None,
                checkpoint_out: None,
                progress: Some(super::ProgressConfig {
                    every: SimTime::from_secs_f64(10.0),
                    out: Some(out_s),
                }),
                threads: 1,
            },
            RingProtocol { n, delivered: 0, round: 1, model },
            n,
            n,
            Box::new(task),
            ComputeModel::uniform(n, 0.01),
            fabric,
            ChurnSchedule::empty(),
        );
        let (m, ledger) = h.run();
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        let lines: Vec<&str> = text.lines().collect();
        // Ticks at 10, 20, ..., 60 plus the terminal line.
        assert!(lines.len() >= 6, "{} lines:\n{text}", lines.len());
        let mut prev = -1.0;
        for l in &lines {
            let j = crate::util::Json::parse(l).unwrap();
            let t = j.field("t_s").unwrap().as_f64().unwrap();
            assert!(t >= prev, "sim-time went backwards: {t} after {prev}");
            prev = t;
            let total = j.field("bytes_total").unwrap().as_u64().unwrap();
            let good = j.field("bytes_goodput").unwrap().as_u64().unwrap();
            let drop = j.field("bytes_dropped").unwrap().as_u64().unwrap();
            let re = j.field("bytes_retrans").unwrap().as_u64().unwrap();
            assert_eq!(total, good + drop + re, "ledger does not reconcile: {l}");
        }
        // The terminal line agrees with the final summary exactly.
        let last = crate::util::Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(last.field("bytes_total").unwrap().as_u64().unwrap(), ledger.total());
        assert_eq!(last.field("rounds").unwrap().as_u64().unwrap(), m.final_round as u64);
        assert_eq!(
            last.field("peers_est").unwrap().as_u64().unwrap(),
            m.traffic.distinct_peers
        );
        assert_eq!(last.field("events").unwrap().as_u64().unwrap(), m.events);
    }

    #[test]
    fn absent_progress_changes_nothing() {
        // A progress-enabled run and a plain run share the session RNG
        // stream: the convergence curve (metric bits) must match exactly.
        let out = std::env::temp_dir().join("modest_harness_progress_absent.jsonl");
        let (plain, _) = ring_harness(5, 0).run();
        let n = 5;
        let task = MockTask::new(n, 8, 0.2, 1);
        let model = task.init_model();
        let latency = LatencyMatrix::uniform(n, SimTime::from_millis(20));
        let fabric = NetworkFabric::uniform(latency, 10e6, n);
        let h = SimHarness::new(
            HarnessConfig {
                max_time: SimTime::from_secs_f64(60.0),
                max_rounds: 0,
                eval_interval: SimTime::from_secs_f64(5.0),
                target_metric: None,
                seed: 9,
                sampling: SamplingVersion::default(),
                spec_json: None,
                checkpoint_at: None,
                checkpoint_out: None,
                progress: Some(super::ProgressConfig {
                    every: SimTime::from_secs_f64(7.0),
                    out: Some(out.to_str().unwrap().to_string()),
                }),
                threads: 1,
            },
            RingProtocol { n, delivered: 0, round: 1, model },
            n,
            n,
            Box::new(task),
            ComputeModel::uniform(n, 0.01),
            fabric,
            ChurnSchedule::empty(),
        );
        let (with_progress, _) = h.run();
        std::fs::remove_file(&out).ok();
        let ca: Vec<(Round, u64)> =
            plain.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
        let cb: Vec<(Round, u64)> =
            with_progress.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
        assert_eq!(ca, cb);
        assert_eq!(plain.final_round, with_progress.final_round);
    }

    #[test]
    fn dead_nodes_drop_deliveries() {
        use crate::sim::churn::{ChurnEvent, ChurnKind};
        let n = 4;
        let task = MockTask::new(n, 8, 0.2, 1);
        let model = task.init_model();
        let latency = LatencyMatrix::uniform(n, SimTime::from_millis(20));
        let fabric = NetworkFabric::uniform(latency, 10e6, n);
        let churn = ChurnSchedule::new(vec![ChurnEvent {
            at: SimTime::from_secs_f64(1.0),
            node: 2,
            kind: ChurnKind::Crash,
        }]);
        let h = SimHarness::new(
            HarnessConfig {
                max_time: SimTime::from_secs_f64(30.0),
                max_rounds: 0,
                eval_interval: SimTime::from_secs_f64(5.0),
                target_metric: None,
                seed: 9,
                sampling: SamplingVersion::default(),
                spec_json: None,
                checkpoint_at: None,
                checkpoint_out: None,
                progress: None,
                threads: 1,
            },
            RingProtocol { n, delivered: 0, round: 1, model },
            n,
            n,
            Box::new(task),
            ComputeModel::uniform(n, 0.01),
            fabric,
            churn,
        );
        // The ring passes through node 2: once it crashes, the ring stalls
        // and the session just idles to the probe ticks — no panic, no
        // delivery at a dead node.
        let (m, _) = h.run();
        assert!(m.duration_s <= 30.0 + 1e-6);
    }

    #[test]
    fn sharded_run_is_bit_identical_to_single_thread() {
        let (base, tb) = ring_harness_t(6, 0, 1).run();
        let cb: Vec<(Round, u64)> =
            base.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
        for threads in [2, 4] {
            let (m, t) = ring_harness_t(6, 0, threads).run();
            assert_eq!(m.events, base.events, "t={threads}");
            assert_eq!(m.final_round, base.final_round, "t={threads}");
            assert_eq!(t.total(), tb.total(), "t={threads}");
            let c: Vec<(Round, u64)> =
                m.curve.iter().map(|p| (p.round, p.metric.to_bits())).collect();
            assert_eq!(c, cb, "t={threads}");
        }
    }
}
