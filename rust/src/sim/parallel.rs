//! Deterministic sharded execution of the event queue.
//!
//! The DES kernel's determinism contract — same seed, same fingerprint,
//! bit-for-bit — hinges on one global pop order: events fire strictly by
//! `(time, insertion seq)`, and every RNG draw, fabric transfer, ledger
//! line, and metrics sample happens as a side effect of a handler running
//! at its exact position in that order. Classic parallel DES trades that
//! order away (DecentralizePy-style process-per-node runs fast but never
//! replays); this module keeps it by splitting the *queue work* — not the
//! handlers — across threads:
//!
//! * Nodes are partitioned into `T` shards by a **stable hash of the
//!   routing key** (node id), independent of `T`, so the same event always
//!   belongs to the same shard family regardless of thread count.
//! * Each shard is a persistent worker thread **owning a full
//!   [`EventQueue`] partition** (calendar or heap backend — the same
//!   feature switch as the single-threaded path). Workers absorb the
//!   expensive queue maintenance: bulk sorted inserts, calendar window
//!   hops, rebalances, and the pop loop that materializes each window.
//! * The main thread runs a **conservative synchronous-window loop**. At a
//!   window barrier it flushes per-shard FIFO mailboxes (events minted
//!   since the last barrier), asks every partition for its next event
//!   time, takes the minimum `W0`, and has all partitions drain
//!   `[W0, W0 + lookahead)` in parallel — `lookahead` being the minimum
//!   pairwise one-way latency of the session's quantized latency matrix.
//!   The drained, per-shard-sorted batches are then merged front-to-front
//!   by `(time, seq)`, which replays the single-queue pop order exactly.
//! * Events scheduled *during* a window at times inside it (zero-delay
//!   self-sends, timers below the lookahead) go to a main-side overlay
//!   heap that participates in the same merge — so correctness never
//!   depends on the lookahead being a true lower bound; a too-large
//!   horizon only drains events early into the merge, never out of order.
//!
//! Because seqs are minted by one central counter in handler order, and
//! handlers run serially on the main thread in exact `(time, seq)` order,
//! every observable stream — fingerprints, metrics curves, traffic
//! ledgers, progress lines, snapshots — is **bit-identical to the
//! single-thread run by construction** (pinned end-to-end by
//! `tests/parallel_differential.rs`). Snapshots serialize the merged
//! cross-partition view in canonical `(time, seq)` order, so a checkpoint
//! written under `T=4` restores under `T=1` and vice versa.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::engine::{validate_restore, EventQueue, ScheduledEvent};
use super::time::SimTime;

/// Stable shard of a routing key: a splitmix64 finalizer (full avalanche,
/// so consecutive node ids spread evenly) reduced modulo the shard count.
/// The hash itself never depends on `shards`, so shard families are
/// consistent across thread counts — only the modulus changes.
#[inline]
pub fn stable_shard(key: u64, shards: usize) -> usize {
    let mut z = key.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// Commands the window loop sends to a shard worker.
enum Cmd<E> {
    /// Bulk-insert entries whose `(at, seq)` keys were minted centrally.
    Insert(Vec<(SimTime, u64, E)>),
    /// Reply with the partition's next event time ([`Reply::Min`]).
    MinTime,
    /// Pop every event strictly below the µs horizon, in partition order
    /// ([`Reply::Batch`]).
    DrainBelow(u64),
    /// Remove every live event, sorted, without advancing the partition
    /// clock ([`Reply::All`]) — the snapshot dance, always followed by a
    /// [`Cmd::PutBack`] of the same entries.
    TakeAll,
    PutBack(Vec<(SimTime, u64, E)>),
}

enum Reply<E> {
    Min(Option<SimTime>),
    Batch(Vec<(SimTime, u64, E)>),
    All(Vec<(SimTime, u64, E)>),
}

/// A shard worker: owns one queue partition, executes commands until the
/// command channel disconnects. Replies that fail to send (main side
/// already dropped) just end the loop early.
fn run_worker<E>(rx: Receiver<Cmd<E>>, tx: Sender<Reply<E>>) {
    let mut q: EventQueue<E> = EventQueue::new();
    while let Ok(cmd) = rx.recv() {
        let sent = match cmd {
            Cmd::Insert(mut batch) => {
                // Ascending insertion hits the calendar's in-bucket append
                // fast path, making the bulk insert O(batch) after the sort.
                batch.sort_unstable_by_key(|&(at, seq, _)| (at.0, seq));
                for (at, seq, e) in batch {
                    q.schedule_preassigned(at, seq, e);
                }
                Ok(())
            }
            Cmd::MinTime => tx.send(Reply::Min(q.peek_time())),
            Cmd::DrainBelow(h) => {
                let mut out = Vec::new();
                while q.peek_time().is_some_and(|t| t.0 < h) {
                    let entry = q.pop_entry().expect("peeked event vanished");
                    out.push(entry);
                }
                tx.send(Reply::Batch(out))
            }
            Cmd::TakeAll => tx.send(Reply::All(q.drain_sorted())),
            Cmd::PutBack(batch) => {
                for (at, seq, e) in batch {
                    q.schedule_preassigned(at, seq, e);
                }
                Ok(())
            }
        };
        if sent.is_err() {
            break;
        }
    }
}

/// A deterministic sharded event queue: the same observable contract as
/// [`EventQueue`] (pop strictly by `(time, insertion seq)`, monotone
/// clock, peak-live capacity accounting, snapshot restore), with the
/// queue maintenance spread over `T` worker-owned partitions and merged
/// at conservative window barriers. See the module docs for the design.
pub struct ShardedQueue<E> {
    txs: Vec<Sender<Cmd<E>>>,
    rxs: Vec<Receiver<Reply<E>>>,
    workers: Vec<JoinHandle<()>>,
    /// Routing key extractor (node id for harness events); hashed through
    /// [`stable_shard`] to pick the partition.
    route: fn(&E) -> u64,
    lookahead_us: u64,
    /// Per-shard FIFOs of events minted since the last barrier, destined
    /// for the shard's partition (all at or beyond the horizon).
    mailboxes: Vec<Vec<(SimTime, u64, E)>>,
    /// The current window's drained batches, consumed front-first by the
    /// merge.
    batches: Vec<VecDeque<(SimTime, u64, E)>>,
    /// Events scheduled *during* the current window at times inside it —
    /// merged alongside the batches, so a handler's zero-delay self-send
    /// still pops at its exact global position.
    overlay: BinaryHeap<ScheduledEvent<E>>,
    /// Exclusive µs upper bound of the drained window.
    horizon_us: u64,
    now: SimTime,
    seq: u64,
    popped: u64,
    /// Live (scheduled, not yet popped) events, and its high-water mark —
    /// which equals the single queue's arena capacity (slots grow exactly
    /// when live exceeds every previous level), keeping snapshot bytes
    /// identical across thread counts.
    live: usize,
    peak: usize,
}

impl<E: Send + 'static> ShardedQueue<E> {
    pub fn new(threads: usize, lookahead: SimTime, route: fn(&E) -> u64) -> ShardedQueue<E> {
        assert!(threads >= 2, "sharded queue needs at least two shards (use EventQueue for one)");
        assert!(lookahead.0 >= 1, "sharded queue needs a positive lookahead");
        let mut txs = Vec::with_capacity(threads);
        let mut rxs = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let (ctx, crx) = channel::<Cmd<E>>();
            let (rtx, rrx) = channel::<Reply<E>>();
            let handle = std::thread::Builder::new()
                .name(format!("des-shard-{i}"))
                .spawn(move || run_worker(crx, rtx))
                .expect("failed to spawn DES shard worker");
            txs.push(ctx);
            rxs.push(rrx);
            workers.push(handle);
        }
        ShardedQueue {
            txs,
            rxs,
            workers,
            route,
            lookahead_us: lookahead.0.max(1),
            mailboxes: (0..threads).map(|_| Vec::new()).collect(),
            batches: (0..threads).map(|_| VecDeque::new()).collect(),
            overlay: BinaryHeap::new(),
            horizon_us: 0,
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
            live: 0,
            peak: 0,
        }
    }

    /// Rebuild from snapshot state — same contract as
    /// [`EventQueue::restore`], with the live events redistributed to their
    /// stable shards. The horizon restarts at the restored clock (nothing
    /// drained yet), so the first pop opens a fresh window; pop order is
    /// geometry-independent, exactly as for the calendar's re-derived
    /// window.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        threads: usize,
        lookahead: SimTime,
        route: fn(&E) -> u64,
        now: SimTime,
        seq: u64,
        popped: u64,
        peak_capacity: usize,
        events: Vec<(SimTime, u64, E)>,
    ) -> anyhow::Result<ShardedQueue<E>> {
        validate_restore(now, seq, peak_capacity, &events)?;
        let mut q = ShardedQueue::new(threads, lookahead, route);
        q.now = now;
        q.seq = seq;
        q.popped = popped;
        q.live = events.len();
        // Mirrors the single backend: a restored arena holds exactly the
        // live events, and the high-water mark regrows from there.
        q.peak = events.len();
        q.horizon_us = now.0;
        let mut per: Vec<Vec<(SimTime, u64, E)>> = (0..threads).map(|_| Vec::new()).collect();
        for (at, s, e) in events {
            per[stable_shard(route(&e), threads)].push((at, s, e));
        }
        for (i, batch) in per.into_iter().enumerate() {
            if !batch.is_empty() {
                q.txs[i].send(Cmd::Insert(batch)).expect("shard worker died");
            }
        }
        Ok(q)
    }
}

impl<E> ShardedQueue<E> {
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Peak simultaneously-live events — the sharded equivalent of the
    /// single backend's arena high-water mark (bit-identical in snapshots).
    pub fn arena_capacity(&self) -> usize {
        self.peak
    }

    pub fn seq_counter(&self) -> u64 {
        self.seq
    }

    pub fn threads(&self) -> usize {
        self.txs.len()
    }

    /// Schedule `event` at absolute virtual time `at` (clamped to `now`,
    /// like the single backend). Inside the current window the event joins
    /// the overlay merge; otherwise it is mailboxed for its stable shard
    /// and flushed at the next barrier.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.live += 1;
        self.peak = self.peak.max(self.live);
        if at.0 < self.horizon_us {
            self.overlay.push(ScheduledEvent { at, seq, event });
        } else {
            let shard = stable_shard((self.route)(&event), self.mailboxes.len());
            self.mailboxes[shard].push((at, seq, event));
        }
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// The earliest `(at, seq)` key over the batch fronts and the overlay,
    /// tagged with its source (`usize::MAX` = overlay).
    fn merge_front(&self) -> Option<((u64, u64), usize)> {
        let mut best: Option<((u64, u64), usize)> = None;
        for (i, b) in self.batches.iter().enumerate() {
            if let Some(&(at, seq, _)) = b.front() {
                let key = (at.0, seq);
                if best.is_none_or(|(k, _)| key < k) {
                    best = Some((key, i));
                }
            }
        }
        if let Some(s) = self.overlay.peek() {
            let key = (s.at.0, s.seq);
            if best.is_none_or(|(k, _)| key < k) {
                best = Some((key, usize::MAX));
            }
        }
        best
    }

    /// Open the next window: flush mailboxes to their partitions, find the
    /// global minimum next-event time `W0`, and have every partition drain
    /// `[W0, W0 + lookahead)` in parallel. Returns false when the whole
    /// queue is exhausted.
    fn advance_window(&mut self) -> bool {
        debug_assert!(
            self.overlay.is_empty() && self.batches.iter().all(|b| b.is_empty()),
            "window advanced with unmerged events"
        );
        if self.live == 0 {
            return false;
        }
        for (i, mb) in self.mailboxes.iter_mut().enumerate() {
            if !mb.is_empty() {
                self.txs[i].send(Cmd::Insert(std::mem::take(mb))).expect("shard worker died");
            }
        }
        for tx in &self.txs {
            tx.send(Cmd::MinTime).expect("shard worker died");
        }
        let mut w0: Option<u64> = None;
        for rx in &self.rxs {
            match rx.recv().expect("shard worker died") {
                Reply::Min(Some(t)) => w0 = Some(w0.map_or(t.0, |w| w.min(t.0))),
                Reply::Min(None) => {}
                _ => unreachable!("shard protocol violation"),
            }
        }
        let Some(w0) = w0 else {
            // live > 0 means some partition must have had an event; a miss
            // here would be a lost-event bug, not an empty queue.
            unreachable!("live events but no partition reported a next time")
        };
        let horizon = w0.saturating_add(self.lookahead_us);
        for tx in &self.txs {
            tx.send(Cmd::DrainBelow(horizon)).expect("shard worker died");
        }
        for (rx, batch) in self.rxs.iter().zip(self.batches.iter_mut()) {
            match rx.recv().expect("shard worker died") {
                Reply::Batch(b) => *batch = VecDeque::from(b),
                _ => unreachable!("shard protocol violation"),
            }
        }
        self.horizon_us = horizon;
        true
    }

    /// Pop the earliest event, advancing the clock to its timestamp —
    /// exactly the single queue's `(time, insertion seq)` order.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        loop {
            match self.merge_front() {
                Some((_, src)) => {
                    let (at, _seq, event) = if src == usize::MAX {
                        let s = self.overlay.pop().expect("peeked overlay event vanished");
                        (s.at, s.seq, s.event)
                    } else {
                        self.batches[src].pop_front().expect("peeked batch front vanished")
                    };
                    debug_assert!(at >= self.now, "sharded queue went back in time");
                    self.now = at;
                    self.live -= 1;
                    self.popped += 1;
                    return Some((at, event));
                }
                None => {
                    if !self.advance_window() {
                        return None;
                    }
                }
            }
        }
    }

    /// Peek at the next event time without popping. Needs `&mut self`: an
    /// exhausted window must advance to know the next time (the barrier is
    /// queue bookkeeping, not observable state).
    pub fn peek_time(&mut self) -> Option<SimTime> {
        loop {
            if let Some(((at, _), _)) = self.merge_front() {
                return Some(SimTime::from_micros(at));
            }
            if !self.advance_window() {
                return None;
            }
        }
    }

    /// Run `f` over every live event in canonical `(at, seq)` order — the
    /// snapshot path. Partition contents are pulled out over the channels
    /// (which work from `&self`), merged with the in-flight window state,
    /// and put back untouched afterwards.
    pub fn with_live_events<R>(&self, f: impl FnOnce(&[(SimTime, u64, &E)]) -> R) -> R {
        for tx in &self.txs {
            tx.send(Cmd::TakeAll).expect("shard worker died");
        }
        let shards: Vec<Vec<(SimTime, u64, E)>> = self
            .rxs
            .iter()
            .map(|rx| match rx.recv().expect("shard worker died") {
                Reply::All(v) => v,
                _ => unreachable!("shard protocol violation"),
            })
            .collect();
        let mut all: Vec<(SimTime, u64, &E)> = Vec::with_capacity(self.live);
        for (at, seq, e) in shards.iter().flatten() {
            all.push((*at, *seq, e));
        }
        for (at, seq, e) in self.batches.iter().flatten() {
            all.push((*at, *seq, e));
        }
        for (at, seq, e) in self.mailboxes.iter().flatten() {
            all.push((*at, *seq, e));
        }
        for s in self.overlay.iter() {
            all.push((s.at, s.seq, &s.event));
        }
        all.sort_unstable_by_key(|&(at, seq, _)| (at.0, seq));
        debug_assert_eq!(all.len(), self.live, "live accounting out of sync");
        let r = f(&all);
        drop(all);
        for (i, batch) in shards.into_iter().enumerate() {
            if !batch.is_empty() {
                self.txs[i].send(Cmd::PutBack(batch)).expect("shard worker died");
            }
        }
        r
    }
}

/// The queue a session actually runs on: the classic single-threaded
/// backend, or the sharded conservative-window scheduler. `T = 1` (the
/// default) takes the `Single` arm everywhere — one predictable branch per
/// call, zero allocation, zero threads; today's loop is byte-for-byte
/// unchanged.
pub enum SessionQueue<E> {
    Single(EventQueue<E>),
    Sharded(ShardedQueue<E>),
}

impl<E: Send + 'static> SessionQueue<E> {
    /// Rebuild from snapshot state under whichever execution mode this
    /// session runs — snapshots are thread-count-agnostic, so a blob
    /// written under `T = 4` restores here under `T = 1` and vice versa.
    #[allow(clippy::too_many_arguments)]
    pub fn restore(
        shards: Option<(usize, SimTime)>,
        route: fn(&E) -> u64,
        now: SimTime,
        seq: u64,
        popped: u64,
        peak_capacity: usize,
        events: Vec<(SimTime, u64, E)>,
    ) -> anyhow::Result<SessionQueue<E>> {
        Ok(match shards {
            Some((threads, lookahead)) => SessionQueue::Sharded(ShardedQueue::restore(
                threads,
                lookahead,
                route,
                now,
                seq,
                popped,
                peak_capacity,
                events,
            )?),
            None => {
                SessionQueue::Single(EventQueue::restore(now, seq, popped, peak_capacity, events)?)
            }
        })
    }
}

impl<E> SessionQueue<E> {
    #[inline]
    pub fn now(&self) -> SimTime {
        match self {
            SessionQueue::Single(q) => q.now(),
            SessionQueue::Sharded(q) => q.now(),
        }
    }

    #[inline]
    pub fn events_processed(&self) -> u64 {
        match self {
            SessionQueue::Single(q) => q.events_processed(),
            SessionQueue::Sharded(q) => q.events_processed(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            SessionQueue::Single(q) => q.len(),
            SessionQueue::Sharded(q) => q.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        match self {
            SessionQueue::Single(q) => q.is_empty(),
            SessionQueue::Sharded(q) => q.is_empty(),
        }
    }

    #[inline]
    pub fn arena_capacity(&self) -> usize {
        match self {
            SessionQueue::Single(q) => q.arena_capacity(),
            SessionQueue::Sharded(q) => q.arena_capacity(),
        }
    }

    #[inline]
    pub fn seq_counter(&self) -> u64 {
        match self {
            SessionQueue::Single(q) => q.seq_counter(),
            SessionQueue::Sharded(q) => q.seq_counter(),
        }
    }

    #[inline]
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        match self {
            SessionQueue::Single(q) => q.schedule_at(at, event),
            SessionQueue::Sharded(q) => q.schedule_at(at, event),
        }
    }

    #[inline]
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        match self {
            SessionQueue::Single(q) => q.schedule_in(delay, event),
            SessionQueue::Sharded(q) => q.schedule_in(delay, event),
        }
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        match self {
            SessionQueue::Single(q) => q.pop(),
            SessionQueue::Sharded(q) => q.pop(),
        }
    }

    /// `&mut self` (unlike the single backend's peek): a sharded queue with
    /// an exhausted window must advance its barrier to learn the next
    /// time. The barrier is queue bookkeeping, not observable state — the
    /// returned time matches the single backend exactly.
    #[inline]
    pub fn peek_time(&mut self) -> Option<SimTime> {
        match self {
            SessionQueue::Single(q) => q.peek_time(),
            SessionQueue::Sharded(q) => q.peek_time(),
        }
    }

    /// Run `f` over every live event in canonical `(at, seq)` order — the
    /// snapshot path, identical bytes under both execution modes.
    pub fn with_live_events<R>(&self, f: impl FnOnce(&[(SimTime, u64, &E)]) -> R) -> R {
        match self {
            SessionQueue::Single(q) => f(&q.live_events()),
            SessionQueue::Sharded(q) => q.with_live_events(f),
        }
    }
}

impl<E> Drop for ShardedQueue<E> {
    fn drop(&mut self) {
        // Disconnect the command channels so workers fall out of their
        // recv loop, then reap them; a worker that already panicked is
        // reported by its own thread, not re-raised here.
        self.txs.clear();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn route_id(e: &u64) -> u64 {
        *e
    }

    /// Differential oracle: any interleaved schedule/pop script must pop
    /// bit-identically to the single-thread backend.
    fn lockstep(threads: usize, lookahead_us: u64, script: impl Fn(u64) -> (u64, u64)) {
        let mut single: EventQueue<u64> = EventQueue::new();
        let mut sharded = ShardedQueue::new(threads, SimTime::from_micros(lookahead_us), route_id);
        for i in 0..500u64 {
            let (at, id) = script(i);
            single.schedule_at(SimTime::from_micros(at), id);
            sharded.schedule_at(SimTime::from_micros(at), id);
        }
        let mut n = 0u64;
        loop {
            let a = single.pop();
            let b = sharded.pop();
            assert_eq!(a, b, "divergence after {n} pops (T={threads})");
            // Reschedule a follow-up from some pops, below and above the
            // lookahead, to exercise overlay and mailbox routing.
            if let Some((at, id)) = a {
                n += 1;
                if n < 2_000 && id % 3 == 0 {
                    let delay = if id % 6 == 0 { lookahead_us / 2 + 1 } else { lookahead_us * 3 };
                    single.schedule_at(at + SimTime::from_micros(delay), id / 3);
                    sharded.schedule_at(at + SimTime::from_micros(delay), id / 3);
                }
            } else {
                break;
            }
        }
        assert_eq!(single.events_processed(), sharded.events_processed());
        assert_eq!(single.now(), sharded.now());
        assert_eq!(single.seq_counter(), sharded.seq_counter());
        assert_eq!(single.arena_capacity(), sharded.arena_capacity());
    }

    #[test]
    fn pops_replay_single_thread_order() {
        for threads in [2, 3, 4] {
            lockstep(threads, 100, |i| ((i * 37) % 1000, i));
        }
    }

    #[test]
    fn dense_ties_replay_insertion_order() {
        lockstep(4, 50, |i| ((i / 25) * 10, i));
    }

    #[test]
    fn shard_hash_is_thread_count_independent() {
        // Same key, different moduli: the underlying hash must not change.
        // (Trivially true of `hash % T`, pinned so a "rebalance-aware"
        // refactor cannot silently break T-agnostic state layout.)
        for key in [0u64, 1, 42, u64::MAX] {
            let h2 = stable_shard(key, 2);
            let h4 = stable_shard(key, 4);
            assert!(h2 < 2 && h4 < 4);
        }
        // And ids spread: 1000 consecutive ids never all land on one shard.
        let mut counts = [0usize; 4];
        for id in 0..1000u64 {
            counts[stable_shard(id, 4)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 100), "skewed shard spread: {counts:?}");
    }

    #[test]
    fn snapshot_view_matches_single_and_leaves_queue_intact() {
        let mut single: EventQueue<u64> = EventQueue::new();
        let mut sharded = ShardedQueue::new(4, SimTime::from_micros(100), route_id);
        for i in 0..300u64 {
            let at = SimTime::from_micros((i * 53) % 2_000);
            single.schedule_at(at, i);
            sharded.schedule_at(at, i);
        }
        for _ in 0..100 {
            assert_eq!(single.pop(), sharded.pop());
        }
        // Mid-window live view: must equal the single queue's canonical
        // live_events, with partitions, batches, mailboxes, and overlay
        // all merged.
        let want: Vec<(SimTime, u64, u64)> =
            single.live_events().into_iter().map(|(t, s, &e)| (t, s, e)).collect();
        let got = sharded
            .with_live_events(|evs| evs.iter().map(|&(t, s, &e)| (t, s, e)).collect::<Vec<_>>());
        assert_eq!(got, want);
        // The dance must not perturb subsequent pops.
        loop {
            let (a, b) = (single.pop(), sharded.pop());
            assert_eq!(a, b, "pop order diverged after snapshot view");
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn restore_redistributes_and_replays() {
        let mut single: EventQueue<u64> = EventQueue::new();
        for i in 0..200u64 {
            single.schedule_at(SimTime::from_micros(500 + (i * 31) % 700), i);
        }
        for _ in 0..60 {
            single.pop();
        }
        let live: Vec<(SimTime, u64, u64)> =
            single.live_events().into_iter().map(|(t, s, &e)| (t, s, e)).collect();
        let mut sharded = ShardedQueue::restore(
            3,
            SimTime::from_micros(64),
            route_id,
            single.now(),
            single.seq_counter(),
            single.events_processed(),
            single.arena_capacity(),
            live.clone(),
        )
        .expect("valid restore");
        assert_eq!(sharded.len(), live.len());
        loop {
            let (a, b) = (single.pop(), sharded.pop());
            assert_eq!(a, b, "restored sharded pop diverged");
            if a.is_none() {
                break;
            }
        }
        // Corrupt inputs fail exactly like the single backend.
        assert!(ShardedQueue::restore(
            2,
            SimTime::from_micros(1),
            route_id,
            SimTime::from_micros(1 << 30),
            u64::MAX,
            0,
            live.len(),
            live,
        )
        .is_err());
    }
}
