//! The discrete-event queue: monotone virtual clock + deterministic order.
//!
//! Sessions (MoDeST, FedAvg, D-SGD) push `(fire_time, event)` pairs and pop
//! them in timestamp order; ties break by insertion sequence so identical
//! configs replay identically. The queue is generic over the session's event
//! type — each protocol defines its own.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;

/// An event scheduled at a virtual time, ordered for a min-heap.
#[derive(Debug)]
pub struct ScheduledEvent<E> {
    pub at: SimTime,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event queue with a virtual clock.
///
/// Invariant: `pop()` never returns an event earlier than the last popped
/// one (time is monotone), and events at equal times pop in push order.
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    now: SimTime,
    seq: u64,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute virtual time `at`.
    ///
    /// Scheduling in the past (before `now`) is clamped to `now`: it models
    /// a zero-delay effect and keeps the monotonicity invariant.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(ScheduledEvent { at, seq, event });
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "event queue went back in time");
        self.now = ev.at;
        self.popped += 1;
        Some((ev.at, ev.event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(30), "c");
        q.schedule_at(SimTime::from_millis(10), "a");
        q.schedule_at(SimTime::from_millis(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        let t = SimTime::from_millis(5);
        for i in 0..10 {
            q.schedule_at(t, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(10), ());
        q.schedule_at(SimTime::from_millis(5), ());
        let mut last = SimTime::ZERO;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            assert_eq!(q.now(), t);
        }
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(10), "first");
        q.pop();
        q.schedule_at(SimTime::from_millis(1), "late");
        let (t, e) = q.pop().unwrap();
        assert_eq!(e, "late");
        assert_eq!(t, SimTime::from_millis(10));
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(100), "base");
        q.pop();
        q.schedule_in(SimTime::from_millis(50), "later");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime::from_millis(150));
    }

    #[test]
    fn counts_processed_events() {
        let mut q = EventQueue::new();
        for i in 0..5u64 {
            q.schedule_at(SimTime::from_micros(i), i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.events_processed(), 5);
    }
}
