//! The discrete-event queue: monotone virtual clock + deterministic order.
//!
//! Sessions (MoDeST, FedAvg, D-SGD, gossip) push `(fire_time, event)` pairs
//! and pop them in timestamp order; ties break by insertion sequence so
//! identical configs replay identically. The queue is generic over the
//! session's event type — each protocol defines its own.
//!
//! Two backends share one API and one observable pop order:
//!
//! * [`CalendarEventQueue`] — the default. A calendar queue in the style of
//!   Brown '88 (and of Corten's allocation-free event loop): a window of
//!   time-sliced buckets over the near future gives O(1) amortized
//!   push/pop for the hot path (messages scheduled within a few average
//!   event-gaps of `now`), while a spill heap holds the far future (probe
//!   ticks, churn scripts scheduled at bootstrap). The bucket width adapts
//!   to the observed inter-event gap whenever the window is re-anchored.
//! * [`HeapEventQueue`] — the original `BinaryHeap` implementation, kept as
//!   a differential-testing shim (`tests/queue_differential.rs` drives both
//!   in lockstep) and selectable crate-wide via the `queue-heap` cargo
//!   feature.
//!
//! Both backends store event payloads in an [`EventArena`]: a slab of
//! fixed-size records recycled through a free list and addressed by `u32`
//! handles. Buckets and heaps then hold only small fixed-width entries
//! (`(time, seq, handle)` — the sort key is copied next to the handle so
//! ordering never needs to chase into the slab), payloads are written once
//! and moved once on pop (never shuffled during rebalances), and the
//! arena's footprint is bounded by the *peak live* event count instead of
//! growing with bucket slack. Handle reuse cannot perturb ordering —
//! handles are identity only, never part of the sort key — so every
//! same-seed fingerprint replays bit-identically (pinned by
//! `tests/queue_differential.rs`).
//!
//! Both pop strictly by `(time, insertion seq)`, so swapping backends never
//! changes a session's fingerprint.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::time::SimTime;

/// An event scheduled at a virtual time, ordered for a min-heap.
///
/// This is the public statement of the ordering contract — earliest
/// `(at, seq)` pops first. The queue backends themselves keep payloads in
/// an internal arena and order fixed-width `(at, seq, handle)` entries.
#[derive(Debug)]
pub struct ScheduledEvent<E> {
    pub at: SimTime,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The default event queue backend.
#[cfg(not(feature = "queue-heap"))]
pub type EventQueue<E> = CalendarEventQueue<E>;

/// The default event queue backend (heap shim selected by `queue-heap`).
#[cfg(feature = "queue-heap")]
pub type EventQueue<E> = HeapEventQueue<E>;

// -------------------------------------------------------------- event arena

/// One fixed-size arena record: the `(at, seq)` sort key plus the payload.
/// `event` is `None` exactly while the slot sits on the free list.
struct Slot<E> {
    at: SimTime,
    seq: u64,
    event: Option<E>,
}

/// Slab/free-list arena of scheduled events, addressed by `u32` handles.
///
/// Slots are allocated once and recycled LIFO through `free`; the slab
/// never shrinks, so its high-water mark equals the peak number of
/// simultaneously live events — the natural working set of a session —
/// rather than the total events ever scheduled.
struct EventArena<E> {
    slots: Vec<Slot<E>>,
    free: Vec<u32>,
}

impl<E> EventArena<E> {
    fn new() -> Self {
        EventArena { slots: Vec::new(), free: Vec::new() }
    }

    /// Store an event, reusing a free slot when one exists.
    fn insert(&mut self, at: SimTime, seq: u64, event: E) -> u32 {
        if let Some(h) = self.free.pop() {
            let s = &mut self.slots[h as usize];
            debug_assert!(s.event.is_none(), "free-listed slot still occupied");
            s.at = at;
            s.seq = seq;
            s.event = Some(event);
            h
        } else {
            let h = u32::try_from(self.slots.len())
                .expect("event arena: more than u32::MAX simultaneously live events");
            self.slots.push(Slot { at, seq, event: Some(event) });
            h
        }
    }

    /// Take the event out of slot `h` and recycle the slot.
    fn remove(&mut self, h: u32) -> (SimTime, E) {
        let s = &mut self.slots[h as usize];
        let event = s.event.take().expect("event slot already freed");
        self.free.push(h);
        (s.at, event)
    }

    #[inline]
    fn at(&self, h: u32) -> SimTime {
        self.slots[h as usize].at
    }

    /// The `(at µs, seq)` sort key of slot `h`.
    #[inline]
    fn key(&self, h: u32) -> (u64, u64) {
        let s = &self.slots[h as usize];
        (s.at.0, s.seq)
    }

    /// A fixed-width heap entry for slot `h` (key copied out of the slab).
    fn entry(&self, h: u32) -> QueueEntry {
        let s = &self.slots[h as usize];
        QueueEntry { at: s.at, seq: s.seq, handle: h }
    }

    /// Slots ever allocated (the arena's high-water mark).
    fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Iterate the live (scheduled, not yet popped) records in slot order.
    /// Snapshot serialization sorts these by `(at, seq)` — slot order is an
    /// allocation artifact and must never leak into a snapshot's bytes.
    fn live(&self) -> impl Iterator<Item = (SimTime, u64, &E)> {
        self.slots
            .iter()
            .filter_map(|s| s.event.as_ref().map(|e| (s.at, s.seq, e)))
    }
}

/// Validate a snapshot's queue section before rebuilding a backend from it.
/// Shared by both backends (and the sharded wrapper in `sim::parallel`) so
/// every restore path rejects the same corrupt inputs. `events` must
/// arrive sorted ascending by `(at, seq)`.
pub(crate) fn validate_restore<E>(
    now: SimTime,
    seq: u64,
    peak_capacity: usize,
    events: &[(SimTime, u64, E)],
) -> anyhow::Result<()> {
    if events.len() > peak_capacity {
        anyhow::bail!(
            "queue restore: {} live events exceed the snapshot's peak-live arena bound {} \
             (corrupt snapshot, or the capacity-tracks-peak invariant was broken at write time)",
            events.len(),
            peak_capacity
        );
    }
    let mut prev: Option<(u64, u64)> = None;
    for &(at, s, _) in events {
        if at < now {
            anyhow::bail!(
                "queue restore: event (at={}µs, seq={s}) is earlier than the restored clock \
                 {}µs — the snapshot violates time monotonicity",
                at.0,
                now.0
            );
        }
        if s >= seq {
            anyhow::bail!(
                "queue restore: event seq {s} is not below the restored seq counter {seq}"
            );
        }
        if prev.is_some_and(|p| p >= (at.0, s)) {
            anyhow::bail!(
                "queue restore: events not strictly ascending by (at, seq) at (at={}µs, seq={s})",
                at.0
            );
        }
        prev = Some((at.0, s));
    }
    Ok(())
}

/// Fixed-width ordered entry: the `(at, seq)` key is duplicated beside the
/// handle because `BinaryHeap` comparisons cannot borrow the arena. The
/// handle is identity only — it never participates in ordering, so slot
/// reuse cannot perturb pop order.
#[derive(Debug, Clone, Copy)]
struct QueueEntry {
    at: SimTime,
    seq: u64,
    handle: u32,
}

impl PartialEq for QueueEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for QueueEntry {}

impl PartialOrd for QueueEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// --------------------------------------------------------------- heap shim

/// Min-heap event queue with a virtual clock (the pre-calendar backend).
///
/// Invariant: `pop()` never returns an event earlier than the last popped
/// one (time is monotone), and events at equal times pop in push order.
pub struct HeapEventQueue<E> {
    arena: EventArena<E>,
    heap: BinaryHeap<QueueEntry>,
    now: SimTime,
    seq: u64,
    popped: u64,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    pub fn new() -> Self {
        HeapEventQueue {
            arena: EventArena::new(),
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Event slots ever allocated (the arena's high-water mark: peak
    /// simultaneously live events, not total events scheduled).
    pub fn arena_capacity(&self) -> usize {
        self.arena.capacity()
    }

    /// Schedule `event` at absolute virtual time `at`.
    ///
    /// Scheduling in the past (before `now`) is clamped to `now`: it models
    /// a zero-delay effect and keeps the monotonicity invariant.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.schedule_raw(at, seq, event);
    }

    /// Insert an event with an already-assigned `(at, seq)` key — the
    /// restore path, which replays keys minted before the snapshot.
    fn schedule_raw(&mut self, at: SimTime, seq: u64, event: E) {
        let handle = self.arena.insert(at, seq, event);
        self.heap.push(QueueEntry { at, seq, handle });
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// The next insertion sequence number (snapshot state: restored events
    /// all carry seqs below it, and post-resume pushes continue from it).
    pub fn seq_counter(&self) -> u64 {
        self.seq
    }

    /// Every live scheduled event, sorted by `(at, seq)` — the canonical
    /// pop order, independent of arena slot allocation history. This is
    /// what a snapshot serializes.
    pub fn live_events(&self) -> Vec<(SimTime, u64, &E)> {
        let mut v: Vec<_> = self.arena.live().collect();
        v.sort_unstable_by_key(|&(at, seq, _)| (at.0, seq));
        v
    }

    /// Rebuild a queue from snapshot state. `events` must be sorted
    /// ascending by `(at, seq)` (the [`HeapEventQueue::live_events`]
    /// order); `peak_capacity` is the writing queue's arena high-water
    /// mark, and the rebuilt arena is bounded by it — live events can
    /// never exceed the peak-live bound, so a violation means corruption
    /// and fails loudly rather than silently over-allocating.
    pub fn restore(
        now: SimTime,
        seq: u64,
        popped: u64,
        peak_capacity: usize,
        events: Vec<(SimTime, u64, E)>,
    ) -> anyhow::Result<Self> {
        validate_restore(now, seq, peak_capacity, &events)?;
        let mut q = HeapEventQueue::new();
        q.now = now;
        q.seq = seq;
        q.popped = popped;
        q.arena.slots.reserve_exact(events.len());
        for (at, s, event) in events {
            q.schedule_raw(at, s, event);
        }
        assert!(
            q.arena.capacity() <= peak_capacity,
            "restored arena over-allocated past the snapshot's peak-live bound"
        );
        Ok(q)
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.pop_entry().map(|(at, _, e)| (at, e))
    }

    /// Pop the earliest event together with its insertion seq — the sharded
    /// merge needs the full `(at, seq)` key to interleave partitions in the
    /// exact single-queue order. Advances the clock like `pop`.
    pub fn pop_entry(&mut self) -> Option<(SimTime, u64, E)> {
        let entry = self.heap.pop()?;
        let (at, event) = self.arena.remove(entry.handle);
        debug_assert!(at >= self.now, "event queue went back in time");
        self.now = at;
        self.popped += 1;
        Some((at, entry.seq, event))
    }

    /// Insert an event whose `(at, seq)` key was minted elsewhere — the
    /// sharded execution path, where one central counter assigns seqs
    /// across every queue partition. The internal counter ratchets past
    /// `seq` so the live-seq < counter invariant keeps holding.
    pub fn schedule_preassigned(&mut self, at: SimTime, seq: u64, event: E) {
        self.seq = self.seq.max(seq + 1);
        self.schedule_raw(at, seq, event);
    }

    /// Remove and return every live event sorted ascending by `(at, seq)`,
    /// WITHOUT advancing the clock or the processed counter. The sharded
    /// snapshot path serializes a merged cross-partition view and then
    /// reinserts the events via [`HeapEventQueue::schedule_preassigned`];
    /// a plain pop loop would ratchet `now` forward and make the reinsert
    /// non-monotone.
    pub fn drain_sorted(&mut self) -> Vec<(SimTime, u64, E)> {
        let mut v = Vec::with_capacity(self.heap.len());
        while let Some(entry) = self.heap.pop() {
            let (at, event) = self.arena.remove(entry.handle);
            v.push((at, entry.seq, event));
        }
        v
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

// ----------------------------------------------------------- calendar queue

/// Number of near-window buckets (power of two; window = `BUCKETS * width`).
const BUCKETS: usize = 2048;
/// Upper bound on the adaptive bucket width (µs) so `BUCKETS * width` stays
/// far from u64 overflow.
const MAX_WIDTH_US: u64 = 1 << 40;
/// Width adaptation targets this many events per bucket on average.
const TARGET_PER_BUCKET: f64 = 4.0;
/// A push that leaves a bucket beyond this length triggers a near-window
/// rebuild with a freshly derived width (recovers from a stale coarse
/// width after an idle stretch, when `rewindow` cannot run because the
/// near window never drains).
const REBALANCE_LEN: usize = 512;

/// Bucketed calendar event queue: O(1) amortized push/pop on the hot path.
///
/// Near-future events (within `BUCKETS * width` µs of the window anchor)
/// live in time-sliced buckets, each kept sorted ascending by
/// `(time, seq)`; the common append-at-end insert and the pop-front are
/// both O(1). Far-future events spill into a min-heap and are drained into
/// buckets when the window re-anchors past them. Pop order is exactly
/// `(time, insertion seq)` — bit-identical to [`HeapEventQueue`].
///
/// Payloads live once in the shared [`EventArena`]; buckets hold only
/// 4-byte handles and the far heap 24-byte keyed entries, so rebalances
/// and window hops shuffle handles, never event payloads, and per-bucket
/// slack costs 4 bytes per slot instead of a full event record.
pub struct CalendarEventQueue<E> {
    /// Slab storage for every scheduled event's payload and key.
    arena: EventArena<E>,
    /// `buckets[i]` covers `[win_start + i*width, win_start + (i+1)*width)`
    /// µs, sorted ascending by `(at, seq)` (front = earliest).
    buckets: Vec<VecDeque<u32>>,
    /// Bucket width in µs (adapts at each re-anchor).
    width: u64,
    /// Absolute µs covered by `buckets[0]`'s left edge.
    win_start: u64,
    /// First bucket that may still hold events (monotone within a window).
    cursor: usize,
    /// Events currently in buckets.
    near_len: usize,
    /// Events at or beyond the window end (min-first via [`QueueEntry`]'s
    /// reversed `Ord`).
    far: BinaryHeap<QueueEntry>,
    now: SimTime,
    seq: u64,
    popped: u64,
    /// Exponential moving average of the inter-pop time gap (µs); sizes the
    /// buckets at the next re-anchor.
    gap_ema: f64,
    /// Pushes since the last rebalance — a rebuild is allowed only after
    /// `near_len` further pushes, keeping its cost amortized O(1)/push even
    /// for distributions no width can spread (dense same-µs clusters).
    since_rebalance: u64,
}

impl<E> Default for CalendarEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> CalendarEventQueue<E> {
    pub fn new() -> Self {
        CalendarEventQueue {
            arena: EventArena::new(),
            buckets: (0..BUCKETS).map(|_| VecDeque::new()).collect(),
            width: 256,
            win_start: 0,
            cursor: 0,
            near_len: 0,
            far: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
            gap_ema: 256.0,
            since_rebalance: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.near_len + self.far.len()
    }

    pub fn is_empty(&self) -> bool {
        self.near_len == 0 && self.far.is_empty()
    }

    /// Event slots ever allocated (the arena's high-water mark: peak
    /// simultaneously live events, not total events scheduled).
    pub fn arena_capacity(&self) -> usize {
        self.arena.capacity()
    }

    fn win_end(&self) -> u64 {
        self.win_start.saturating_add(self.width * BUCKETS as u64)
    }

    /// Insert handle `h` into the right near bucket; returns the bucket
    /// index so [`CalendarEventQueue::schedule_at`] can watch for overflow.
    fn insert_near(&mut self, h: u32) -> usize {
        let (at_us, seq) = self.arena.key(h);
        // When the window was just (re-)anchored ahead of `now` (idle jump
        // to a distant first event), a push may land before `win_start`;
        // clamp it into the cursor bucket. Every earlier bucket is empty
        // and every event in or after the cursor bucket has a larger
        // (at, seq) key — in-bucket sorted insertion keeps the global pop
        // order exact.
        let idx = if at_us <= self.win_start {
            self.cursor
        } else {
            (((at_us - self.win_start) / self.width) as usize).max(self.cursor)
        };
        debug_assert!(idx < BUCKETS, "near insert outside window");
        let arena = &self.arena;
        let b = &mut self.buckets[idx];
        let key = (at_us, seq);
        // Hot path: events arrive mostly in increasing (at, seq) — append.
        if !b.back().is_some_and(|&e| arena.key(e) > key) {
            b.push_back(h);
        } else {
            let pos = b.partition_point(|&e| arena.key(e) < key);
            b.insert(pos, h);
        }
        self.near_len += 1;
        idx
    }

    /// Rebuild the near window around the events it actually holds, with a
    /// width derived from their spread. Triggered when one bucket grows
    /// past [`REBALANCE_LEN`] — a stale over-coarse width after an idle
    /// stretch (probe-only traffic inflates the gap estimate; `rewindow`
    /// can only fix it once the near window drains, which a steady-state
    /// session never does). Pop order is untouched: handles are re-placed
    /// in canonical `(at, seq)` order, payloads never move.
    fn rebalance(&mut self) {
        let mut all: Vec<u32> = Vec::with_capacity(self.near_len);
        for b in &mut self.buckets[self.cursor..] {
            all.extend(b.drain(..));
        }
        {
            let arena = &self.arena;
            all.sort_unstable_by_key(|&h| arena.key(h));
        }
        if all.is_empty() {
            return;
        }
        // Width from the 99th-percentile span so one straggler far ahead
        // (a probe tick past a dense burst) cannot keep the width coarse;
        // events beyond the resulting window spill to the far heap.
        let lo = self.arena.key(all[0]).0;
        let p99 = self.arena.key(all[(all.len() * 99) / 100]).0;
        let span = (p99 - lo).max(1);
        let per_event = span as f64 * TARGET_PER_BUCKET / all.len() as f64;
        self.width = (per_event.ceil() as u64).clamp(1, MAX_WIDTH_US);
        self.gap_ema = self.gap_ema.min(self.width as f64);
        self.win_start = (lo / self.width) * self.width;
        self.cursor = 0;
        self.near_len = 0;
        let end = self.win_end();
        for h in all {
            if self.arena.key(h).0 < end {
                // Sorted order → the append fast path, O(1) each.
                self.insert_near(h);
            } else {
                self.far.push(self.arena.entry(h));
            }
        }
        // The new window may END LATER than the old one (a width increase):
        // any far event now inside it must move near, or a later-timed near
        // event could pop before it and break the far >= win_end invariant
        // (and with it, clock monotonicity and heap-equivalence).
        while let Some(e) = self.far.peek() {
            if e.at.0 >= end {
                break;
            }
            let e = self.far.pop().expect("peeked event vanished");
            self.insert_near(e.handle);
        }
    }

    /// Re-anchor the window at the earliest far event and drain every far
    /// event that now falls inside it. Only called when the buckets are
    /// empty, so the cursor restarts at 0.
    fn rewindow(&mut self) {
        debug_assert_eq!(self.near_len, 0);
        self.width = ((self.gap_ema * TARGET_PER_BUCKET).ceil() as u64).clamp(1, MAX_WIDTH_US);
        let first = self.far.peek().expect("rewindow on an empty far heap").at.0;
        self.win_start = (first / self.width) * self.width;
        self.cursor = 0;
        let end = self.win_end();
        while let Some(e) = self.far.peek() {
            if e.at.0 >= end {
                break;
            }
            let e = self.far.pop().expect("peeked event vanished");
            self.insert_near(e.handle);
        }
    }

    /// Schedule `event` at absolute virtual time `at`.
    ///
    /// Scheduling in the past (before `now`) is clamped to `now`: it models
    /// a zero-delay effect and keeps the monotonicity invariant.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.schedule_raw(at, seq, event);
    }

    /// Insert an event with an already-assigned `(at, seq)` key — shared by
    /// `schedule_at` (which mints the key) and the restore path (which
    /// replays keys minted before the snapshot).
    fn schedule_raw(&mut self, at: SimTime, seq: u64, event: E) {
        let handle = self.arena.insert(at, seq, event);
        if self.near_len == 0 && self.far.is_empty() {
            // Empty queue: re-anchor the window directly at this event so a
            // long idle jump (e.g. the gap to the next probe tick) never
            // forces a far-heap round trip.
            self.win_start = (at.0 / self.width) * self.width;
            self.cursor = 0;
            self.insert_near(handle);
            return;
        }
        if at.0 < self.win_end() {
            let idx = self.insert_near(handle);
            self.since_rebalance += 1;
            // An over-coarse width piles everything into one bucket and
            // degrades the sorted insert; rebuild with a fresh width. At
            // width 1 the events are true ties and no width can help; the
            // cooldown amortizes the rebuild over the pushes since.
            if self.buckets[idx].len() > REBALANCE_LEN
                && self.width > 1
                && self.since_rebalance >= self.near_len as u64
            {
                self.rebalance();
                self.since_rebalance = 0;
            }
        } else {
            self.far.push(QueueEntry { at, seq, handle });
        }
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// The next insertion sequence number (snapshot state: restored events
    /// all carry seqs below it, and post-resume pushes continue from it).
    pub fn seq_counter(&self) -> u64 {
        self.seq
    }

    /// Every live scheduled event, sorted by `(at, seq)` — the canonical
    /// pop order, independent of bucket/heap placement and arena slot
    /// allocation history. This is what a snapshot serializes, which is why
    /// the calendar geometry (window anchor, adaptive width, gap EMA) never
    /// appears in a snapshot: it is performance state, re-derived on
    /// restore, and pop order does not depend on it.
    pub fn live_events(&self) -> Vec<(SimTime, u64, &E)> {
        let mut v: Vec<_> = self.arena.live().collect();
        v.sort_unstable_by_key(|&(at, seq, _)| (at.0, seq));
        v
    }

    /// Rebuild a queue from snapshot state. `events` must be sorted
    /// ascending by `(at, seq)` (the [`CalendarEventQueue::live_events`]
    /// order); `peak_capacity` is the writing queue's arena high-water
    /// mark, and the rebuilt arena is bounded by it — live events can
    /// never exceed the peak-live bound, so a violation means corruption
    /// and fails loudly rather than silently over-allocating. Bucket width
    /// and window anchor start from defaults and re-adapt; pop order is
    /// geometry-independent, so the resumed stream stays bit-identical to
    /// an uninterrupted run (and to the heap backend).
    pub fn restore(
        now: SimTime,
        seq: u64,
        popped: u64,
        peak_capacity: usize,
        events: Vec<(SimTime, u64, E)>,
    ) -> anyhow::Result<Self> {
        validate_restore(now, seq, peak_capacity, &events)?;
        let mut q = CalendarEventQueue::new();
        q.now = now;
        q.seq = seq;
        q.popped = popped;
        q.arena.slots.reserve_exact(events.len());
        // Ascending insertion hits the in-bucket append fast path, so the
        // rebuild is O(live) plus far-heap pushes.
        for (at, s, event) in events {
            q.schedule_raw(at, s, event);
        }
        assert!(
            q.arena.capacity() <= peak_capacity,
            "restored arena over-allocated past the snapshot's peak-live bound"
        );
        Ok(q)
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.pop_entry().map(|(at, _, e)| (at, e))
    }

    /// Insert an event whose `(at, seq)` key was minted elsewhere — the
    /// sharded execution path, where one central counter assigns seqs
    /// across every queue partition. The internal counter ratchets past
    /// `seq` so the live-seq < counter invariant keeps holding.
    pub fn schedule_preassigned(&mut self, at: SimTime, seq: u64, event: E) {
        self.seq = self.seq.max(seq + 1);
        self.schedule_raw(at, seq, event);
    }

    /// Remove and return every live event sorted ascending by `(at, seq)`,
    /// WITHOUT advancing the clock or the processed counter. The sharded
    /// snapshot path serializes a merged cross-partition view and then
    /// reinserts the events via
    /// [`CalendarEventQueue::schedule_preassigned`]; a plain pop loop would
    /// ratchet `now` forward and make the reinsert non-monotone.
    pub fn drain_sorted(&mut self) -> Vec<(SimTime, u64, E)> {
        let mut handles: Vec<u32> = Vec::with_capacity(self.len());
        for b in &mut self.buckets[self.cursor..] {
            handles.extend(b.drain(..));
        }
        while let Some(e) = self.far.pop() {
            handles.push(e.handle);
        }
        {
            let arena = &self.arena;
            handles.sort_unstable_by_key(|&h| arena.key(h));
        }
        self.near_len = 0;
        self.cursor = 0;
        let mut v = Vec::with_capacity(handles.len());
        for h in handles {
            let seq = self.arena.key(h).1;
            let (at, event) = self.arena.remove(h);
            v.push((at, seq, event));
        }
        v
    }

    /// Pop the earliest event together with its insertion seq — the sharded
    /// merge needs the full `(at, seq)` key to interleave partitions in the
    /// exact single-queue order. Advances the clock like `pop`.
    pub fn pop_entry(&mut self) -> Option<(SimTime, u64, E)> {
        if self.near_len == 0 {
            if self.far.is_empty() {
                return None;
            }
            self.rewindow();
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            debug_assert!(self.cursor < BUCKETS, "near events lost");
        }
        let h = self.buckets[self.cursor].pop_front().expect("non-empty bucket");
        self.near_len -= 1;
        let seq = self.arena.key(h).1;
        let (at, event) = self.arena.remove(h);
        debug_assert!(at >= self.now, "event queue went back in time");
        // Clamp the sample so one idle jump (a probe tick after traffic
        // went quiet) cannot blow the gap estimate — and hence the next
        // window's bucket width — up by orders of magnitude. A genuinely
        // coarser workload still converges (≤16x growth per sample).
        let gap = ((at.0 - self.now.0) as f64).min(self.gap_ema * 16.0);
        self.gap_ema = 0.9 * self.gap_ema + 0.1 * gap;
        self.now = at;
        self.popped += 1;
        Some((at, seq, event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        if self.near_len > 0 {
            for b in &self.buckets[self.cursor..] {
                if let Some(&h) = b.front() {
                    return Some(self.arena.at(h));
                }
            }
        }
        self.far.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run the shared queue contract against both backends.
    macro_rules! queue_contract {
        ($mod:ident, $q:ident) => {
            mod $mod {
                use crate::sim::engine::$q;
                use crate::sim::time::SimTime;

                #[test]
                fn pops_in_time_order() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(30), "c");
                    q.schedule_at(SimTime::from_millis(10), "a");
                    q.schedule_at(SimTime::from_millis(20), "b");
                    let order: Vec<&str> =
                        std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
                    assert_eq!(order, vec!["a", "b", "c"]);
                }

                #[test]
                fn ties_break_by_insertion_order() {
                    let mut q = $q::new();
                    let t = SimTime::from_millis(5);
                    for i in 0..10 {
                        q.schedule_at(t, i);
                    }
                    let order: Vec<i32> =
                        std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
                    assert_eq!(order, (0..10).collect::<Vec<_>>());
                }

                #[test]
                fn clock_advances_monotonically() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(10), ());
                    q.schedule_at(SimTime::from_millis(5), ());
                    let mut last = SimTime::ZERO;
                    while let Some((t, _)) = q.pop() {
                        assert!(t >= last);
                        last = t;
                        assert_eq!(q.now(), t);
                    }
                }

                #[test]
                fn past_scheduling_clamps_to_now() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(10), "first");
                    q.pop();
                    q.schedule_at(SimTime::from_millis(1), "late");
                    let (t, e) = q.pop().unwrap();
                    assert_eq!(e, "late");
                    assert_eq!(t, SimTime::from_millis(10));
                }

                #[test]
                fn schedule_in_is_relative() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(100), "base");
                    q.pop();
                    q.schedule_in(SimTime::from_millis(50), "later");
                    let (t, _) = q.pop().unwrap();
                    assert_eq!(t, SimTime::from_millis(150));
                }

                #[test]
                fn counts_processed_events() {
                    let mut q = $q::new();
                    for i in 0..5u64 {
                        q.schedule_at(SimTime::from_micros(i), i);
                    }
                    while q.pop().is_some() {}
                    assert_eq!(q.events_processed(), 5);
                }

                #[test]
                fn peek_matches_next_pop() {
                    let mut q = $q::new();
                    assert_eq!(q.peek_time(), None);
                    q.schedule_at(SimTime::from_millis(7), 1);
                    q.schedule_at(SimTime::from_millis(3), 2);
                    assert_eq!(q.peek_time(), Some(SimTime::from_millis(3)));
                    let (t, _) = q.pop().unwrap();
                    assert_eq!(t, SimTime::from_millis(3));
                }

                #[test]
                fn len_tracks_contents() {
                    let mut q = $q::new();
                    assert!(q.is_empty());
                    for i in 0..100u64 {
                        q.schedule_at(SimTime::from_micros(i * 37 % 50), i);
                    }
                    assert_eq!(q.len(), 100);
                    q.pop();
                    assert_eq!(q.len(), 99);
                }

                #[test]
                fn arena_capacity_tracks_peak_live_not_total() {
                    // Five full drain cycles of 1000 events each: the slab
                    // must recycle freed slots instead of growing per push.
                    let mut q = $q::new();
                    for wave in 0..5u64 {
                        for i in 0..1_000u64 {
                            let at = SimTime::from_micros(q.now().0 + 1 + i);
                            q.schedule_at(at, wave * 1_000 + i);
                        }
                        let mut last = q.now();
                        while let Some((t, _)) = q.pop() {
                            assert!(t >= last, "reuse broke time order");
                            last = t;
                        }
                    }
                    assert_eq!(
                        q.arena_capacity(),
                        1_000,
                        "freed slots must be recycled across drain cycles"
                    );
                }

                #[test]
                fn restore_preserves_order_and_respects_peak_capacity() {
                    // Build up a peak (50 live), free some slots, then
                    // rebuild from the snapshot view: the restored queue
                    // must pop identically AND its arena must stay within
                    // the recorded peak-live bound — a rebuilt arena
                    // silently outgrowing the snapshot's working set is
                    // the over-allocation bug this case pins down.
                    let mut q = $q::new();
                    for i in 0..50u64 {
                        q.schedule_at(SimTime::from_micros(100 + (i * 37) % 90), i);
                    }
                    for _ in 0..20 {
                        q.pop();
                    }
                    let peak = q.arena_capacity();
                    assert_eq!(peak, 50);
                    let live: Vec<(SimTime, u64, u64)> = q
                        .live_events()
                        .into_iter()
                        .map(|(t, s, &e)| (t, s, e))
                        .collect();
                    assert_eq!(live.len(), 30);
                    let mut r = $q::restore(
                        q.now(),
                        q.seq_counter(),
                        q.events_processed(),
                        peak,
                        live.clone(),
                    )
                    .expect("valid restore");
                    assert!(
                        r.arena_capacity() <= peak,
                        "restored arena {} exceeds peak-live bound {peak}",
                        r.arena_capacity()
                    );
                    assert_eq!(r.now(), q.now());
                    assert_eq!(r.events_processed(), q.events_processed());
                    assert_eq!(r.len(), q.len());
                    // Post-restore pushes must interleave exactly like
                    // pushes on the original (seq counter continuity).
                    q.schedule_at(SimTime::from_micros(130), 999);
                    r.schedule_at(SimTime::from_micros(130), 999);
                    loop {
                        match (q.pop(), r.pop()) {
                            (None, None) => break,
                            (a, b) => assert_eq!(a, b, "restored pop order diverged"),
                        }
                    }
                    // More live events than the recorded peak = corruption:
                    // the restore must fail loudly, not over-allocate.
                    let err = $q::restore(
                        SimTime::ZERO,
                        u64::MAX,
                        0,
                        live.len() - 1,
                        live.clone(),
                    )
                    .expect_err("over-peak restore accepted");
                    assert!(err.to_string().contains("peak-live"), "{err}");
                    // Events before the restored clock violate monotonicity.
                    assert!($q::restore(
                        SimTime::from_micros(10_000),
                        u64::MAX,
                        0,
                        live.len(),
                        live.clone(),
                    )
                    .is_err());
                    // Event seqs at/above the seq counter are inconsistent.
                    assert!($q::restore(SimTime::ZERO, 1, 0, live.len(), live).is_err());
                }

                #[test]
                fn drain_sorted_roundtrips_through_preassigned_reinsert() {
                    // The sharded snapshot dance: drain every live event
                    // (sorted, clock untouched), reinsert with the same
                    // keys, and keep popping exactly as if nothing
                    // happened — including events earlier than the latest
                    // drained one, which a pop-based drain would corrupt
                    // by ratcheting `now` to the maximum.
                    let mut q = $q::new();
                    for i in 0..200u64 {
                        q.schedule_at(SimTime::from_micros(100 + (i * 37) % 90), i);
                    }
                    for _ in 0..50 {
                        q.pop();
                    }
                    let (now, popped, len) = (q.now(), q.events_processed(), q.len());
                    let drained = q.drain_sorted();
                    assert_eq!(drained.len(), len);
                    assert!(q.is_empty());
                    assert_eq!(q.now(), now, "drain moved the clock");
                    assert_eq!(q.events_processed(), popped, "drain counted pops");
                    assert!(
                        drained.windows(2).all(|w| (w[0].0 .0, w[0].1) < (w[1].0 .0, w[1].1)),
                        "drain not sorted by (at, seq)"
                    );
                    for &(at, seq, e) in &drained {
                        q.schedule_preassigned(at, seq, e);
                    }
                    // Post-reinsert pushes continue from past the drained
                    // seqs (the counter ratchets), so interleaving stays
                    // exact.
                    q.schedule_at(SimTime::from_micros(150), 999);
                    let mut keys = Vec::new();
                    while let Some((at, seq, _)) = q.pop_entry() {
                        keys.push((at.0, seq));
                    }
                    assert!(keys.windows(2).all(|w| w[0] < w[1]), "pop order broke");
                    assert_eq!(keys.len(), len + 1);
                }

                #[test]
                fn reused_slots_keep_time_seq_order() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_micros(100), "a");
                    q.schedule_at(SimTime::from_micros(50), "b");
                    assert_eq!(q.pop().unwrap().1, "b");
                    // "c" reuses b's freed slot but carries a later time
                    // than "d": handle identity must not leak into order.
                    q.schedule_at(SimTime::from_micros(70), "c");
                    q.schedule_at(SimTime::from_micros(60), "d");
                    assert_eq!(q.pop().unwrap().1, "d");
                    assert_eq!(q.pop().unwrap().1, "c");
                    assert_eq!(q.pop().unwrap().1, "a");
                    assert!(q.pop().is_none());
                }
            }
        };
    }

    queue_contract!(heap_backend, HeapEventQueue);
    queue_contract!(calendar_backend, CalendarEventQueue);

    #[test]
    fn calendar_survives_window_hops_and_reanchors() {
        let mut q = CalendarEventQueue::new();
        // Far beyond any initial window: forces far-heap spill + rewindow.
        q.schedule_at(SimTime::from_secs_f64(3600.0), "late");
        q.schedule_at(SimTime::from_millis(1), "early");
        assert_eq!(q.pop().unwrap().1, "early");
        // Idle jump: queue drains then re-anchors on the distant event.
        assert_eq!(q.pop().unwrap().1, "late");
        assert_eq!(q.now(), SimTime::from_secs_f64(3600.0));
        // Post-jump scheduling still works near the new now.
        q.schedule_in(SimTime::from_millis(2), "after");
        assert_eq!(q.pop().unwrap().1, "after");
        assert!(q.pop().is_none());
    }

    #[test]
    fn calendar_tie_burst_in_one_bucket_pops_in_seq_order() {
        let mut q = CalendarEventQueue::new();
        let t = SimTime::from_micros(42);
        for i in 0..1000u64 {
            q.schedule_at(t, i);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..1000).collect::<Vec<_>>());
    }
}
