//! The discrete-event queue: monotone virtual clock + deterministic order.
//!
//! Sessions (MoDeST, FedAvg, D-SGD, gossip) push `(fire_time, event)` pairs
//! and pop them in timestamp order; ties break by insertion sequence so
//! identical configs replay identically. The queue is generic over the
//! session's event type — each protocol defines its own.
//!
//! Two backends share one API and one observable pop order:
//!
//! * [`CalendarEventQueue`] — the default. A calendar queue in the style of
//!   Brown '88 (and of Corten's allocation-free event loop): a window of
//!   time-sliced buckets over the near future gives O(1) amortized
//!   push/pop for the hot path (messages scheduled within a few average
//!   event-gaps of `now`), while a spill heap holds the far future (probe
//!   ticks, churn scripts scheduled at bootstrap). The bucket width adapts
//!   to the observed inter-event gap whenever the window is re-anchored.
//! * [`HeapEventQueue`] — the original `BinaryHeap` implementation, kept as
//!   a differential-testing shim (`tests/queue_differential.rs` drives both
//!   in lockstep) and selectable crate-wide via the `queue-heap` cargo
//!   feature.
//!
//! Both pop strictly by `(time, insertion seq)`, so swapping backends never
//! changes a session's fingerprint.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::time::SimTime;

/// An event scheduled at a virtual time, ordered for a min-heap.
#[derive(Debug)]
pub struct ScheduledEvent<E> {
    pub at: SimTime,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The default event queue backend.
#[cfg(not(feature = "queue-heap"))]
pub type EventQueue<E> = CalendarEventQueue<E>;

/// The default event queue backend (heap shim selected by `queue-heap`).
#[cfg(feature = "queue-heap")]
pub type EventQueue<E> = HeapEventQueue<E>;

// --------------------------------------------------------------- heap shim

/// Min-heap event queue with a virtual clock (the pre-calendar backend).
///
/// Invariant: `pop()` never returns an event earlier than the last popped
/// one (time is monotone), and events at equal times pop in push order.
pub struct HeapEventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    now: SimTime,
    seq: u64,
    popped: u64,
}

impl<E> Default for HeapEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> HeapEventQueue<E> {
    pub fn new() -> Self {
        HeapEventQueue {
            heap: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute virtual time `at`.
    ///
    /// Scheduling in the past (before `now`) is clamped to `now`: it models
    /// a zero-delay effect and keeps the monotonicity invariant.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(ScheduledEvent { at, seq, event });
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "event queue went back in time");
        self.now = ev.at;
        self.popped += 1;
        Some((ev.at, ev.event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }
}

// ----------------------------------------------------------- calendar queue

/// Number of near-window buckets (power of two; window = `BUCKETS * width`).
const BUCKETS: usize = 2048;
/// Upper bound on the adaptive bucket width (µs) so `BUCKETS * width` stays
/// far from u64 overflow.
const MAX_WIDTH_US: u64 = 1 << 40;
/// Width adaptation targets this many events per bucket on average.
const TARGET_PER_BUCKET: f64 = 4.0;
/// A push that leaves a bucket beyond this length triggers a near-window
/// rebuild with a freshly derived width (recovers from a stale coarse
/// width after an idle stretch, when `rewindow` cannot run because the
/// near window never drains).
const REBALANCE_LEN: usize = 512;

/// Bucketed calendar event queue: O(1) amortized push/pop on the hot path.
///
/// Near-future events (within `BUCKETS * width` µs of the window anchor)
/// live in time-sliced buckets, each kept sorted ascending by
/// `(time, seq)`; the common append-at-end insert and the pop-front are
/// both O(1). Far-future events spill into a min-heap and are drained into
/// buckets when the window re-anchors past them. Pop order is exactly
/// `(time, insertion seq)` — bit-identical to [`HeapEventQueue`].
pub struct CalendarEventQueue<E> {
    /// `buckets[i]` covers `[win_start + i*width, win_start + (i+1)*width)`
    /// µs, sorted ascending by `(at, seq)` (front = earliest).
    buckets: Vec<VecDeque<ScheduledEvent<E>>>,
    /// Bucket width in µs (adapts at each re-anchor).
    width: u64,
    /// Absolute µs covered by `buckets[0]`'s left edge.
    win_start: u64,
    /// First bucket that may still hold events (monotone within a window).
    cursor: usize,
    /// Events currently in buckets.
    near_len: usize,
    /// Events at or beyond the window end (min-first via `ScheduledEvent`'s
    /// reversed `Ord`).
    far: BinaryHeap<ScheduledEvent<E>>,
    now: SimTime,
    seq: u64,
    popped: u64,
    /// Exponential moving average of the inter-pop time gap (µs); sizes the
    /// buckets at the next re-anchor.
    gap_ema: f64,
    /// Pushes since the last rebalance — a rebuild is allowed only after
    /// `near_len` further pushes, keeping its cost amortized O(1)/push even
    /// for distributions no width can spread (dense same-µs clusters).
    since_rebalance: u64,
}

impl<E> Default for CalendarEventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> CalendarEventQueue<E> {
    pub fn new() -> Self {
        CalendarEventQueue {
            buckets: (0..BUCKETS).map(|_| VecDeque::new()).collect(),
            width: 256,
            win_start: 0,
            cursor: 0,
            near_len: 0,
            far: BinaryHeap::new(),
            now: SimTime::ZERO,
            seq: 0,
            popped: 0,
            gap_ema: 256.0,
            since_rebalance: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events processed so far.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    pub fn len(&self) -> usize {
        self.near_len + self.far.len()
    }

    pub fn is_empty(&self) -> bool {
        self.near_len == 0 && self.far.is_empty()
    }

    fn win_end(&self) -> u64 {
        self.win_start.saturating_add(self.width * BUCKETS as u64)
    }

    /// Insert into the right near bucket; returns the bucket index so
    /// [`CalendarEventQueue::schedule_at`] can watch for overflow.
    fn insert_near(&mut self, ev: ScheduledEvent<E>) -> usize {
        // When the window was just (re-)anchored ahead of `now` (idle jump
        // to a distant first event), a push may land before `win_start`;
        // clamp it into the cursor bucket. Every earlier bucket is empty
        // and every event in or after the cursor bucket has a larger
        // (at, seq) key — in-bucket sorted insertion keeps the global pop
        // order exact.
        let idx = if ev.at.0 <= self.win_start {
            self.cursor
        } else {
            (((ev.at.0 - self.win_start) / self.width) as usize).max(self.cursor)
        };
        debug_assert!(idx < BUCKETS, "near insert outside window");
        let b = &mut self.buckets[idx];
        let key = (ev.at.0, ev.seq);
        // Hot path: events arrive mostly in increasing (at, seq) — append.
        if !b.back().is_some_and(|e| (e.at.0, e.seq) > key) {
            b.push_back(ev);
        } else {
            let pos = b.partition_point(|e| (e.at.0, e.seq) < key);
            b.insert(pos, ev);
        }
        self.near_len += 1;
        idx
    }

    /// Rebuild the near window around the events it actually holds, with a
    /// width derived from their spread. Triggered when one bucket grows
    /// past [`REBALANCE_LEN`] — a stale over-coarse width after an idle
    /// stretch (probe-only traffic inflates the gap estimate; `rewindow`
    /// can only fix it once the near window drains, which a steady-state
    /// session never does). Pop order is untouched: events are re-placed
    /// in canonical `(at, seq)` order.
    fn rebalance(&mut self) {
        let mut all: Vec<ScheduledEvent<E>> = Vec::with_capacity(self.near_len);
        for b in &mut self.buckets[self.cursor..] {
            all.extend(b.drain(..));
        }
        all.sort_unstable_by(|a, b| (a.at.0, a.seq).cmp(&(b.at.0, b.seq)));
        if all.is_empty() {
            return;
        }
        // Width from the 99th-percentile span so one straggler far ahead
        // (a probe tick past a dense burst) cannot keep the width coarse;
        // events beyond the resulting window spill to the far heap.
        let lo = all[0].at.0;
        let p99 = all[(all.len() * 99) / 100].at.0;
        let span = (p99 - lo).max(1);
        let per_event = span as f64 * TARGET_PER_BUCKET / all.len() as f64;
        self.width = (per_event.ceil() as u64).clamp(1, MAX_WIDTH_US);
        self.gap_ema = self.gap_ema.min(self.width as f64);
        self.win_start = (lo / self.width) * self.width;
        self.cursor = 0;
        self.near_len = 0;
        let end = self.win_end();
        for ev in all {
            if ev.at.0 < end {
                // Sorted order → the append fast path, O(1) each.
                self.insert_near(ev);
            } else {
                self.far.push(ev);
            }
        }
        // The new window may END LATER than the old one (a width increase):
        // any far event now inside it must move near, or a later-timed near
        // event could pop before it and break the far >= win_end invariant
        // (and with it, clock monotonicity and heap-equivalence).
        while let Some(e) = self.far.peek() {
            if e.at.0 >= end {
                break;
            }
            let ev = self.far.pop().expect("peeked event vanished");
            self.insert_near(ev);
        }
    }

    /// Re-anchor the window at the earliest far event and drain every far
    /// event that now falls inside it. Only called when the buckets are
    /// empty, so the cursor restarts at 0.
    fn rewindow(&mut self) {
        debug_assert_eq!(self.near_len, 0);
        self.width = ((self.gap_ema * TARGET_PER_BUCKET).ceil() as u64).clamp(1, MAX_WIDTH_US);
        let first = self.far.peek().expect("rewindow on an empty far heap").at.0;
        self.win_start = (first / self.width) * self.width;
        self.cursor = 0;
        let end = self.win_end();
        while let Some(e) = self.far.peek() {
            if e.at.0 >= end {
                break;
            }
            let ev = self.far.pop().expect("peeked event vanished");
            self.insert_near(ev);
        }
    }

    /// Schedule `event` at absolute virtual time `at`.
    ///
    /// Scheduling in the past (before `now`) is clamped to `now`: it models
    /// a zero-delay effect and keeps the monotonicity invariant.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        let ev = ScheduledEvent { at, seq, event };
        if self.near_len == 0 && self.far.is_empty() {
            // Empty queue: re-anchor the window directly at this event so a
            // long idle jump (e.g. the gap to the next probe tick) never
            // forces a far-heap round trip.
            self.win_start = (at.0 / self.width) * self.width;
            self.cursor = 0;
            self.insert_near(ev);
            return;
        }
        if at.0 < self.win_end() {
            let idx = self.insert_near(ev);
            self.since_rebalance += 1;
            // An over-coarse width piles everything into one bucket and
            // degrades the sorted insert; rebuild with a fresh width. At
            // width 1 the events are true ties and no width can help; the
            // cooldown amortizes the rebuild over the pushes since.
            if self.buckets[idx].len() > REBALANCE_LEN
                && self.width > 1
                && self.since_rebalance >= self.near_len as u64
            {
                self.rebalance();
                self.since_rebalance = 0;
            }
        } else {
            self.far.push(ev);
        }
    }

    /// Schedule `event` after a virtual delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.near_len == 0 {
            if self.far.is_empty() {
                return None;
            }
            self.rewindow();
        }
        while self.buckets[self.cursor].is_empty() {
            self.cursor += 1;
            debug_assert!(self.cursor < BUCKETS, "near events lost");
        }
        let ev = self.buckets[self.cursor].pop_front().expect("non-empty bucket");
        self.near_len -= 1;
        debug_assert!(ev.at >= self.now, "event queue went back in time");
        // Clamp the sample so one idle jump (a probe tick after traffic
        // went quiet) cannot blow the gap estimate — and hence the next
        // window's bucket width — up by orders of magnitude. A genuinely
        // coarser workload still converges (≤16x growth per sample).
        let gap = ((ev.at.0 - self.now.0) as f64).min(self.gap_ema * 16.0);
        self.gap_ema = 0.9 * self.gap_ema + 0.1 * gap;
        self.now = ev.at;
        self.popped += 1;
        Some((ev.at, ev.event))
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        if self.near_len > 0 {
            for b in &self.buckets[self.cursor..] {
                if let Some(e) = b.front() {
                    return Some(e.at);
                }
            }
        }
        self.far.peek().map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run the shared queue contract against both backends.
    macro_rules! queue_contract {
        ($mod:ident, $q:ident) => {
            mod $mod {
                use crate::sim::engine::$q;
                use crate::sim::time::SimTime;

                #[test]
                fn pops_in_time_order() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(30), "c");
                    q.schedule_at(SimTime::from_millis(10), "a");
                    q.schedule_at(SimTime::from_millis(20), "b");
                    let order: Vec<&str> =
                        std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
                    assert_eq!(order, vec!["a", "b", "c"]);
                }

                #[test]
                fn ties_break_by_insertion_order() {
                    let mut q = $q::new();
                    let t = SimTime::from_millis(5);
                    for i in 0..10 {
                        q.schedule_at(t, i);
                    }
                    let order: Vec<i32> =
                        std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
                    assert_eq!(order, (0..10).collect::<Vec<_>>());
                }

                #[test]
                fn clock_advances_monotonically() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(10), ());
                    q.schedule_at(SimTime::from_millis(5), ());
                    let mut last = SimTime::ZERO;
                    while let Some((t, _)) = q.pop() {
                        assert!(t >= last);
                        last = t;
                        assert_eq!(q.now(), t);
                    }
                }

                #[test]
                fn past_scheduling_clamps_to_now() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(10), "first");
                    q.pop();
                    q.schedule_at(SimTime::from_millis(1), "late");
                    let (t, e) = q.pop().unwrap();
                    assert_eq!(e, "late");
                    assert_eq!(t, SimTime::from_millis(10));
                }

                #[test]
                fn schedule_in_is_relative() {
                    let mut q = $q::new();
                    q.schedule_at(SimTime::from_millis(100), "base");
                    q.pop();
                    q.schedule_in(SimTime::from_millis(50), "later");
                    let (t, _) = q.pop().unwrap();
                    assert_eq!(t, SimTime::from_millis(150));
                }

                #[test]
                fn counts_processed_events() {
                    let mut q = $q::new();
                    for i in 0..5u64 {
                        q.schedule_at(SimTime::from_micros(i), i);
                    }
                    while q.pop().is_some() {}
                    assert_eq!(q.events_processed(), 5);
                }

                #[test]
                fn peek_matches_next_pop() {
                    let mut q = $q::new();
                    assert_eq!(q.peek_time(), None);
                    q.schedule_at(SimTime::from_millis(7), 1);
                    q.schedule_at(SimTime::from_millis(3), 2);
                    assert_eq!(q.peek_time(), Some(SimTime::from_millis(3)));
                    let (t, _) = q.pop().unwrap();
                    assert_eq!(t, SimTime::from_millis(3));
                }

                #[test]
                fn len_tracks_contents() {
                    let mut q = $q::new();
                    assert!(q.is_empty());
                    for i in 0..100u64 {
                        q.schedule_at(SimTime::from_micros(i * 37 % 50), i);
                    }
                    assert_eq!(q.len(), 100);
                    q.pop();
                    assert_eq!(q.len(), 99);
                }
            }
        };
    }

    queue_contract!(heap_backend, HeapEventQueue);
    queue_contract!(calendar_backend, CalendarEventQueue);

    #[test]
    fn calendar_survives_window_hops_and_reanchors() {
        let mut q = CalendarEventQueue::new();
        // Far beyond any initial window: forces far-heap spill + rewindow.
        q.schedule_at(SimTime::from_secs_f64(3600.0), "late");
        q.schedule_at(SimTime::from_millis(1), "early");
        assert_eq!(q.pop().unwrap().1, "early");
        // Idle jump: queue drains then re-anchors on the distant event.
        assert_eq!(q.pop().unwrap().1, "late");
        assert_eq!(q.now(), SimTime::from_secs_f64(3600.0));
        // Post-jump scheduling still works near the new now.
        q.schedule_in(SimTime::from_millis(2), "after");
        assert_eq!(q.pop().unwrap().1, "after");
        assert!(q.pop().is_none());
    }

    #[test]
    fn calendar_tie_burst_in_one_bucket_pops_in_seq_order() {
        let mut q = CalendarEventQueue::new();
        let t = SimTime::from_micros(42);
        for i in 0..1000u64 {
            q.schedule_at(t, i);
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..1000).collect::<Vec<_>>());
    }
}
