//! # modest-dl — MoDeST: decentralized learning with client sampling
//!
//! Production-quality reproduction of *"Decentralized Learning Made Practical
//! with Client Sampling"* (MoDeST; de Vos, Dhasade, Kermarrec, Lavoie,
//! Pouwelse, 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   decentralized sampling ([`modest::sampler`]), the membership registry
//!   ([`modest::registry`]), activity tracking ([`modest::activity`]), and
//!   the push-based train/aggregate protocol ([`modest::node`]); plus the
//!   FedAvg / D-SGD baselines ([`baselines`]) and epidemic gossip-DL
//!   ([`gossip`]). All protocols implement [`sim::Protocol`], run on one
//!   shared substrate — the deterministic DES harness ([`sim::SimHarness`])
//!   and the contended WAN fabric with per-node uplink/downlink capacities
//!   ([`net::NetworkFabric`]) — and are launched declaratively through the
//!   Scenario API ([`scenario`]): a layered [`scenario::ScenarioSpec`]
//!   (workload/population/network/protocol/run) dispatched via the
//!   [`scenario::ProtocolRegistry`], plus synthetic federated datasets
//!   ([`data`]) and metrics ([`metrics`]).
//! * **Layer 2** — JAX train/eval/aggregate graphs per model variant,
//!   AOT-lowered to HLO text at build time (`python/compile/`).
//! * **Layer 1** — Pallas kernels for the dense layer (fwd+bwd), the fused
//!   SGD update, and model averaging (`python/compile/kernels/`).
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! when built with the off-by-default `xla` feature; without it a stub
//! keeps all signatures compiling and the mock task drives every protocol
//! test. Python is never on the round path. See rust/README.md for the
//! layer diagram, DESIGN.md for the system inventory, and EXPERIMENTS.md
//! for paper-vs-measured.

pub mod baselines;
pub mod config;
pub mod data;
pub mod experiments;
pub mod gossip;
pub mod learning;
pub mod metrics;
pub mod modest;
pub mod net;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod util;

/// Node identifier: dense index into the session's node table.
pub type NodeId = u32;

/// Training round number (1-based, as in the paper's Algorithm 4).
pub type Round = u64;
