//! The PJRT execution engine: compile once, execute many.
//!
//! One [`XlaRuntime`] owns the PJRT CPU client; each [`VariantRuntime`]
//! holds the three compiled executables (train / eval / avg) plus the
//! initial flat model. All simulated nodes share the executables — a node's
//! state is only its `Vec<f32>` parameter vector, so hundreds of simulated
//! nodes cost hundreds of models, not hundreds of compilations.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use xla::{
    HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtDevice, PjRtLoadedExecutable,
    XlaComputation,
};

use super::manifest::{IoSpec, Manifest, VariantManifest};

/// A training/eval batch in the variant's input dtype.
#[derive(Debug, Clone)]
pub enum Batch {
    /// f32 features + i32 labels (classifiers).
    F32I32 { x: Vec<f32>, y: Vec<i32> },
    /// i32 indices/tokens + f32 targets (matrix factorization).
    I32F32 { x: Vec<i32>, y: Vec<f32> },
    /// i32 tokens + i32 targets (language model).
    I32I32 { x: Vec<i32>, y: Vec<i32> },
}

impl Batch {
    /// Upload x/y as device buffers with the manifest shapes.
    ///
    /// Executions go through `execute_b` with rust-owned input buffers: the
    /// crate's literal-taking `execute` leaks every input buffer it creates
    /// (they are `release()`d in the C shim and never deleted — ~14 MB per
    /// FEMNIST step; §Perf L3 iteration 1).
    fn buffers(
        &self,
        client: &PjRtClient,
        dev: &PjRtDevice,
        xs: &IoSpec,
        ys: &IoSpec,
    ) -> Result<(PjRtBuffer, PjRtBuffer)> {
        let e = |e: xla::Error| anyhow::anyhow!("upload batch: {e:?}");
        let (xb, yb) = match self {
            Batch::F32I32 { x, y } => (
                client.buffer_from_host_buffer::<f32>(x, &xs.shape, Some(dev)).map_err(e)?,
                client.buffer_from_host_buffer::<i32>(y, &ys.shape, Some(dev)).map_err(e)?,
            ),
            Batch::I32F32 { x, y } => (
                client.buffer_from_host_buffer::<i32>(x, &xs.shape, Some(dev)).map_err(e)?,
                client.buffer_from_host_buffer::<f32>(y, &ys.shape, Some(dev)).map_err(e)?,
            ),
            Batch::I32I32 { x, y } => (
                client.buffer_from_host_buffer::<i32>(x, &xs.shape, Some(dev)).map_err(e)?,
                client.buffer_from_host_buffer::<i32>(y, &ys.shape, Some(dev)).map_err(e)?,
            ),
        };
        Ok((xb, yb))
    }

    pub fn x_len(&self) -> usize {
        match self {
            Batch::F32I32 { x, .. } => x.len(),
            Batch::I32F32 { x, .. } => x.len(),
            Batch::I32I32 { x, .. } => x.len(),
        }
    }
}

/// Output of one train step.
#[derive(Debug)]
pub struct TrainOut {
    pub params: Vec<f32>,
    pub velocity: Vec<f32>,
    pub loss: f32,
}

/// Output of one eval call: metric sum (correct count or squared error) and
/// loss sum over the batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalOut {
    pub metric_sum: f32,
    pub loss_sum: f32,
}

/// Compiled executables + metadata for one model variant.
pub struct VariantRuntime {
    pub manifest: VariantManifest,
    client: PjRtClient,
    train_exe: PjRtLoadedExecutable,
    eval_exe: PjRtLoadedExecutable,
    avg_exe: PjRtLoadedExecutable,
    init: Vec<f32>,
}

impl VariantRuntime {
    /// The AOT'd initial flat model (shared starting point, Alg. 4 line 8).
    pub fn init_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    pub fn param_count(&self) -> usize {
        self.manifest.param_count
    }

    fn device(&self) -> Result<PjRtDevice> {
        self.client
            .addressable_devices()
            .into_iter()
            .next()
            .ok_or_else(|| anyhow::anyhow!("no addressable PJRT device"))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize], dev: &PjRtDevice) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, Some(dev))
            .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
    }

    /// Execute with rust-owned input buffers (leak-free path, see
    /// [`Batch::buffers`]) and download the tuple result.
    fn run(&self, exe: &PjRtLoadedExecutable, bufs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let out = exe
            .execute_b(bufs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }

    /// One SGD/momentum step on one batch:
    /// `(params', vel', loss) = train(params, vel, x, y, lr, mu)`.
    pub fn train_step(
        &self,
        params: &[f32],
        velocity: &[f32],
        batch: &Batch,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut> {
        let m = &self.manifest;
        anyhow::ensure!(params.len() == m.param_count, "params len");
        anyhow::ensure!(velocity.len() == m.param_count, "velocity len");
        let dev = self.device()?;
        let pb = self.upload_f32(params, &[m.param_count], &dev)?;
        let vb = self.upload_f32(velocity, &[m.param_count], &dev)?;
        let (xb, yb) = batch.buffers(&self.client, &dev, &m.train_x, &m.train_y)?;
        let lrb = self.upload_f32(&[lr], &[], &dev)?;
        let mub = self.upload_f32(&[mu], &[], &dev)?;
        let mut outs = self.run(&self.train_exe, &[&pb, &vb, &xb, &yb, &lrb, &mub])?;
        anyhow::ensure!(outs.len() == 3, "train tuple arity {}", outs.len());
        let loss = outs.pop().unwrap().to_vec::<f32>()?[0];
        let velocity = outs.pop().unwrap().to_vec::<f32>()?;
        let params = outs.pop().unwrap().to_vec::<f32>()?;
        Ok(TrainOut { params, velocity, loss })
    }

    /// Evaluate on one test batch: returns (metric_sum, loss_sum).
    pub fn eval_batch(&self, params: &[f32], batch: &Batch) -> Result<EvalOut> {
        let m = &self.manifest;
        anyhow::ensure!(params.len() == m.param_count, "params len");
        let dev = self.device()?;
        let pb = self.upload_f32(params, &[m.param_count], &dev)?;
        let (xb, yb) = batch.buffers(&self.client, &dev, &m.eval_x, &m.eval_y)?;
        let outs = self.run(&self.eval_exe, &[&pb, &xb, &yb])?;
        anyhow::ensure!(outs.len() == 2, "eval tuple arity {}", outs.len());
        Ok(EvalOut {
            metric_sum: outs[0].to_vec::<f32>()?[0],
            loss_sum: outs[1].to_vec::<f32>()?[0],
        })
    }

    /// Aggregate up to `smax` models through the Pallas masked-mean kernel.
    ///
    /// This is the XLA-backed aggregation path; the coordinator also has a
    /// native path (`learning::aggregate_native`) — the two are benched
    /// against each other (`rust/benches/hotpaths.rs`).
    pub fn aggregate(&self, models: &[&[f32]]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(!models.is_empty(), "aggregate of zero models");
        anyhow::ensure!(
            models.len() <= m.smax,
            "{} models > smax {}",
            models.len(),
            m.smax
        );
        let p = m.param_count;
        let mut stack = vec![0f32; m.smax * p];
        let mut mask = vec![0f32; m.smax];
        for (i, model) in models.iter().enumerate() {
            anyhow::ensure!(model.len() == p, "model {i} len");
            stack[i * p..(i + 1) * p].copy_from_slice(model);
            mask[i] = 1.0;
        }
        let dev = self.device()?;
        let sb = self.upload_f32(&stack, &[m.smax, p], &dev)?;
        let mb = self.upload_f32(&mask, &[m.smax], &dev)?;
        let cb = self.upload_f32(&[models.len() as f32], &[], &dev)?;
        let outs = self.run(&self.avg_exe, &[&sb, &mb, &cb])?;
        anyhow::ensure!(outs.len() == 1, "avg tuple arity {}", outs.len());
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// Owns the PJRT client and the artifact directory.
pub struct XlaRuntime {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl XlaRuntime {
    /// Load `artifacts/` (the default) or any directory with a manifest.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaRuntime { client, dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.dir.join(file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e:?}"))
    }

    /// Compile the three executables for one variant.
    pub fn variant(&self, name: &str) -> Result<VariantRuntime> {
        let vm = self.manifest.variant(name)?.clone();
        let train_exe = self.compile(&vm.files.train)?;
        let eval_exe = self.compile(&vm.files.eval)?;
        let avg_exe = self.compile(&vm.files.avg)?;
        let init_bytes = std::fs::read(self.dir.join(&vm.files.init))
            .context("reading init params")?;
        anyhow::ensure!(
            init_bytes.len() == vm.param_count * 4,
            "init size {} != 4*{}",
            init_bytes.len(),
            vm.param_count
        );
        let init: Vec<f32> = init_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(VariantRuntime {
            manifest: vm,
            client: self.client.clone(),
            train_exe,
            eval_exe,
            avg_exe,
            init,
        })
    }
}
