//! `artifacts/manifest.json` schema — written by `python/compile/aot.py`,
//! parsed with the in-tree JSON module (the build is offline: no serde).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// Shape + dtype of one executable input.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl IoSpec {
    fn from_json(v: &Json) -> Result<IoSpec> {
        let shape = v
            .field("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(IoSpec { shape, dtype: v.field("dtype")?.as_str()?.to_string() })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// Artifact file names per executable kind.
#[derive(Debug, Clone)]
pub struct VariantFiles {
    pub train: String,
    pub eval: String,
    pub avg: String,
    pub init: String,
}

/// Everything the rust loader needs to know about one model variant.
#[derive(Debug, Clone)]
pub struct VariantManifest {
    pub name: String,
    pub kind: String, // classifier | matfact | lm
    pub param_count: usize,
    pub model_bytes: u64,
    pub smax: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Paper Table 3 network size for this task.
    pub nodes: u32,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub train_x: IoSpec,
    pub train_y: IoSpec,
    pub eval_x: IoSpec,
    pub eval_y: IoSpec,
    pub files: VariantFiles,
    pub init_sha256: String,
    pub meta: BTreeMap<String, Json>,
}

impl VariantManifest {
    fn from_json(v: &Json) -> Result<VariantManifest> {
        let files = v.field("files")?;
        let meta = match v.get("meta") {
            Some(Json::Obj(pairs)) => {
                pairs.iter().map(|(k, x)| (k.clone(), x.clone())).collect()
            }
            _ => BTreeMap::new(),
        };
        Ok(VariantManifest {
            name: v.field("name")?.as_str()?.to_string(),
            kind: v.field("kind")?.as_str()?.to_string(),
            param_count: v.field("param_count")?.as_usize()?,
            model_bytes: v.field("model_bytes")?.as_u64()?,
            smax: v.field("smax")?.as_usize()?,
            lr: v.field("lr")?.as_f64()? as f32,
            momentum: v.field("momentum")?.as_f64()? as f32,
            nodes: v.field("nodes")?.as_u64()? as u32,
            train_batch: v.field("train_batch")?.as_usize()?,
            eval_batch: v.field("eval_batch")?.as_usize()?,
            train_x: IoSpec::from_json(v.field("train_x")?)?,
            train_y: IoSpec::from_json(v.field("train_y")?)?,
            eval_x: IoSpec::from_json(v.field("eval_x")?)?,
            eval_y: IoSpec::from_json(v.field("eval_y")?)?,
            files: VariantFiles {
                train: files.field("train")?.as_str()?.to_string(),
                eval: files.field("eval")?.as_str()?.to_string(),
                avg: files.field("avg")?.as_str()?.to_string(),
                init: files.field("init")?.as_str()?.to_string(),
            },
            init_sha256: v.field("init_sha256")?.as_str()?.to_string(),
            meta,
        })
    }

    /// Integer metadata field (classes, vocab, users, ...).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize().ok())
    }
}

/// Top-level manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub seed: u64,
    pub variants: BTreeMap<String, VariantManifest>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        for (name, body) in v.field("variants")?.as_obj()? {
            variants.insert(
                name.clone(),
                VariantManifest::from_json(body)
                    .with_context(|| format!("variant {name:?}"))?,
            );
        }
        Ok(Manifest { seed: v.field("seed")?.as_u64()?, variants })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn variant(&self, name: &str) -> Result<&VariantManifest> {
        self.variants.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown variant {name:?}; available: {:?}",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{
        "seed": 42,
        "variants": {
            "toy": {
                "name": "toy", "kind": "classifier",
                "param_count": 10, "model_bytes": 40, "smax": 4,
                "lr": 0.01, "momentum": 0.0, "nodes": 8,
                "train_batch": 2, "eval_batch": 4,
                "train_x": {"shape": [2, 3], "dtype": "f32"},
                "train_y": {"shape": [2], "dtype": "i32"},
                "eval_x": {"shape": [4, 3], "dtype": "f32"},
                "eval_y": {"shape": [4], "dtype": "i32"},
                "files": {"train": "t", "eval": "e", "avg": "a", "init": "i"},
                "init_sha256": "00",
                "meta": {"classes": 5}
            }
        }
    }"#;

    #[test]
    fn parses_a_minimal_manifest() {
        let m = Manifest::parse(MINIMAL).unwrap();
        let v = m.variant("toy").unwrap();
        assert_eq!(v.param_count, 10);
        assert_eq!(v.train_x.elements(), 6);
        assert_eq!(v.train_x.dims_i64(), vec![2, 3]);
        assert_eq!(v.meta_usize("classes"), Some(5));
        assert!((v.lr - 0.01).abs() < 1e-9);
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn missing_field_is_error_with_context() {
        let bad = MINIMAL.replace("\"param_count\": 10,", "");
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("param_count"), "{err:#}");
    }
}
