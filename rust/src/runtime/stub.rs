//! Stub runtime for builds without the `xla` feature.
//!
//! Keeps every `Option<&XlaRuntime>`-shaped signature across the config,
//! experiment, bench, and example layers compiling; loading always fails
//! with an actionable error, so artifact-backed datasets are rejected at
//! runtime while the mock task and the whole simulator remain usable.

use std::path::Path;

use anyhow::Result;

use super::manifest::Manifest;

/// Placeholder for the PJRT runtime (enable the `xla` feature for the real
/// one).
pub struct XlaRuntime {
    manifest: Manifest,
}

impl XlaRuntime {
    /// Always fails: this build has no PJRT engine.
    pub fn load(dir: impl AsRef<Path>) -> Result<XlaRuntime> {
        anyhow::bail!(
            "cannot load artifacts from {:?}: modest-dl was built without the \
             `xla` feature (rebuild with `--features xla` and the `xla` PJRT \
             dependency enabled in Cargo.toml, or run with --mock)",
            dir.as_ref()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}
