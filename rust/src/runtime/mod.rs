//! PJRT runtime: load AOT'd HLO-text artifacts and execute them from rust.
//!
//! This is the only bridge between Layer 3 and the JAX/Pallas layers. At
//! build time `python/compile/aot.py` lowers each model variant to HLO
//! *text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos, the
//! text parser reassigns ids); here we parse, compile once per variant on
//! the PJRT CPU client, and execute with flat `Vec<f32>` models. Python is
//! never on the round path.

pub mod engine;
pub mod manifest;

pub use engine::{Batch, EvalOut, TrainOut, VariantRuntime, XlaRuntime};
pub use manifest::{IoSpec, Manifest, VariantManifest};
