//! PJRT runtime: load AOT'd HLO-text artifacts and execute them from rust.
//!
//! This is the only bridge between Layer 3 and the JAX/Pallas layers. At
//! build time `python/compile/aot.py` lowers each model variant to HLO
//! *text* (xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos, the
//! text parser reassigns ids); here we parse, compile once per variant on
//! the PJRT CPU client, and execute with flat `Vec<f32>` models. Python is
//! never on the round path.
//!
//! The PJRT engine is gated behind the off-by-default `xla` cargo feature
//! so the simulator, protocols, and experiments build without native deps.
//! Without the feature, [`stub::XlaRuntime`] keeps every signature
//! compiling and fails with a clear error at load time; the manifest
//! parser ([`manifest`]) is pure rust and always available.

pub mod manifest;

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub use engine::{Batch, EvalOut, TrainOut, VariantRuntime, XlaRuntime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaRuntime;

pub use manifest::{IoSpec, Manifest, VariantManifest};
