//! Synthetic geo latency matrix (WonderNetwork-style substitute).
//!
//! Cities are placed uniformly on a sphere; one-way latency between cities
//! is great-circle distance at fiber propagation speed (~2/3 c) with a route
//! inflation factor, plus a per-pair jitter and a fixed last-mile cost.
//! Nodes are assigned to cities round-robin exactly as the paper does.

use crate::sim::{SimRng, SimTime};
use crate::NodeId;

/// Parameters of the synthetic geography.
#[derive(Debug, Clone)]
pub struct LatencyParams {
    /// Number of distinct cities (the paper ends with 227 usable ones).
    pub cities: usize,
    /// Fixed per-hop cost added to every one-way latency (last mile), secs.
    pub base_s: f64,
    /// Route inflation over great-circle distance (cables aren't geodesics).
    pub inflation: f64,
    /// Relative jitter amplitude applied per city pair (0.1 = ±10%).
    pub jitter: f64,
}

impl Default for LatencyParams {
    fn default() -> Self {
        LatencyParams {
            cities: 227,
            base_s: 0.004,
            inflation: 1.6,
            jitter: 0.15,
        }
    }
}

const EARTH_RADIUS_KM: f64 = 6371.0;
/// Propagation speed in fiber, km/s (~0.66 c).
const FIBER_KM_S: f64 = 199_000.0;

/// Dense symmetric one-way latency matrix over cities + node->city map.
///
/// Latencies are stored pre-quantized in integer µs (the exact values
/// `SimTime::from_secs_f64` would produce), and each node carries its
/// city-row base offset, so the per-transfer lookup on the fabric hot path
/// is two array reads and an add — no float math, no multiply.
#[derive(Debug, Clone)]
pub struct LatencyMatrix {
    cities: usize,
    /// Row-major one-way latency in µs between cities (pre-quantized).
    lat_us: Vec<u64>,
    /// City index for each node (round-robin).
    node_city: Vec<usize>,
    /// Precomputed `city * cities` row base per node.
    node_row: Vec<usize>,
}

impl LatencyMatrix {
    fn from_secs_table(cities: usize, lat_s: Vec<f64>, node_city: Vec<usize>) -> Self {
        let lat_us = lat_s.iter().map(|&s| SimTime::from_secs_f64(s).0).collect();
        let node_row = node_city.iter().map(|&c| c * cities).collect();
        LatencyMatrix { cities, lat_us, node_city, node_row }
    }

    /// Build the synthetic geography from a seeded RNG.
    pub fn synthetic(params: &LatencyParams, nodes: usize, rng: &mut SimRng) -> Self {
        let c = params.cities.max(1);
        // Uniform points on the sphere.
        let pts: Vec<[f64; 3]> = (0..c)
            .map(|_| {
                let z = 2.0 * rng.next_f64() - 1.0;
                let phi = 2.0 * std::f64::consts::PI * rng.next_f64();
                let r = (1.0 - z * z).sqrt();
                [r * phi.cos(), r * phi.sin(), z]
            })
            .collect();
        let mut lat = vec![0.0; c * c];
        for i in 0..c {
            for j in (i + 1)..c {
                let dot: f64 = (0..3).map(|k| pts[i][k] * pts[j][k]).sum();
                let ang = dot.clamp(-1.0, 1.0).acos();
                let dist_km = ang * EARTH_RADIUS_KM;
                let prop = dist_km * params.inflation / FIBER_KM_S;
                let jit = 1.0 + params.jitter * (2.0 * rng.next_f64() - 1.0);
                let one_way = (params.base_s + prop) * jit;
                lat[i * c + j] = one_way;
                lat[j * c + i] = one_way;
            }
            // same-city latency: just the base cost
            lat[i * c + i] = params.base_s;
        }
        let node_city = (0..nodes).map(|n| n % c).collect();
        LatencyMatrix::from_secs_table(c, lat, node_city)
    }

    /// Uniform constant latency (useful in tests and microbenches).
    pub fn uniform(nodes: usize, one_way: SimTime) -> Self {
        LatencyMatrix {
            cities: 1,
            lat_us: vec![one_way.0],
            node_city: vec![0; nodes],
            node_row: vec![0; nodes],
        }
    }

    pub fn nodes(&self) -> usize {
        self.node_city.len()
    }

    /// One-way latency between two nodes.
    #[inline]
    pub fn one_way(&self, a: NodeId, b: NodeId) -> SimTime {
        SimTime(self.lat_us[self.node_row[a as usize] + self.node_city[b as usize]])
    }

    /// Round-trip time between two nodes.
    pub fn rtt(&self, a: NodeId, b: NodeId) -> SimTime {
        SimTime(self.one_way(a, b).0 * 2)
    }

    /// Maximum pairwise one-way latency over the first `n` nodes.
    pub fn max_one_way(&self, n: usize) -> SimTime {
        let mut max = 0u64;
        for a in 0..n.min(self.nodes()) {
            for b in 0..n.min(self.nodes()) {
                max = max.max(self.one_way(a as NodeId, b as NodeId).0);
            }
        }
        SimTime(max)
    }

    /// Minimum one-way latency over every city pair (including same-city
    /// links) — the conservative lookahead of the sharded scheduler in
    /// [`crate::sim::parallel`]: no message can arrive sooner than this, so
    /// a window of that width can never pop out of order. O(cities²), a
    /// one-off at session build, independent of node count.
    pub fn min_one_way(&self) -> SimTime {
        SimTime(self.lat_us.iter().copied().min().unwrap_or(0))
    }

    /// Median one-way latency from `a` to all other nodes (the paper fixes
    /// the FL server at the node with the lowest median latency).
    pub fn median_from(&self, a: NodeId, n: usize) -> SimTime {
        let mut v: Vec<u64> = (0..n)
            .filter(|&b| b as NodeId != a)
            .map(|b| self.one_way(a, b as NodeId).0)
            .collect();
        if v.is_empty() {
            return SimTime::ZERO;
        }
        v.sort_unstable();
        SimTime(v[v.len() / 2])
    }

    /// Node among the first `n` with the lowest median latency to the rest.
    pub fn best_connected(&self, n: usize) -> NodeId {
        (0..n as NodeId)
            .min_by_key(|&a| self.median_from(a, n).0)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix(nodes: usize) -> LatencyMatrix {
        let mut rng = SimRng::new(42);
        LatencyMatrix::synthetic(&LatencyParams::default(), nodes, &mut rng)
    }

    #[test]
    fn symmetric_and_positive() {
        let m = matrix(50);
        for a in 0..50u32 {
            for b in 0..50u32 {
                assert_eq!(m.one_way(a, b), m.one_way(b, a));
                assert!(m.one_way(a, b) > SimTime::ZERO);
            }
        }
    }

    #[test]
    fn realistic_wan_range() {
        // One-way latencies should fall in a plausible WAN envelope:
        // base 4ms .. ~250ms (half the worst RTT the paper's Δt=2s bounds).
        let m = matrix(200);
        let max = m.max_one_way(200);
        assert!(max.as_secs_f64() < 0.5, "max one-way {max}");
        assert!(max.as_secs_f64() > 0.02, "geography too flat: {max}");
    }

    #[test]
    fn deterministic_from_seed() {
        let a = matrix(30);
        let b = matrix(30);
        for i in 0..30u32 {
            assert_eq!(a.one_way(0, i), b.one_way(0, i));
        }
    }

    #[test]
    fn rtt_doubles_one_way() {
        let m = matrix(10);
        assert_eq!(m.rtt(1, 2).0, m.one_way(1, 2).0 * 2);
    }

    #[test]
    fn round_robin_city_assignment() {
        let mut rng = SimRng::new(1);
        let m = LatencyMatrix::synthetic(
            &LatencyParams { cities: 10, ..Default::default() },
            25,
            &mut rng,
        );
        // nodes 0 and 10 share a city -> identical latency vectors
        assert_eq!(m.one_way(0, 5), m.one_way(10, 5));
    }

    #[test]
    fn best_connected_is_stable_and_valid() {
        let m = matrix(40);
        let b = m.best_connected(40);
        assert!(b < 40);
        assert_eq!(b, m.best_connected(40));
    }

    #[test]
    fn uniform_matrix() {
        let m = LatencyMatrix::uniform(5, SimTime::from_millis(10));
        assert_eq!(m.one_way(0, 4), SimTime::from_millis(10));
        assert_eq!(m.rtt(1, 2), SimTime::from_millis(20));
    }

    #[test]
    fn min_one_way_is_a_true_lower_bound() {
        let m = matrix(60);
        let min = m.min_one_way();
        assert!(min > SimTime::ZERO, "synthetic base cost keeps links positive");
        for a in 0..60u32 {
            for b in 0..60u32 {
                assert!(m.one_way(a, b) >= min, "{a}->{b} under the reported minimum");
            }
        }
        assert_eq!(
            LatencyMatrix::uniform(4, SimTime::from_millis(10)).min_one_way(),
            SimTime::from_millis(10)
        );
        assert_eq!(LatencyMatrix::uniform(4, SimTime::ZERO).min_one_way(), SimTime::ZERO);
    }
}
