//! Per-node traffic accounting — the substrate behind Tables 1 and 4.
//!
//! Every byte a session sends is recorded twice (outgoing at the sender,
//! incoming at the receiver — the paper's "network usage" is in+out), and
//! classified by [`MsgKind`] so the MoDeST-overhead row of Table 4 can be
//! computed as `total - model payload`.

use super::message::MsgKind;
use crate::sim::{Hll, StreamHistogram};
use crate::NodeId;

/// Index of the sent counter in a per-node usage record.
const SENT: usize = 0;
/// Index of the received counter in a per-node usage record.
const RECV: usize = 1;

/// Mutable traffic ledger for one session.
///
/// Bookkeeping is one fixed-width `[sent, received]` integer record per
/// node in a single flat allocation — 16 bytes/node, no per-transfer heap
/// work (wire parts travel as stack slices), and both counters of a node
/// share a cache line.
#[derive(Debug, Clone)]
pub struct TrafficLedger {
    usage: Vec<[u64; 2]>,
    by_kind: [u64; 4],
    messages: u64,
    /// Bytes lost in flight (fault injection): charged at the sender,
    /// never received.
    dropped: u64,
    /// Bytes of *delivered* retransmissions — real wire cost, but not
    /// goodput (the payload already counted on its first delivery attempt
    /// or is a duplicate the receiver discards).
    retrans: u64,
    /// Streaming log-bucketed histogram of per-attempt transfer sizes
    /// (bytes). Bounded memory regardless of session length.
    xfer_hist: StreamHistogram,
    /// Distinct directed `(from, to)` pairs that ever carried traffic —
    /// an HLL sketch, so 1M-node sessions stay O(1) in attempts.
    peers: Hll,
    /// Running wire total (== the sum of all `sent` columns), kept so the
    /// per-tick progress emitter reads [`Self::total`] in O(1) instead of
    /// scanning a million-entry usage table. Recomputed on restore.
    wire: u64,
}

fn kind_idx(kind: MsgKind) -> usize {
    match kind {
        MsgKind::ModelPayload => 0,
        MsgKind::ViewPayload => 1,
        MsgKind::Control => 2,
        MsgKind::Membership => 3,
    }
}

impl TrafficLedger {
    pub fn new(nodes: usize) -> Self {
        TrafficLedger {
            usage: vec![[0; 2]; nodes],
            by_kind: [0; 4],
            messages: 0,
            dropped: 0,
            retrans: 0,
            xfer_hist: StreamHistogram::new(),
            peers: Hll::with_salt(0),
            wire: 0,
        }
    }

    /// Install the observability hash salt on the peer sketch. Must be
    /// called before the first attempt is recorded; a no-op afterwards
    /// (see [`Hll::set_salt`]), so restored ledgers keep their state.
    pub fn set_obs_salt(&mut self, salt: u64) {
        self.peers.set_salt(salt);
    }

    /// Grow the ledger when nodes join beyond the initial population.
    pub fn ensure_nodes(&mut self, nodes: usize) {
        if nodes > self.usage.len() {
            self.usage.resize(nodes, [0; 2]);
        }
    }

    /// Record one message of `bytes` split across `parts` kind classes.
    ///
    /// An empty `parts` slice is a no-op: nothing was transferred, so no
    /// message is counted (callers composing part lists dynamically may
    /// legitimately end up with none).
    pub fn record_parts(&mut self, from: NodeId, to: NodeId, parts: &[(MsgKind, u64)]) {
        self.record_attempt(from, to, parts, false, true);
    }

    /// Record one delivery *attempt* under fault injection. Every attempt
    /// is wire cost: the sender's uplink carried it, so `sent`, the kind
    /// columns, and the message count always advance. A delivered attempt
    /// credits the receiver (and, when it was a retransmission, the
    /// retransmitted column); a dropped attempt lands in the dropped
    /// column instead — the wire carried it, nobody got it.
    pub fn record_attempt(
        &mut self,
        from: NodeId,
        to: NodeId,
        parts: &[(MsgKind, u64)],
        retransmit: bool,
        delivered: bool,
    ) {
        if parts.is_empty() {
            return;
        }
        let total: u64 = parts.iter().map(|(_, b)| b).sum();
        self.ensure_nodes((from.max(to) + 1) as usize);
        self.usage[from as usize][SENT] += total;
        if delivered {
            self.usage[to as usize][RECV] += total;
            if retransmit {
                self.retrans += total;
            }
        } else {
            self.dropped += total;
        }
        for &(kind, bytes) in parts {
            self.by_kind[kind_idx(kind)] += bytes;
        }
        self.messages += 1;
        self.wire += total;
        self.xfer_hist.record(total);
        self.peers.insert(((from as u64) << 32) | to as u64);
    }

    /// Record a single-kind message.
    pub fn record(&mut self, from: NodeId, to: NodeId, kind: MsgKind, bytes: u64) {
        self.record_parts(from, to, &[(kind, bytes)]);
    }

    pub fn messages(&self) -> u64 {
        self.messages
    }

    /// In+out bytes for one node (the paper's per-node network usage).
    pub fn node_usage(&self, node: NodeId) -> u64 {
        let u = self.usage[node as usize];
        u[SENT] + u[RECV]
    }

    /// Total wire bytes: every attempt counted once at the sender,
    /// including dropped and retransmitted traffic. O(1): maintained as a
    /// running counter alongside the per-node columns.
    pub fn total(&self) -> u64 {
        self.wire
    }

    /// Bytes lost in flight to fault injection.
    pub fn dropped_bytes(&self) -> u64 {
        self.dropped
    }

    /// Bytes of delivered retransmissions.
    pub fn retransmitted_bytes(&self) -> u64 {
        self.retrans
    }

    /// Useful first-delivery bytes: wire total minus in-flight losses and
    /// retransmissions. This is the Fig. 3-style communication-volume
    /// number; [`Self::total`] remains the true wire cost.
    pub fn goodput(&self) -> u64 {
        self.total().saturating_sub(self.dropped).saturating_sub(self.retrans)
    }

    /// Streaming histogram of per-attempt transfer sizes (bytes).
    pub fn xfer_hist(&self) -> &StreamHistogram {
        &self.xfer_hist
    }

    /// Estimated number of distinct directed `(from, to)` pairs that
    /// carried traffic (HLL; within ~5% of the true count).
    pub fn distinct_peers(&self) -> u64 {
        self.peers.count()
    }

    /// Bytes attributed to one traffic class.
    pub fn kind_total(&self, kind: MsgKind) -> u64 {
        self.by_kind[kind_idx(kind)]
    }

    /// Everything beyond raw model payload (Table 4 bottom: "overhead").
    pub fn overhead(&self) -> u64 {
        self.total() - self.kind_total(MsgKind::ModelPayload)
    }

    /// Overhead as a fraction of total traffic.
    pub fn overhead_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.overhead() as f64 / t as f64
        }
    }

    /// (min, max) in+out usage across nodes that touched any traffic,
    /// restricted to the first `n` nodes. Nodes with zero traffic are
    /// excluded from the min, matching how the paper reports "Min." over
    /// participating nodes.
    pub fn min_max_usage(&self, n: usize) -> (u64, u64) {
        let mut min = u64::MAX;
        let mut max = 0;
        for u in self.usage.iter().take(n) {
            let u = u[SENT] + u[RECV];
            if u > 0 {
                min = min.min(u);
                max = max.max(u);
            }
        }
        if min == u64::MAX {
            min = 0;
        }
        (min, max)
    }

    /// Serialize the full ledger: per-node usage records, per-kind totals,
    /// and the message count. All state here is dynamic — there is nothing
    /// to re-derive on restore.
    pub fn write_into(&self, w: &mut crate::sim::SnapshotWriter) {
        w.write_usize(self.usage.len());
        for u in &self.usage {
            w.write_u64(u[SENT]);
            w.write_u64(u[RECV]);
        }
        for &k in &self.by_kind {
            w.write_u64(k);
        }
        w.write_u64(self.messages);
        w.write_u64(self.dropped);
        w.write_u64(self.retrans);
        self.xfer_hist.write_into(w);
        self.peers.write_into(w);
    }

    pub fn read_from(r: &mut crate::sim::SnapshotReader) -> anyhow::Result<TrafficLedger> {
        let n = r.read_usize()?;
        let mut usage = Vec::with_capacity(n);
        for _ in 0..n {
            let sent = r.read_u64()?;
            let recv = r.read_u64()?;
            usage.push([sent, recv]);
        }
        let mut by_kind = [0u64; 4];
        for k in &mut by_kind {
            *k = r.read_u64()?;
        }
        let messages = r.read_u64()?;
        let dropped = r.read_u64()?;
        let retrans = r.read_u64()?;
        let xfer_hist = StreamHistogram::read_from(r)?;
        let peers = Hll::read_from(r)?;
        let wire = usage.iter().map(|u| u[SENT]).sum();
        Ok(TrafficLedger { usage, by_kind, messages, dropped, retrans, xfer_hist, peers, wire })
    }

    /// Conservation check: every sent byte was either received exactly
    /// once or accounted as dropped in flight.
    pub fn is_conserved(&self) -> bool {
        self.usage.iter().map(|u| u[SENT]).sum::<u64>()
            == self.usage.iter().map(|u| u[RECV]).sum::<u64>() + self.dropped
    }
}

/// Pretty-print bytes the way the paper's tables do (GB/MB/KB).
pub fn fmt_bytes(b: u64) -> String {
    let f = b as f64;
    if f >= 1e9 {
        format!("{:.1} GB", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.1} MB", f / 1e6)
    } else if f >= 1e3 {
        format!("{:.1} KB", f / 1e3)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_both_sides() {
        let mut t = TrafficLedger::new(3);
        t.record(0, 1, MsgKind::ModelPayload, 1000);
        assert_eq!(t.node_usage(0), 1000);
        assert_eq!(t.node_usage(1), 1000);
        assert_eq!(t.node_usage(2), 0);
        assert_eq!(t.total(), 1000);
        assert!(t.is_conserved());
    }

    #[test]
    fn overhead_excludes_model_payload() {
        let mut t = TrafficLedger::new(2);
        t.record_parts(
            0,
            1,
            &[(MsgKind::ModelPayload, 900), (MsgKind::ViewPayload, 100)],
        );
        t.record(1, 0, MsgKind::Control, 50);
        assert_eq!(t.total(), 1050);
        assert_eq!(t.overhead(), 150);
        assert!((t.overhead_fraction() - 150.0 / 1050.0).abs() < 1e-12);
    }

    #[test]
    fn min_max_skips_idle_nodes() {
        let mut t = TrafficLedger::new(4);
        t.record(0, 1, MsgKind::ModelPayload, 100);
        t.record(0, 2, MsgKind::ModelPayload, 300);
        let (min, max) = t.min_max_usage(4);
        assert_eq!(min, 100); // node 1
        assert_eq!(max, 400); // node 0 sent 400
    }

    #[test]
    fn grows_for_joining_nodes() {
        let mut t = TrafficLedger::new(2);
        t.record(0, 9, MsgKind::Membership, 10);
        assert_eq!(t.node_usage(9), 10);
    }

    #[test]
    fn empty_parts_is_a_noop() {
        let mut t = TrafficLedger::new(2);
        t.record_parts(0, 1, &[]);
        assert_eq!(t.messages(), 0);
        assert_eq!(t.total(), 0);
        assert_eq!(t.node_usage(0), 0);
        assert!(t.is_conserved());
    }

    #[test]
    fn ensure_nodes_grows_and_is_idempotent() {
        let mut t = TrafficLedger::new(2);
        t.ensure_nodes(5);
        assert_eq!(t.node_usage(4), 0);
        t.record(4, 1, MsgKind::Control, 7);
        // Shrinking requests are ignored; existing counters survive growth.
        t.ensure_nodes(3);
        t.ensure_nodes(8);
        assert_eq!(t.node_usage(4), 7);
        assert_eq!(t.node_usage(7), 0);
        assert!(t.is_conserved());
    }

    #[test]
    fn late_join_growth_via_record() {
        // A node that joins mid-session and immediately sends: both sides
        // of the ledger must grow together (mirrors churn-scripted joins).
        let mut t = TrafficLedger::new(3);
        t.record(7, 0, MsgKind::Membership, 25);
        t.record(1, 7, MsgKind::ModelPayload, 500);
        assert_eq!(t.node_usage(7), 525);
        assert_eq!(t.total(), 525);
        assert!(t.is_conserved());
        let (min, max) = t.min_max_usage(8);
        assert!(min > 0 && max >= min);
    }

    #[test]
    fn dropped_attempts_split_from_goodput() {
        let mut t = TrafficLedger::new(3);
        // First attempt dropped, retransmission delivered.
        t.record_attempt(0, 1, &[(MsgKind::ModelPayload, 1000)], false, false);
        t.record_attempt(0, 1, &[(MsgKind::ModelPayload, 1000)], true, true);
        // An untouched plain delivery.
        t.record(2, 1, MsgKind::Control, 50);
        assert_eq!(t.total(), 2050, "wire cost counts every attempt");
        assert_eq!(t.dropped_bytes(), 1000);
        assert_eq!(t.retransmitted_bytes(), 1000);
        assert_eq!(t.goodput(), 50);
        assert_eq!(t.messages(), 3);
        // Receiver saw only delivered bytes; sender paid for all attempts.
        assert_eq!(t.node_usage(0), 2000);
        assert_eq!(t.node_usage(1), 1050);
        assert!(t.is_conserved());
    }

    #[test]
    fn conservation_detects_unaccounted_loss() {
        let mut t = TrafficLedger::new(2);
        t.record_attempt(0, 1, &[(MsgKind::Control, 10)], false, false);
        assert!(t.is_conserved(), "dropped bytes are accounted");
        // A duplicate delivered retransmission that never lost its original
        // still conserves: retrans is a sub-classification of received.
        t.record_attempt(0, 1, &[(MsgKind::Control, 10)], true, true);
        assert!(t.is_conserved());
        assert_eq!(t.goodput(), 0);
    }

    #[test]
    fn snapshot_roundtrips_loss_columns() {
        let mut t = TrafficLedger::new(2);
        t.record_attempt(0, 1, &[(MsgKind::ModelPayload, 700)], false, false);
        t.record_attempt(0, 1, &[(MsgKind::ModelPayload, 700)], true, true);
        let mut w = crate::sim::SnapshotWriter::new();
        w.begin_section("ledger");
        t.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = crate::sim::SnapshotReader::new(&bytes).unwrap();
        r.begin_section("ledger").unwrap();
        let back = TrafficLedger::read_from(&mut r).unwrap();
        assert_eq!(back.dropped_bytes(), 700);
        assert_eq!(back.retransmitted_bytes(), 700);
        assert_eq!(back.goodput(), 0);
        assert_eq!(back.total(), t.total());
        assert!(back.is_conserved());
    }

    #[test]
    fn sketches_track_attempts_and_roundtrip() {
        let mut t = TrafficLedger::new(8);
        t.set_obs_salt(0x5EED);
        for i in 0..6u32 {
            t.record(i, (i + 1) % 8, MsgKind::ModelPayload, 100 * (i as u64 + 1));
        }
        // Repeats of an existing pair must not grow the distinct count.
        t.record(0, 1, MsgKind::ModelPayload, 100);
        assert_eq!(t.distinct_peers(), 6, "small-n HLL counts are exact");
        assert_eq!(t.xfer_hist().total(), 7);
        assert_eq!(t.xfer_hist().min(), 100);
        assert_eq!(t.xfer_hist().max(), 600);

        let mut w = crate::sim::SnapshotWriter::new();
        w.begin_section("ledger");
        t.write_into(&mut w);
        w.end_section();
        let bytes = w.finish();
        let mut r = crate::sim::SnapshotReader::new(&bytes).unwrap();
        r.begin_section("ledger").unwrap();
        let back = TrafficLedger::read_from(&mut r).unwrap();
        assert_eq!(back.distinct_peers(), t.distinct_peers());
        assert_eq!(back.xfer_hist(), t.xfer_hist());
    }

    #[test]
    fn obs_salt_is_frozen_after_first_attempt() {
        let mut t = TrafficLedger::new(2);
        t.set_obs_salt(1);
        t.record(0, 1, MsgKind::Control, 10);
        let before = t.distinct_peers();
        t.set_obs_salt(2); // ignored: sketch already has inserts
        t.record(0, 1, MsgKind::Control, 10);
        assert_eq!(t.distinct_peers(), before);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_bytes(1_004_100_000_000 / 1000), "1.0 GB");
        assert_eq!(fmt_bytes(7_600_000), "7.6 MB");
        assert_eq!(fmt_bytes(512), "512 B");
    }
}
