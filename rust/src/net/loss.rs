//! Deterministic per-message fault injection for [`super::NetworkFabric`].
//!
//! Drop decisions come from a dedicated `fork("loss")` RNG stream owned by
//! [`LossLayer`], so sessions without a loss model (the `disabled` layer)
//! consume zero draws and perturb nothing — `loss = 0` and absent-section
//! scenarios replay pre-loss same-seed fingerprints bit-identically.
//!
//! Three models, compiled from `network.loss` by the scenario layer:
//!
//! - `Uniform`: one flat drop probability on every transfer.
//! - `Classes`: a per-tier drop probability riding the bandwidth tiers; a
//!   transfer survives only if *both* endpoints' tiers keep it
//!   (`p = 1 − (1−p_from)·(1−p_to)` folded into independent rolls).
//! - `Burst`: a two-state Gilbert–Elliott channel per *receiver* —
//!   exponentially-distributed dwell times in a good and a bad state, each
//!   with its own drop probability. Receiver-side state models last-mile
//!   outages: every sender talking to a node in a bad spell suffers
//!   together, which is what makes loss bursty rather than i.i.d.

use anyhow::Result;

use crate::sim::snapshot::{SnapshotReader, SnapshotWriter};
use crate::sim::{SimRng, SimTime};

/// Runtime drop model, compiled from `scenario::LossSpec` (which owns
/// parsing/validation; every probability here is already in `[0, 1]` and
/// every dwell mean is finite and positive).
#[derive(Debug, Clone, PartialEq)]
pub enum LossModel {
    Uniform { p: f64 },
    Classes { tier_p: Vec<f64> },
    Burst { p_good: f64, p_bad: f64, good_mean_s: f64, bad_mean_s: f64 },
}

/// Per-receiver Gilbert–Elliott channel state, advanced lazily: a channel
/// is materialized on its first decide and caught up through all dwell
/// periods that elapsed since it was last consulted. Catch-up draws depend
/// only on (receiver, now), so decide order between *different* receivers
/// never changes a channel's trajectory.
#[derive(Debug)]
pub struct LossLayer {
    model: Option<LossModel>,
    rng: SimRng,
    /// Burst state, indexed by receiver: in the bad state?
    state_bad: Vec<bool>,
    /// Time at which the current dwell period ends.
    until: Vec<SimTime>,
    /// Whether the channel has been materialized yet.
    init: Vec<bool>,
}

impl LossLayer {
    /// The no-op layer: no model, a placeholder RNG that is never drawn
    /// from, zero per-node state.
    pub fn disabled() -> Self {
        LossLayer {
            model: None,
            rng: SimRng::new(0),
            state_bad: Vec::new(),
            until: Vec::new(),
            init: Vec::new(),
        }
    }

    /// Install `model` with its dedicated RNG stream (the caller forks
    /// `"loss"` off the run seed so this stream is independent of every
    /// other consumer).
    pub fn new(model: LossModel, rng: SimRng) -> Self {
        LossLayer { model: Some(model), rng, state_bad: Vec::new(), until: Vec::new(), init: Vec::new() }
    }

    pub fn enabled(&self) -> bool {
        self.model.is_some()
    }

    fn ensure_node(&mut self, node: usize) {
        if node >= self.init.len() {
            self.state_bad.resize(node + 1, false);
            self.until.resize(node + 1, SimTime::ZERO);
            self.init.resize(node + 1, false);
        }
    }

    /// Roll a drop with probability `p`. Degenerate probabilities consume
    /// no RNG draw, so e.g. a `tiers: [0.0, 0.3]` classes model draws once
    /// per lossy endpoint, not twice per transfer.
    fn roll(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            false
        } else if p >= 1.0 {
            true
        } else {
            self.rng.next_f64() < p
        }
    }

    fn exp_dwell(&mut self, mean_s: f64) -> SimTime {
        // Clamp to one microsecond so a tiny draw can't quantize to a
        // zero-length dwell and stall the catch-up loop.
        SimTime::from_micros((self.rng.next_exp(mean_s) * 1e6).max(1.0) as u64)
    }

    /// Advance `node`'s Gilbert–Elliott channel to `now` and return its
    /// current drop probability.
    fn burst_p(&mut self, node: usize, now: SimTime) -> f64 {
        let (p_good, p_bad, good_mean_s, bad_mean_s) = match &self.model {
            Some(LossModel::Burst { p_good, p_bad, good_mean_s, bad_mean_s }) => {
                (*p_good, *p_bad, *good_mean_s, *bad_mean_s)
            }
            _ => unreachable!("burst_p called without a burst model"),
        };
        self.ensure_node(node);
        if !self.init[node] {
            self.init[node] = true;
            self.state_bad[node] = false;
            let dwell = self.exp_dwell(good_mean_s);
            self.until[node] = dwell; // first dwell measured from t = 0
        }
        while self.until[node] <= now {
            let bad = !self.state_bad[node];
            self.state_bad[node] = bad;
            let mean = if bad { bad_mean_s } else { good_mean_s };
            let dwell = self.exp_dwell(mean);
            self.until[node] += dwell;
        }
        if self.state_bad[node] { p_bad } else { p_good }
    }

    /// Decide whether the transfer `from → to` starting at `now` is lost.
    /// `from_tier`/`to_tier` are the endpoints' bandwidth-class indices
    /// (0 for non-Classes bandwidth configs). Returns `true` to drop.
    pub fn decide(
        &mut self,
        now: SimTime,
        _from: usize,
        to: usize,
        from_tier: u32,
        to_tier: u32,
    ) -> bool {
        match &self.model {
            None => false,
            Some(LossModel::Uniform { p }) => {
                let p = *p;
                self.roll(p)
            }
            Some(LossModel::Classes { tier_p }) => {
                // Independent loss at each endpoint's tier; either roll
                // dropping loses the transfer.
                let p_from = tier_p.get(from_tier as usize).copied().unwrap_or(0.0);
                let p_to = tier_p.get(to_tier as usize).copied().unwrap_or(0.0);
                let lost = self.roll(p_from);
                // Always evaluate the receiver roll too so the draw count
                // per transfer is a function of the tier pair alone, not of
                // the sender roll's outcome.
                let lost_rx = self.roll(p_to);
                lost || lost_rx
            }
            Some(LossModel::Burst { .. }) => {
                let p = self.burst_p(to, now);
                self.roll(p)
            }
        }
    }

    /// Serialize mutable state (RNG position + burst channels). The model
    /// itself is *not* written — it is recompiled from the scenario spec on
    /// restore, so what-if overlays may change the loss config.
    pub fn write_into(&self, w: &mut SnapshotWriter) {
        w.write_bool(self.enabled());
        if !self.enabled() {
            return;
        }
        w.write_rng(&self.rng);
        w.write_usize(self.init.len());
        for i in 0..self.init.len() {
            w.write_bool(self.init[i]);
            w.write_bool(self.state_bad[i]);
            w.write_time(self.until[i]);
        }
    }

    /// Restore mutable state. When the snapshot and the (possibly
    /// overlaid) current config disagree on whether loss is enabled, the
    /// snapshot's loss state is discarded and the freshly-built layer
    /// stands — the branch is deliberately diverging.
    pub fn restore_from(&mut self, r: &mut SnapshotReader) -> Result<()> {
        let was_enabled = r.read_bool()?;
        if !was_enabled {
            return Ok(());
        }
        let rng = r.read_rng()?;
        let n = r.read_usize()?;
        let mut init = Vec::with_capacity(n);
        let mut state_bad = Vec::with_capacity(n);
        let mut until = Vec::with_capacity(n);
        for _ in 0..n {
            init.push(r.read_bool()?);
            state_bad.push(r.read_bool()?);
            until.push(r.read_time()?);
        }
        if self.enabled() {
            self.rng = rng;
            self.init = init;
            self.state_bad = state_bad;
            self.until = until;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss_rng() -> SimRng {
        SimRng::new(42).fork("loss")
    }

    fn snapshot_of(layer: &LossLayer) -> Vec<u8> {
        let mut w = SnapshotWriter::new();
        w.begin_section("loss");
        layer.write_into(&mut w);
        w.end_section();
        w.finish()
    }

    fn restore_into(layer: &mut LossLayer, bytes: &[u8]) {
        let mut r = SnapshotReader::new(bytes).unwrap();
        r.begin_section("loss").unwrap();
        layer.restore_from(&mut r).unwrap();
        r.end_section().unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn disabled_layer_never_drops_and_never_draws() {
        let mut layer = LossLayer::disabled();
        assert!(!layer.enabled());
        for i in 0..1000usize {
            assert!(!layer.decide(SimTime::from_millis(i as u64), i % 7, i % 5, 0, 0));
        }
        // The RNG is untouched: it still matches a fresh seed-0 stream.
        assert_eq!(layer.rng.state(), SimRng::new(0).state());
    }

    #[test]
    fn uniform_extremes_skip_rng_draws() {
        let mut never = LossLayer::new(LossModel::Uniform { p: 0.0 }, loss_rng());
        let mut always = LossLayer::new(LossModel::Uniform { p: 1.0 }, loss_rng());
        for i in 0..100u64 {
            assert!(!never.decide(SimTime::from_millis(i), 0, 1, 0, 0));
            assert!(always.decide(SimTime::from_millis(i), 0, 1, 0, 0));
        }
        assert_eq!(never.rng.state(), loss_rng().state());
        assert_eq!(always.rng.state(), loss_rng().state());
    }

    #[test]
    fn uniform_drop_rate_tracks_p() {
        let mut layer = LossLayer::new(LossModel::Uniform { p: 0.3 }, loss_rng());
        let drops = (0..20_000)
            .filter(|&i| layer.decide(SimTime::from_millis(i), 0, 1, 0, 0))
            .count();
        let rate = drops as f64 / 20_000.0;
        assert!((rate - 0.3).abs() < 0.02, "observed drop rate {rate}");
    }

    #[test]
    fn classes_respects_tier_pair() {
        let model = LossModel::Classes { tier_p: vec![0.0, 0.4] };
        // Tier-0 <-> tier-0: never drops, never draws.
        let mut layer = LossLayer::new(model.clone(), loss_rng());
        for i in 0..100u64 {
            assert!(!layer.decide(SimTime::from_millis(i), 0, 1, 0, 0));
        }
        assert_eq!(layer.rng.state(), loss_rng().state());
        // A lossy endpoint on either side drops at ~its tier rate.
        for (ft, tt) in [(1u32, 0u32), (0, 1)] {
            let mut layer = LossLayer::new(model.clone(), loss_rng());
            let drops = (0..20_000)
                .filter(|&i| layer.decide(SimTime::from_millis(i), 0, 1, ft, tt))
                .count();
            let rate = drops as f64 / 20_000.0;
            assert!((rate - 0.4).abs() < 0.02, "tier ({ft},{tt}) drop rate {rate}");
        }
        // Both endpoints lossy: combined 1-(1-p)^2 = 0.64.
        let mut layer = LossLayer::new(model, loss_rng());
        let drops = (0..20_000)
            .filter(|&i| layer.decide(SimTime::from_millis(i), 0, 1, 1, 1))
            .count();
        let rate = drops as f64 / 20_000.0;
        assert!((rate - 0.64).abs() < 0.02, "two-lossy-tier drop rate {rate}");
    }

    #[test]
    fn burst_channel_alternates_and_is_receiver_scoped() {
        let model = LossModel::Burst {
            p_good: 0.0,
            p_bad: 1.0,
            good_mean_s: 10.0,
            bad_mean_s: 10.0,
        };
        let mut layer = LossLayer::new(model, loss_rng());
        // With p_good=0 / p_bad=1 the decide outcome *is* the channel
        // state. Sample a long horizon: both states must occur, and the
        // drop fraction should hover near the 50% duty cycle.
        let mut drops = 0;
        let samples = 4000u64;
        for i in 0..samples {
            if layer.decide(SimTime::from_secs_f64(i as f64 * 0.5), 0, 1, 0, 0) {
                drops += 1;
            }
        }
        let frac = drops as f64 / samples as f64;
        assert!(frac > 0.2 && frac < 0.8, "bad-state duty cycle {frac}");
        // A different receiver gets an independent, freshly-drawn channel.
        let before = layer.rng.state().1;
        let _ = layer.decide(SimTime::from_secs_f64(1.0), 0, 2, 0, 0);
        assert!(layer.rng.state().1 > before, "second receiver drew no dwell samples");
    }

    #[test]
    fn burst_catch_up_is_time_driven_not_call_driven() {
        // Two layers with identical streams queried at the same final
        // instant land in the same channel state regardless of how many
        // intermediate decides happened (p=0/0 ensures no drop rolls).
        let model = LossModel::Burst {
            p_good: 0.0,
            p_bad: 0.0,
            good_mean_s: 5.0,
            bad_mean_s: 5.0,
        };
        let mut sparse = LossLayer::new(model.clone(), loss_rng());
        let mut dense = LossLayer::new(model, loss_rng());
        let end = SimTime::from_secs_f64(200.0);
        sparse.decide(end, 0, 1, 0, 0);
        for i in 0..50u64 {
            dense.decide(SimTime::from_secs_f64(i as f64 * 4.0), 0, 1, 0, 0);
        }
        dense.decide(end, 0, 1, 0, 0);
        assert_eq!(sparse.state_bad[1], dense.state_bad[1]);
        assert_eq!(sparse.until[1], dense.until[1]);
    }

    #[test]
    fn snapshot_roundtrip_resumes_stream_and_channels() {
        let model = LossModel::Burst {
            p_good: 0.1,
            p_bad: 0.9,
            good_mean_s: 3.0,
            bad_mean_s: 1.0,
        };
        let mut layer = LossLayer::new(model.clone(), loss_rng());
        for i in 0..500u64 {
            layer.decide(SimTime::from_millis(i * 97), 0, (i % 5) as usize, 0, 0);
        }
        let bytes = snapshot_of(&layer);

        let mut restored = LossLayer::new(model, loss_rng());
        restore_into(&mut restored, &bytes);
        for i in 500..1000u64 {
            let t = SimTime::from_millis(i * 97);
            let to = (i % 5) as usize;
            assert_eq!(layer.decide(t, 0, to, 0, 0), restored.decide(t, 0, to, 0, 0));
        }
        assert_eq!(layer.rng.state(), restored.rng.state());
    }

    #[test]
    fn restore_tolerates_enabled_flag_mismatch() {
        // Snapshot written with loss on, restored into a lossless branch:
        // the loss bytes are consumed and dropped.
        let mut lossy = LossLayer::new(LossModel::Uniform { p: 0.5 }, loss_rng());
        for i in 0..100u64 {
            lossy.decide(SimTime::from_millis(i), 0, 1, 0, 0);
        }
        let bytes = snapshot_of(&lossy);
        let mut off = LossLayer::disabled();
        restore_into(&mut off, &bytes);
        assert!(!off.enabled());

        // Snapshot written lossless, restored into a lossy branch: the
        // fresh layer stands untouched.
        let bytes = snapshot_of(&LossLayer::disabled());
        let mut on = LossLayer::new(LossModel::Uniform { p: 0.5 }, loss_rng());
        restore_into(&mut on, &bytes);
        assert!(on.enabled());
        assert_eq!(on.rng.state(), loss_rng().state());
    }
}
