//! Wire-size model for every protocol message.
//!
//! Table 4 of the paper reports *bytes*, so the simulator needs a faithful
//! size model rather than real serialization. Sizes follow the paper's
//! implementation: IPv8-style authenticated UDP headers, TFTP-style bulk
//! transfer for models, and views piggybacked on model transfers
//! (registry entry = id + counter + event flag; activity entry = id +
//! round estimate).

/// Classification of traffic for the overhead accounting in Table 4
/// (bottom): everything that is not raw model payload is "MoDeST overhead".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Model payload bytes inside `train`/`aggregate` transfers.
    ModelPayload,
    /// Piggybacked view bytes inside `train`/`aggregate` transfers.
    ViewPayload,
    /// Ping/pong liveness probes (Alg. 1).
    Control,
    /// Membership advertisements: joined/left (Alg. 2).
    Membership,
}

impl MsgKind {
    /// Stable wire tag for snapshots (in-flight retransmit state).
    pub fn tag(self) -> u8 {
        match self {
            MsgKind::ModelPayload => 0,
            MsgKind::ViewPayload => 1,
            MsgKind::Control => 2,
            MsgKind::Membership => 3,
        }
    }

    pub fn from_tag(tag: u8) -> anyhow::Result<MsgKind> {
        Ok(match tag {
            0 => MsgKind::ModelPayload,
            1 => MsgKind::ViewPayload,
            2 => MsgKind::Control,
            3 => MsgKind::Membership,
            other => anyhow::bail!("unknown MsgKind tag {other}"),
        })
    }
}

/// Byte-size model for protocol messages.
#[derive(Debug, Clone)]
pub struct SizeModel {
    /// Per-packet header: IPv8 auth (sig + pubkey) + UDP/IP.
    pub header: u64,
    /// Bytes per registry entry in a serialized view: node id (8) +
    /// counter (8) + event flag (1).
    pub registry_entry: u64,
    /// Bytes per activity entry: node id (8) + round estimate (8).
    pub activity_entry: u64,
    /// Ping/pong payload (round + sender + nonce).
    pub ping: u64,
}

impl Default for SizeModel {
    fn default() -> Self {
        SizeModel {
            header: 108, // 28 UDP/IP + 64 sig + 16 misc (IPv8-style)
            registry_entry: 17,
            activity_entry: 16,
            ping: 24,
        }
    }
}

impl SizeModel {
    /// Size of a serialized view over `n` known nodes (registry + activity).
    pub fn view_bytes(&self, n: usize) -> u64 {
        (self.registry_entry + self.activity_entry) * n as u64
    }

    /// Total size of a model transfer (train/aggregate) carrying a view.
    /// TFTP-style chunking adds one header per 8 KiB block.
    pub fn model_transfer_bytes(&self, model_bytes: u64, view_nodes: usize) -> u64 {
        let payload = model_bytes + self.view_bytes(view_nodes);
        let blocks = payload.div_ceil(8192).max(1);
        payload + blocks * self.header
    }

    /// Size of a ping or pong packet.
    pub fn ping_bytes(&self) -> u64 {
        self.header + self.ping
    }

    /// Size of a joined/left advertisement.
    pub fn membership_bytes(&self) -> u64 {
        self.header + self.registry_entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_scales_with_population() {
        let m = SizeModel::default();
        assert_eq!(m.view_bytes(0), 0);
        assert_eq!(m.view_bytes(100), 3300);
        assert!(m.view_bytes(500) > m.view_bytes(100));
    }

    #[test]
    fn model_transfer_dominated_by_model() {
        let m = SizeModel::default();
        // FEMNIST-sized model (6.7 MB), 355-node view: overhead must be
        // well under 1% of the transfer, matching Table 4's 0.4%.
        let model = 6_700_000u64;
        let total = m.model_transfer_bytes(model, 355);
        let overhead = total - model;
        assert!((overhead as f64) / (total as f64) < 0.02, "{overhead}");
    }

    #[test]
    fn chunking_headers_counted() {
        let m = SizeModel::default();
        let small = m.model_transfer_bytes(100, 0);
        assert_eq!(small, 100 + m.header);
        let big = m.model_transfer_bytes(16384, 0);
        assert_eq!(big, 16384 + 2 * m.header);
    }

    #[test]
    fn control_sizes_are_small() {
        let m = SizeModel::default();
        assert!(m.ping_bytes() < 200);
        assert!(m.membership_bytes() < 200);
    }
}
